"""Version-adaptive JAX compatibility layer — the single import point.

The distributed API surface this repo depends on has drifted across the
jax versions it must run on:

  * ``shard_map`` moved: ``jax.experimental.shard_map.shard_map``
    (jax <= 0.5.x) -> top-level ``jax.shard_map`` (newer), and its
    replication-check kwarg was renamed ``check_rep`` -> ``check_vma``.
  * ``jax.sharding.AxisType`` and ``jax.make_mesh(..., axis_types=)``
    (explicit-sharding API) do not exist on jax 0.4.x at all.

Every module in the repo resolves these names HERE; nothing else may
version-sniff jax (enforced by the tier-1 grep check).  Feature flags let
callers branch on capability instead of version string:

  JAX_VERSION              (major, minor, patch) ints parsed from jax.__version__
  HAS_AXIS_TYPE            jax.sharding.AxisType exists
  HAS_TOPLEVEL_SHARD_MAP   jax.shard_map exists
  SHARD_MAP_CHECK_KWARG    "check_vma" | "check_rep" | None (name accepted by
                           the resolved shard_map implementation)

The ``_resolve_*``/``_build_*`` helpers take the (possibly fake) jax
module as an argument so tests can exercise both old- and new-API shapes
without installing a second jax.
"""

from __future__ import annotations

import inspect
import math
import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def _version_tuple(version: str) -> Tuple[int, int, int]:
    parts = []
    for piece in version.split(".")[:3]:
        # leading digits only: "37rc1" is 37, a pure suffix like "dev123"
        # contributes nothing (concatenating all digits would turn an rc
        # into a huge patch number)
        m = re.match(r"\d+", piece)
        parts.append(int(m.group()) if m else 0)
    while len(parts) < 3:
        parts.append(0)
    return tuple(parts)  # type: ignore[return-value]


JAX_VERSION: Tuple[int, int, int] = _version_tuple(jax.__version__)

AxisType = getattr(jax.sharding, "AxisType", None)
HAS_AXIS_TYPE: bool = AxisType is not None
HAS_TOPLEVEL_SHARD_MAP: bool = callable(getattr(jax, "shard_map", None))


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def _check_kwarg_name(fn) -> Optional[str]:
    """Which replication-check kwarg does this shard_map accept?"""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return None
    if "check_vma" in params:
        return "check_vma"
    if "check_rep" in params:
        return "check_rep"
    return None


def _resolve_shard_map(jax_module):
    """Return (implementation, check_kwarg_name) for this jax module."""
    impl = getattr(jax_module, "shard_map", None)
    if not callable(impl):
        exp = getattr(jax_module, "experimental", None)
        sub = getattr(exp, "shard_map", None) if exp is not None else None
        if sub is None and jax_module is jax:
            from jax.experimental import shard_map as sub  # noqa: PLC0415
        impl = getattr(sub, "shard_map", None) if sub is not None else None
    if impl is None:
        raise ImportError(
            "could not resolve shard_map: neither jax.shard_map nor "
            "jax.experimental.shard_map.shard_map exists")
    return impl, _check_kwarg_name(impl)


def _build_shard_map(impl, check_kwarg: Optional[str]):
    """Wrap a resolved implementation behind the new-style signature.

    The wrapper always accepts ``check_vma`` (the newest name) and
    translates it to whatever the implementation understands, dropping it
    when the implementation predates both spellings.
    """

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
        kw = dict(kwargs, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        if check_vma is not None and check_kwarg is not None:
            kw[check_kwarg] = check_vma
        return impl(f, **kw)

    return shard_map


_SHARD_MAP_IMPL, SHARD_MAP_CHECK_KWARG = _resolve_shard_map(jax)
shard_map = _build_shard_map(_SHARD_MAP_IMPL, SHARD_MAP_CHECK_KWARG)


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------

def _resolve_axis_types(axis_types, n_axes: int):
    """Normalize user axis_types ("auto" | AxisType | sequence) to a tuple
    of AxisType, or None when this jax has no AxisType (degrade: the
    pre-explicit-sharding default behaves like Auto everywhere)."""
    if not HAS_AXIS_TYPE:
        return None
    if axis_types is None:
        axis_types = "auto"
    if isinstance(axis_types, str) or not isinstance(axis_types, (tuple, list)):
        axis_types = (axis_types,) * n_axes
    if len(axis_types) != n_axes:
        raise ValueError(f"axis_types {axis_types!r} vs {n_axes} axes")

    def one(t):
        if isinstance(t, str):
            try:
                return getattr(AxisType, t.capitalize())
            except AttributeError:
                raise ValueError(f"unknown axis type {t!r}") from None
        return t

    return tuple(one(t) for t in axis_types)


def _mesh_from_devices(axis_shapes, axis_names, devices):
    """Oldest-API fallback: build a Mesh by hand from a device list."""
    n = math.prod(axis_shapes)
    if len(devices) < n:
        raise ValueError(f"need {n} devices for mesh {axis_shapes}, "
                         f"have {len(devices)}")
    grid = np.asarray(devices[:n], dtype=object).reshape(axis_shapes)
    return jax.sharding.Mesh(grid, axis_names)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None, axis_types=None):
    """Version-portable ``jax.make_mesh``.

    ``axis_types`` accepts the new-API values ("auto" / "explicit" /
    "manual", an AxisType, or a per-axis sequence) and is silently dropped
    on jax builds without ``jax.sharding.AxisType`` — those versions have
    exactly one (auto) behavior, so dropping loses nothing.
    """
    axis_shapes = tuple(int(s) for s in axis_shapes)
    axis_names = tuple(axis_names)
    resolved = _resolve_axis_types(axis_types, len(axis_names))
    kwargs = {} if devices is None else {"devices": devices}
    mk = getattr(jax, "make_mesh", None)
    if mk is not None:
        # decide by signature, not by catching TypeError: a swallowed
        # TypeError from inside make_mesh would silently downgrade a
        # requested explicit/manual mesh to the auto default
        if resolved is not None:
            try:
                accepts = "axis_types" in inspect.signature(mk).parameters
            except (TypeError, ValueError):
                accepts = True
            if accepts:
                kwargs["axis_types"] = resolved
        return mk(axis_shapes, axis_names, **kwargs)
    return _mesh_from_devices(axis_shapes, axis_names,
                              devices if devices is not None else jax.devices())


# ---------------------------------------------------------------------------
# compiled-artifact introspection
# ---------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to one flat dict.

    jax <= 0.4.x returns a one-element LIST of per-program dicts; newer
    jax returns the dict directly.  Returns {} when the backend offers no
    cost model at all.
    """
    try:
        cost = compiled.cost_analysis()
    except Exception:
        return {}
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        merged: dict = {}
        for entry in cost:
            if isinstance(entry, dict):
                merged.update(entry)
        return merged
    return dict(cost)
