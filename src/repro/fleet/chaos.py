"""Deterministic chaos harness: composite fault schedules + verdicts.

One harness, three consumers (tests, ``benchmarks/serve.py``'s chaos
smoke scenario, ``examples/chaos_fleet.py``): build a fleet whose
replicas carry arbitrary ``FaultPlan`` compositions (kill x hang x slow
x transient x torn-shard x join timing), drive it to drain, and reduce
the run to STRUCTURAL verdicts — quantities that are deterministic
functions of the schedule, never of the wall clock:

  * ``token_identical`` / ``silent_drops``: the fleet oracle — every
    submitted request completes with tokens byte-identical to the
    single-engine greedy reference, under any recoverable schedule;
  * ``recoveries`` vs ``transients_injected``: every transient incident
    that was scheduled to clear actually cleared through retry/backoff
    (none leaked into the kill path);
  * ``restores`` vs rescales: every membership change re-sliced the
    checkpointed state onto the new plan (when checkpointing is on);
  * ``corrupt_shards``: torn snapshots were detected and skipped, never
    loaded.

Because every fault is tick-addressed and every timestamp comes from
the controller's tick counter, re-running the same schedule replays
exactly — the byte-identical-trace property the tier-1 tests pin.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from .controller import FleetController, FleetReport, RetryPolicy
from .replica import FaultPlan, Replica

__all__ = ["ChaosReplicaSpec", "ChaosSchedule", "chaos_verdicts",
           "run_chaos"]


@dataclasses.dataclass(frozen=True)
class ChaosReplicaSpec:
    """One fleet member of a chaos schedule: identity + capacity +
    (optionally) the deterministic faults it will suffer."""

    name: str
    rate: float = 1.0
    fault: Optional[FaultPlan] = None


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """A composite, fully tick-addressed fault schedule.

    ``checkpoint_every`` > 0 additionally asks ``run_chaos`` to enable
    the controller's live checkpoint-recovery plane (the caller supplies
    the directory and state)."""

    replicas: Tuple[ChaosReplicaSpec, ...]
    join_at: Optional[int] = None
    join_name: str = "joiner"
    join_rate: float = 1.0
    checkpoint_every: int = 0

    def _count(self, pred) -> int:
        return sum(1 for s in self.replicas
                   if s.fault is not None and pred(s.fault))

    @property
    def injected_kills(self) -> int:
        return self._count(lambda f: f.kill_at is not None)

    @property
    def injected_hangs(self) -> int:
        return self._count(lambda f: f.hang_at is not None
                           and f.kill_at is None)

    @property
    def injected_slows(self) -> int:
        return self._count(lambda f: f.slow_at is not None)

    @property
    def injected_transients(self) -> int:
        """Transient incidents scheduled to CLEAR: a transient on a
        replica that also dies (kill/hang) may never recover — only
        transient-bearing replicas with no fatal fault are counted as
        must-recover."""
        return self._count(lambda f: f.transient_at is not None
                           and f.kill_at is None and f.hang_at is None)

    @property
    def injected_torn(self) -> int:
        return self._count(lambda f: f.torn_shard_at is not None)


def run_chaos(schedule: ChaosSchedule,
              make_replica: Callable[[str, float, Optional[FaultPlan]],
                                     Replica],
              workload: Sequence[Tuple[np.ndarray, int, float]], *,
              miss_threshold: int = 3,
              retry: Optional[RetryPolicy] = None,
              min_alive: int = 1,
              checkpoint_dir=None, checkpoint_state: Any = None,
              virtual_k: int = 1024,
              tracer=None, metrics=None,
              max_ticks: int = 200_000
              ) -> Tuple[FleetController, FleetReport]:
    """Build the schedule's fleet, submit the workload, drive to drain.

    ``make_replica(name, rate, fault)`` supplies the engine flavor (the
    tests' FakeModel, the benchmarks' real transformer) so the harness
    stays model-agnostic.  Returns (controller, report); a schedule that
    cannot drain raises the controller's typed error (``FleetDegraded``,
    ``CorruptShard``) — loud, never a hang, bounded by ``max_ticks``."""
    reps = [make_replica(s.name, s.rate, s.fault)
            for s in schedule.replicas]
    ctrl = FleetController(
        reps, miss_threshold=miss_threshold, retry=retry,
        min_alive=min_alive,
        checkpoint_dir=checkpoint_dir if schedule.checkpoint_every else None,
        checkpoint_state=checkpoint_state if schedule.checkpoint_every
        else None,
        checkpoint_every=schedule.checkpoint_every,
        virtual_k=virtual_k, tracer=tracer, metrics=metrics)
    if schedule.join_at is not None:
        ctrl.schedule_join(
            make_replica(schedule.join_name, schedule.join_rate, None),
            at_tick=schedule.join_at)
    for prompt, max_new, arrival in workload:
        ctrl.submit(prompt, max_new, arrival=arrival)
    return ctrl, ctrl.run(max_ticks=max_ticks)


def chaos_verdicts(schedule: ChaosSchedule, report: FleetReport,
                   workload: Sequence[Tuple[np.ndarray, int, float]],
                   reference: Optional[Dict[int, np.ndarray]] = None
                   ) -> Dict[str, Any]:
    """Reduce a chaos run to its structural verdicts.

    ``reference`` maps fleet rid (submission order) -> expected greedy
    tokens; without it the token-identity verdict is skipped (None)."""
    n = len(workload)
    silent_drops = n - report.n_completed
    token_identical: Optional[bool] = None
    if reference is not None:
        token_identical = (
            set(report.completed) == set(reference)
            and all(np.array_equal(report.completed[r], reference[r])
                    for r in reference))
    rescales = len(report.kills) + len(report.joins)
    ckpt_on = schedule.checkpoint_every > 0
    return {
        "requests": n,
        "completed": report.n_completed,
        "silent_drops": silent_drops,
        "token_identical": token_identical,
        "ticks": report.ticks,
        "requeues": report.requeues,
        "kills": len(report.kills),
        "joins": len(report.joins),
        "retries": report.retries,
        "recoveries": report.recoveries,
        "restores": report.restores,
        "corrupt_shards": report.corrupt_shards,
        "transients_injected": schedule.injected_transients,
        "torn_injected": schedule.injected_torn,
        "gates": {
            # every scheduled-to-clear transient actually recovered
            # through retry/backoff (none escalated to a kill)
            "recovered_all_transients":
                report.recoveries == schedule.injected_transients,
            # every membership change restored the checkpointed state
            # onto its new plan (vacuously true with checkpointing off)
            "restores_match_rescales":
                (report.restores == rescales) if ckpt_on else True,
            "token_identical": bool(token_identical),
            "zero_silent_drops": silent_drops == 0,
        },
    }
