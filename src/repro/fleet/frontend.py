"""Async fleet front-end: submit / stream-tokens / await-drain.

The front-end owns the event loop; everything below it (controller,
replicas, engines) is synchronous and tick-driven.  Every await point
advances the fleet by whole controller ticks, so concurrency is
cooperative and DETERMINISTIC: the same submission script produces the
same tick-by-tick schedule on every run, with no wall clock anywhere —
the "injectable clock" is the controller's tick counter itself, and the
event loop is whatever ``asyncio`` loop the caller runs under (tests
inject their own via ``asyncio.Runner``/``asyncio.run``).

Backpressure: ``submit`` suspends (ticking the fleet) while the number
of unfinished requests is at or above ``max_pending`` — a producer that
outruns the fleet donates its waiting time to serving instead of
growing the queue without bound.

Streaming is exactly-once across rescale: ``stream`` keeps a ``sent``
cursor into the request's token prefix, and because a requeued request
regenerates an identical prefix (greedy oracle), the cursor never skips
or repeats a token even if the replica serving it is killed mid-stream.
"""

from __future__ import annotations

import asyncio
from typing import AsyncIterator, Dict, List, Sequence, Tuple

import numpy as np

from .controller import FleetController, FleetReport


class UnknownRequest(KeyError):
    """``stream`` asked for a rid the fleet never issued.  Without this,
    the streamer would tick the fleet forever waiting for tokens that
    can never arrive."""


class FleetClosed(RuntimeError):
    """``submit`` after ``drain``: the front-end has retired its fleet
    and no longer accepts work (a late producer would otherwise enqueue
    onto a controller nobody is draining)."""


class FleetFrontend:
    def __init__(self, controller: FleetController, *,
                 max_pending: int = 64):
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.controller = controller
        self.max_pending = int(max_pending)
        self._closed = False

    @property
    def depth(self) -> int:
        """Unfinished requests (the backpressure signal)."""
        return self.controller.depth

    async def _advance(self) -> None:
        """One controller tick + a cooperative yield, so concurrent
        submitters/streamers interleave at tick granularity."""
        self.controller.tick()
        await asyncio.sleep(0)

    async def submit(self, prompt, max_new: int,
                     arrival: float = 0.0) -> int:
        """Enqueue a request, suspending while the fleet is saturated.
        Raises ``FleetClosed`` once ``drain`` has completed."""
        if self._closed:
            raise FleetClosed(
                "submit after drain: this front-end's fleet has been "
                "drained and accepts no further requests")
        while self.depth >= self.max_pending:
            await self._advance()
        return self.controller.submit(prompt, max_new, arrival=arrival)

    async def stream(self, rid: int) -> AsyncIterator[int]:
        """Yield ``rid``'s tokens as they land on the host, exactly once
        each, driving the fleet forward while waiting.  Raises
        ``UnknownRequest`` for a rid the fleet never issued (streaming an
        unknown rid would otherwise tick forever)."""
        if rid not in self.controller.requests:
            raise UnknownRequest(
                f"rid {rid} was never issued by this fleet")
        sent = 0
        while True:
            toks = self.controller.tokens_so_far(rid)
            while sent < toks.shape[0]:
                yield int(toks[sent])
                sent += 1
            done = self.controller.results.get(rid)
            if done is not None and sent >= done.shape[0]:
                return
            await self._advance()

    async def drain(self) -> FleetReport:
        """Tick until every submitted request has completed, then close
        the front-end (later ``submit`` calls raise ``FleetClosed``)."""
        while self.controller.tick():
            await asyncio.sleep(0)
        self._closed = True
        return self.controller.report()

    # -- sync convenience ---------------------------------------------------
    def serve(self, workload: Sequence[Tuple[np.ndarray, int, float]],
              *, stream_rids: Sequence[int] = ()) -> FleetReport:
        """Submit a [(prompt, max_new, arrival), ...] trace with
        backpressure, drain, and return the report.  ``stream_rids``
        additionally consumes those requests through ``stream`` (tokens
        land in ``self.streamed``) to exercise the concurrent path."""
        self.streamed: Dict[int, List[int]] = {}

        async def consume(rid: int) -> None:
            async for tok in self.stream(rid):
                self.streamed.setdefault(rid, []).append(tok)

        async def produce() -> None:
            for prompt, max_new, arrival in workload:
                await self.submit(prompt, max_new, arrival=arrival)

        async def go() -> FleetReport:
            tasks = [asyncio.ensure_future(consume(r))
                     for r in stream_rids]
            await produce()
            report = await self.drain()
            for t in tasks:
                await t
            return report

        return asyncio.run(go())
