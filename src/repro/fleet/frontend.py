"""Async fleet front-end: submit / stream-tokens / await-drain.

The front-end owns the event loop; everything below it (controller,
replicas, engines) is synchronous and tick-driven.  Every await point
advances the fleet by whole controller ticks, so concurrency is
cooperative and DETERMINISTIC: the same submission script produces the
same tick-by-tick schedule on every run, with no wall clock anywhere —
the "injectable clock" is the controller's tick counter itself, and the
event loop is whatever ``asyncio`` loop the caller runs under (tests
inject their own via ``asyncio.Runner``/``asyncio.run``).

Backpressure: ``submit`` suspends (ticking the fleet) while the number
of unfinished requests is at or above ``max_pending`` — a producer that
outruns the fleet donates its waiting time to serving instead of
growing the queue without bound.

Streaming is exactly-once across rescale: ``stream`` keeps a ``sent``
cursor into the request's token prefix, and because a requeued request
regenerates an identical prefix (greedy oracle), the cursor never skips
or repeats a token even if the replica serving it is killed mid-stream.

Graceful degradation (the typed-failure contract): when the fleet's
alive capacity is below the controller's ``min_alive`` floor, ``submit``
rejects with ``FleetDegraded`` carrying a retry-after hint (ticks until
the next scheduled join) instead of queueing work nobody can serve;
``drain`` takes an optional tick ``deadline`` so a hung fleet can never
hang the caller; and a ``stream`` whose fleet closed (drain finished,
failed, or hit its deadline) with the request still incomplete raises
``FleetDegraded`` rather than awaiting tokens that can never arrive.
"""

from __future__ import annotations

import asyncio
from typing import AsyncIterator, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .controller import FleetController, FleetDegraded, FleetReport


class UnknownRequest(KeyError):
    """``stream`` asked for a rid the fleet never issued.  Without this,
    the streamer would tick the fleet forever waiting for tokens that
    can never arrive."""


class FleetClosed(RuntimeError):
    """``submit`` after ``drain``: the front-end has retired its fleet
    and no longer accepts work (a late producer would otherwise enqueue
    onto a controller nobody is draining)."""


class FleetFrontend:
    def __init__(self, controller: FleetController, *,
                 max_pending: int = 64):
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.controller = controller
        self.max_pending = int(max_pending)
        self._closed = False

    @property
    def depth(self) -> int:
        """Unfinished requests (the backpressure signal)."""
        return self.controller.depth

    async def _advance(self) -> None:
        """One controller tick + a cooperative yield, so concurrent
        submitters/streamers interleave at tick granularity."""
        self.controller.tick()
        await asyncio.sleep(0)

    def _reject_if_degraded(self) -> None:
        c = self.controller
        if not c.degraded:
            return
        ra = c.retry_after_hint()
        c.metrics.counter("degraded_rejections").inc()
        c.tracer.event("degraded_reject", track="controller", lane="health",
                       alive=len(c.alive_names()), floor=c.min_alive,
                       retry_after=ra)
        raise FleetDegraded(
            f"fleet degraded: {len(c.alive_names())} alive < floor "
            f"{c.min_alive}"
            + (f", capacity returns in ~{ra} ticks (scheduled join)"
               if ra is not None else ", no recovery scheduled"),
            retry_after=ra)

    async def submit(self, prompt, max_new: int,
                     arrival: float = 0.0) -> int:
        """Enqueue a request, suspending while the fleet is saturated.
        Raises ``FleetClosed`` once ``drain`` has completed, and
        ``FleetDegraded`` (with ``retry_after``) while alive capacity is
        below the controller's floor — a typed rejection the producer
        can retry, instead of work queueing onto a fleet that cannot
        serve it."""
        if self._closed:
            raise FleetClosed(
                "submit after drain: this front-end's fleet has been "
                "drained and accepts no further requests")
        self._reject_if_degraded()
        while self.depth >= self.max_pending:
            await self._advance()
            self._reject_if_degraded()
        return self.controller.submit(prompt, max_new, arrival=arrival)

    async def stream(self, rid: int) -> AsyncIterator[int]:
        """Yield ``rid``'s tokens as they land on the host, exactly once
        each, driving the fleet forward while waiting.  Raises
        ``UnknownRequest`` for a rid the fleet never issued (streaming an
        unknown rid would otherwise tick forever), and ``FleetDegraded``
        when the fleet closed — drain finished, failed, or timed out —
        with this request still incomplete: its tokens can never arrive,
        so the streamer terminates loudly instead of hanging."""
        if rid not in self.controller.requests:
            raise UnknownRequest(
                f"rid {rid} was never issued by this fleet")
        sent = 0
        while True:
            toks = self.controller.tokens_so_far(rid)
            while sent < toks.shape[0]:
                yield int(toks[sent])
                sent += 1
            done = self.controller.results.get(rid)
            if done is not None and sent >= done.shape[0]:
                return
            if self._closed:
                raise FleetDegraded(
                    f"stream({rid}): fleet closed with the request "
                    f"incomplete ({sent} tokens streamed) — its replica "
                    f"died or drain gave up, and no survivor will finish "
                    f"it", retry_after=None)
            await self._advance()

    async def drain(self, *, deadline: Optional[int] = None) -> FleetReport:
        """Tick until every submitted request has completed, then close
        the front-end (later ``submit`` calls raise ``FleetClosed``).

        ``deadline`` bounds the drain to that many ticks: a fleet that
        cannot finish (e.g. a hung replica below the heartbeat radar)
        raises ``FleetDegraded`` instead of hanging the caller forever.
        The front-end closes on EVERY exit path — success, deadline, or
        a controller failure mid-drain — so concurrent streamers observe
        the closure and terminate instead of awaiting dead tokens."""
        start = self.controller.tick_count
        try:
            while self.controller.tick():
                if (deadline is not None
                        and self.controller.tick_count - start >= deadline):
                    raise FleetDegraded(
                        f"drain deadline: {self.controller.depth} requests "
                        f"still unfinished after {deadline} ticks — the "
                        f"fleet is wedged, not slow", retry_after=None)
                await asyncio.sleep(0)
        finally:
            self._closed = True
        return self.controller.report()

    # -- sync convenience ---------------------------------------------------
    def serve(self, workload: Sequence[Tuple[np.ndarray, int, float]],
              *, stream_rids: Sequence[int] = (),
              deadline: Optional[int] = None) -> FleetReport:
        """Submit a [(prompt, max_new, arrival), ...] trace with
        backpressure, drain, and return the report.  ``stream_rids``
        additionally consumes those requests through ``stream`` (tokens
        land in ``self.streamed``) to exercise the concurrent path.
        ``deadline`` forwards to ``drain``; when it fires, the streamer
        tasks are cancelled before the typed error propagates."""
        self.streamed: Dict[int, List[int]] = {}

        async def consume(rid: int) -> None:
            async for tok in self.stream(rid):
                self.streamed.setdefault(rid, []).append(tok)

        async def produce() -> None:
            for prompt, max_new, arrival in workload:
                await self.submit(prompt, max_new, arrival=arrival)

        async def go() -> FleetReport:
            tasks = [asyncio.ensure_future(consume(r))
                     for r in stream_rids]
            await produce()
            try:
                report = await self.drain(deadline=deadline)
            except BaseException:
                for t in tasks:
                    t.cancel()
                await asyncio.gather(*tasks, return_exceptions=True)
                raise
            for t in tasks:
                await t
            return report

        return asyncio.run(go())
