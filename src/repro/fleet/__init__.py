"""Fleet runtime: N serving replicas behind one async service.

Three explicit layers (ROADMAP "Fleet runtime"):

  frontend.py    async submit / stream / drain with backpressure
  controller.py  routing (CapacityPlanner), health, rescale via
                 runtime.rebalance drop_devices/join_devices,
                 exactly-once requeue of a dead replica's work
  replica.py     one ServingEngine behind a narrow step-callable
                 surface, with heartbeat + fault injection

The fleet oracle invariant: under greedy decoding the fleet's tokens
are byte-identical to per-request ``greedy_generate`` for ANY kill/join
schedule, because each engine is oracle-identical and the controller
requeues (never double-harvests) a dead replica's outstanding work.
"""

from .controller import (FleetController, FleetReport,  # noqa: F401
                         FleetRequest)
from .frontend import (FleetClosed, FleetFrontend,  # noqa: F401
                       UnknownRequest)
from .replica import (FaultPlan, Replica, ReplicaDead,  # noqa: F401
                      build_engine)
