"""Fleet runtime: N serving replicas behind one async service.

Four explicit layers (ROADMAP "Fleet runtime"):

  frontend.py    async submit / stream / drain with backpressure and
                 typed graceful degradation (FleetDegraded + retry-after,
                 drain deadline, stream liveness)
  controller.py  routing (CapacityPlanner), health, rescale via
                 runtime.rebalance drop_devices/join_devices,
                 exactly-once requeue of a dead replica's work,
                 transient retry/backoff (RetryPolicy) and live
                 checkpoint-recovery through checkpoint.reshard
  replica.py     one ServingEngine behind a narrow step-callable
                 surface, with heartbeat + deterministic fault injection
                 (kill / hang / slow / transient / torn-shard)
  chaos.py       the deterministic chaos harness: composite fault
                 schedules + structural verdicts, shared by tests,
                 benchmarks and examples

The fleet oracle invariant: under greedy decoding the fleet's tokens
are byte-identical to per-request ``greedy_generate`` for ANY
recoverable fault schedule, because each engine is oracle-identical and
the controller requeues (never double-harvests) a dead replica's
outstanding work; unrecoverable schedules fail loudly with typed errors
(``FleetDegraded``, ``CorruptShard``), never by hanging or dropping.
"""

from .chaos import (ChaosReplicaSpec, ChaosSchedule,  # noqa: F401
                    chaos_verdicts, run_chaos)
from .controller import (FleetController, FleetDegraded,  # noqa: F401
                         FleetReport, FleetRequest, RetryPolicy)
from .frontend import (FleetClosed, FleetFrontend,  # noqa: F401
                       UnknownRequest)
from .replica import (FaultPlan, Replica, ReplicaDead,  # noqa: F401
                      TransientError, build_engine)
