"""Replica plane: a ``ServingEngine`` behind a narrow step-callable surface.

A replica is the fleet's unit of capacity.  It owns one engine (slot or
paged plane — the fleet does not care which), advances it one iteration
when the controller says so, and reports health through a heartbeat the
controller samples: a replica that stops beating for ``miss_threshold``
ticks is declared dead exactly like one whose step raised.

Fault injection lives here because rescale is THE correctness surface of
a fleet: ``FaultPlan.kill_at`` makes the step raise ``ReplicaDead`` (the
crash path), ``hang_at`` makes it go silent without raising (the
heartbeat-miss path), ``transient_at`` makes it raise ``TransientError``
for a bounded window (the retry/backoff path), ``slow_at`` contends it
(the drift-corrector path), and ``torn_shard_at`` corrupts its fleet
checkpoint shards (the shard-integrity path).  Every fault must leave
the fleet's token stream byte-identical to the no-fault run, which the
greedy oracle guarantees as long as the controller requeues everything
a dead replica still owed (``Replica.outstanding``) and never harvests
it again — and retries only steps that did no engine work.

``build_engine`` is the one sanctioned ``ServingEngine`` constructor
call site outside ``launch/``: CI grep-gates direct construction so
every serving surface acquires engines through the fleet plane.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..serve.engine import EngineConfig, ServingEngine
from ..serve.engine.request import Request


def build_engine(model, config: EngineConfig = EngineConfig(),
                 clock=None, tracer=None, metrics=None,
                 name: str = "engine") -> ServingEngine:
    """Factory for serving engines (slot or paged, per ``config``).

    ``tracer``/``metrics`` thread the observability plane through — the
    default (None) engine runs on a ``NullTracer`` and a private
    registry, so tracing is opt-in per engine."""
    return ServingEngine(model, config, clock=clock, tracer=tracer,
                         metrics=metrics, name=name)


class ReplicaDead(RuntimeError):
    """A replica's step crashed fatally (fault injection or a real
    failure).  The controller's only recovery is kill + requeue."""


class TransientError(RuntimeError):
    """A replica's step failed *recoverably* (injected transient, or a
    real blip: OOM-retry, preempted host, flaky interconnect).  The
    engine state is untouched — the step did no work — so the controller
    may retry the same replica after a backoff instead of killing it."""


@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault schedule, in *replica-local* step counts —
    tick-addressed so any composite schedule replays exactly.

    kill_at: the step raises ``ReplicaDead`` once this many steps ran.
    hang_at: the step silently stops (no heartbeat, no progress) — the
    controller must catch this via heartbeat-miss, not an exception.
    slow_at: from this step on, only every ``slow_factor``-th step does
    engine work (the others beat the heartbeat and return idle) — a
    CONTENDED replica: alive and healthy, at 1/slow_factor throughput.
    The drift corrector, not the health plane, must handle this one.
    transient_at: steps ``[transient_at, transient_at + transient_for)``
    raise ``TransientError`` without touching the engine, then the fault
    clears — the retry/backoff path's case.  Each retry attempt advances
    the local step clock, so ``transient_for`` is the number of FAILING
    attempts before the replica recovers.
    torn_shard_at: once this many local steps ran, every fleet
    checkpoint written while this replica is a member gets ITS shard
    payload torn (truncated mid-write) — the shard-integrity path's
    case: restore must detect the corruption (``CorruptShard``) and fall
    back to an older intact snapshot rather than load garbage.
    """

    kill_at: Optional[int] = None
    hang_at: Optional[int] = None
    slow_at: Optional[int] = None
    slow_factor: int = 2
    transient_at: Optional[int] = None
    transient_for: int = 1
    torn_shard_at: Optional[int] = None


class Replica:
    """One engine + identity + health, stepped by the fleet controller."""

    def __init__(self, name: str, model,
                 config: EngineConfig = EngineConfig(), *,
                 rate: float = 1.0, fault: Optional[FaultPlan] = None,
                 clock=None, tracer=None, metrics=None):
        if rate <= 0:
            raise ValueError(f"replica {name!r} needs a positive rate "
                             f"(tokens/sec the planner splits by), got "
                             f"{rate}")
        self.name = str(name)
        self.rate = float(rate)
        # the replica's engine shares the fleet tracer/metrics so its
        # spans land on a per-replica track in the one fleet trace
        self.engine = build_engine(model, config, clock=clock,
                                   tracer=tracer, metrics=metrics,
                                   name=f"replica:{self.name}")
        self.fault = fault if fault is not None else FaultPlan()
        self.alive = True
        self.last_heartbeat = 0   # controller tick of the last live step
        self.ticks = 0            # replica-local step count (fault clock)
        # active-slot ticks: the utilization denominator the corrector
        # divides decode tokens by.  tokens/slot_ticks is PER-SLOT
        # throughput — ~1 for a healthy replica at any batch occupancy,
        # 1/slow_factor for a contended one — so neither idle phases nor
        # ramp-up occupancy skew masquerade as slowness
        self.slot_ticks = 0

    # -- request surface -------------------------------------------------
    def submit(self, prompt, max_new: int) -> int:
        """Enqueue on the local engine (arrival 0: the fleet controller
        already applied arrival eligibility — replicas serve ASAP)."""
        return self.engine.submit(prompt, max_new, arrival=0.0)

    def load(self) -> int:
        """Requests this replica still owes (queued + in flight)."""
        return (len(self.engine.queue)
                + len(self.engine.scheduler.active))

    def queued(self) -> int:
        """Requests waiting un-admitted — the stealable backlog."""
        return len(self.engine.queue)

    # -- step surface ------------------------------------------------------
    def step(self, tick: int) -> bool:
        """One engine iteration under the fault plan.

        Beats the heartbeat on every live call — even an idle one (an
        idle replica is healthy, not dead).  Returns whether the engine
        had work.  Raises ``ReplicaDead`` on the crash fault.
        """
        if not self.alive:
            return False
        self.ticks += 1
        n_act = len(self.engine.scheduler.active)
        if (self.fault.kill_at is not None
                and self.ticks >= self.fault.kill_at):
            raise ReplicaDead(
                f"replica {self.name!r}: injected kill at local step "
                f"{self.ticks} (fleet tick {tick})")
        if (self.fault.hang_at is not None
                and self.ticks >= self.fault.hang_at):
            return False          # silent: no heartbeat, no progress
        if (self.fault.transient_at is not None
                and self.fault.transient_at <= self.ticks
                < self.fault.transient_at + max(1, self.fault.transient_for)):
            # recoverable: the engine did no work, so a later retry of
            # this same step is safe.  No heartbeat here — liveness
            # during the incident is the CONTROLLER's call (it stamps
            # the heartbeat when it classifies the failure as transient)
            raise TransientError(
                f"replica {self.name!r}: injected transient at local "
                f"step {self.ticks} (fleet tick {tick}, clears at step "
                f"{self.fault.transient_at + max(1, self.fault.transient_for)})")
        if (self.fault.slow_at is not None
                and self.ticks >= self.fault.slow_at
                and self.ticks % max(2, self.fault.slow_factor) != 0):
            # a contended step holds its slots without producing — that
            # IS the utilization signal the drift corrector keys on
            if self.load() > 0:
                self.slot_ticks += max(1, n_act)
            self.last_heartbeat = tick   # contended, not dead
            return False
        worked = self.engine.step()
        if self.load() > 0 or worked:
            self.slot_ticks += max(1, n_act,
                                   len(self.engine.scheduler.active))
        self.last_heartbeat = tick
        return worked

    # -- drain / failover surface ----------------------------------------
    def harvest(self) -> Dict[int, np.ndarray]:
        """Newly completed local requests (local rid -> tokens)."""
        return self.engine.harvest()

    def tokens_so_far(self, local_rid: int) -> np.ndarray:
        return self.engine.tokens_so_far(local_rid)

    def outstanding(self) -> List[Request]:
        """What this replica still owes: everything not harvested."""
        return self.engine.outstanding()

    def shed(self, n: int) -> List[Request]:
        """Give up ``n`` queued (never in-flight) requests, latest-arrival
        first — the work-stealing path.  Shed requests were never
        admitted, so zero tokens were generated for them and the greedy
        oracle survives their requeue on another replica."""
        return self.engine.shed_queued(n)

    def progress(self) -> Dict[str, float]:
        return self.engine.progress()

    def __repr__(self) -> str:   # pragma: no cover - debugging aid
        return (f"Replica({self.name!r}, rate={self.rate}, "
                f"alive={self.alive}, load={self.load()})")
