"""Fleet controller: route, step, harvest, heal — N replicas as one service.

The controller is the master of the paper's master-worker shape
(Dongarra et al.): requests are the divisible load, replicas the
heterogeneous workers, and BOTH scheduling brains route through the
same §4 solvers —

  * request routing: ``CapacityPlanner.plan()`` over the live replicas'
    measured rates, interleaved by ``route()`` (smooth weighted
    round-robin), re-planned on every kill/join;
  * the fleet's layer split: a ``runtime.rebalance`` ``LayerAssignment``
    over a virtual contraction dimension, re-solved live through
    ``drop_devices`` / ``join_devices`` on every membership change, so a
    co-hosted LBP matmul always knows each survivor's share.

Exactly-once tokens under rescale (the fleet oracle invariant):

  * a fleet request's tokens are recorded at most once, keyed by its
    fleet rid, from the FIRST harvest that completes it;
  * a dead replica is never harvested again — everything it still owed
    (``Replica.outstanding``: queued, in flight, completed-but-
    unharvested) is requeued under the same fleet rid and regenerated
    from scratch on a survivor;
  * greedy decoding is deterministic and batching-invariant (the
    single-engine oracle property), so the regenerated tokens are
    byte-identical to what the dead replica would have produced — the
    stream loses nothing and duplicates nothing, under ANY kill/join
    schedule.

Time is the controller's tick counter (injectable by construction: the
async front-end advances it explicitly, tests drive it directly), never
the wall clock.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs.drift import DriftMonitor
from ..obs.metrics import MetricsRegistry
from ..obs.trace import NullTracer
from ..runtime.correct import CorrectionPolicy, WorkStealingCorrector
from ..runtime.rebalance import (RebalancePlan, drop_devices, join_devices,
                                 plan_rebalance)
from ..serve.engine.planner import CapacityPlanner
from .replica import Replica, ReplicaDead


@dataclasses.dataclass
class FleetRequest:
    """A request as the fleet sees it: fleet-level identity + placement."""

    rid: int
    prompt: np.ndarray
    max_new: int
    arrival: float = 0.0          # fleet ticks
    replica: Optional[str] = None
    local_rid: Optional[int] = None
    n_requeues: int = 0


@dataclasses.dataclass
class FleetReport:
    completed: Dict[int, np.ndarray]     # fleet rid -> tokens
    ticks: int
    requeues: int
    kills: List[Tuple[int, str]]         # (tick, replica name)
    joins: List[Tuple[int, str]]
    occupancy: Dict[str, float]          # per-replica mean decode occupancy
    decode_tokens: Dict[str, int]
    events: List[str]
    steals: int = 0                      # drift-triggered work steals

    @property
    def n_completed(self) -> int:
        return len(self.completed)


class FleetController:
    def __init__(self, replicas: Sequence[Replica], *,
                 miss_threshold: int = 3, route_window: int = 16,
                 virtual_k: int = 1024, mode: str = "PCCS",
                 steal: bool = False,
                 steal_policy: Optional[CorrectionPolicy] = None,
                 tracer=None, metrics=None):
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"replica names must be unique, got {names}")
        if not replicas:
            raise ValueError("a fleet needs at least one replica")
        self.replicas: Dict[str, Replica] = {r.name: r for r in replicas}
        self.miss_threshold = int(miss_threshold)
        self.route_window = int(route_window)
        self.mode = mode
        self.tick_count = 0
        # observability plane.  The controller is the outermost timeline
        # owner: it overrides whatever clock the replica engines adopted
        # so the whole fleet renders on ONE tick axis.
        self.tracer = tracer if tracer is not None else NullTracer()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer.use_clock(lambda: float(self.tick_count))
        # request bookkeeping
        self.requests: Dict[int, FleetRequest] = {}
        self.results: Dict[int, np.ndarray] = {}
        self._next_rid = 0
        self._unassigned: List[FleetRequest] = []
        self._owner: Dict[Tuple[str, int], int] = {}  # (name, local) -> rid
        # rescale bookkeeping
        self.requeues = 0
        # dynamic correction (runtime.correct): drift-tripped replicas
        # shed queued work through the exactly-once requeue path
        self.steal = bool(steal)
        self.steal_policy = steal_policy
        self.steals = 0
        self._corrector: Optional[WorkStealingCorrector] = None
        self.kills: List[Tuple[int, str]] = []
        self.joins: List[Tuple[int, str]] = []
        self.events: List[str] = []
        self._kill_schedule: List[Tuple[int, str]] = []
        self._join_schedule: List[Tuple[int, Replica]] = []
        # live layer split over a virtual contraction dim: re-solved
        # through runtime.rebalance on every membership change
        self._rb_names: List[str] = list(names)
        self.rebalance: RebalancePlan = plan_rebalance(
            int(virtual_k), [r.rate for r in replicas], quantum=1,
            mode="PCSS")
        self._route_seq: List[str] = []
        self._route_pos = 0
        self._replan()

    # -- membership ------------------------------------------------------
    def alive_names(self) -> List[str]:
        return [n for n in self._rb_names if self.replicas[n].alive]

    def schedule_kill(self, name: str, at_tick: int) -> None:
        """Declare ``name`` dead at ``at_tick`` (operator-initiated drain
        — the crash path is ``FaultPlan`` on the replica itself)."""
        if name not in self.replicas:
            raise KeyError(f"unknown replica {name!r}")
        self._kill_schedule.append((int(at_tick), name))

    def schedule_join(self, replica: Replica, at_tick: int) -> None:
        if replica.name in self.replicas:
            raise ValueError(f"replica {replica.name!r} already exists")
        self._join_schedule.append((int(at_tick), replica))

    def _replan(self, rates: Optional[Dict[str, float]] = None) -> None:
        """Rebuild the routing sequence from the live replicas' rates via
        the capacity planner (the §4 equal-finish split + smooth WRR).

        ``rates`` overrides the nominal per-replica rates (the steal path
        passes observation-smoothed rates so routing follows the measured
        platform, not the stale catalogue numbers).  The ``fleet_drift``
        gauge resets with the plan: drift is plan-relative, so a stale
        pre-replan value must never outlive the plan it scored.
        """
        # the gauge baseline resets with the plan on EVERY replan path
        # (corrector, kill, join) — first post-replan observation scores
        # against the fresh plan, not the old one's residue
        self.metrics.gauge("fleet_drift").set(0.0)
        alive = self.alive_names()
        if not alive:
            self._route_seq, self._route_pos = [], 0
            self._drift, self._drift_names = None, []
            self._corrector = None
            return
        rate_of = rates if rates is not None else {}
        planner = CapacityPlanner(
            rates=[rate_of.get(n, self.replicas[n].rate) for n in alive],
            mode=self.mode, quantum=1)
        plan = planner.plan(max(self.route_window, len(alive)))
        self._route_seq = [alive[i] for i in planner.route(plan)]
        self._route_pos = 0
        # plan-vs-actual: score decode tokens served SINCE this plan
        # against the plan's share fractions (obs.drift); the gauge is
        # the runtime.rebalance re-plan trigger signal
        self._drift = DriftMonitor(plan.partition, metrics=self.metrics,
                                   gauge_name="fleet_drift")
        self._drift_names = list(alive)
        self._drift_base = {
            n: self.replicas[n].progress()["decode_tokens"] for n in alive}
        self._drift_lbase = {
            n: self.replicas[n].slot_ticks for n in alive}
        self._drift_tick = self.tick_count
        # dynamic correction: a serve-plane corrector seeded on THIS plan.
        # The steal budget is fleet-lifetime, not plan-lifetime — each
        # fresh corrector gets only what the fleet has not yet spent, so
        # the correction count is bounded across any replan sequence.
        self._corrector = None
        if self.steal and len(alive) > 1:
            pol = self.steal_policy if self.steal_policy is not None else \
                CorrectionPolicy(hysteresis=1.5, cooldown=2,
                                 max_corrections=8, persistence=2,
                                 min_window=32.0 * len(alive))
            pol = dataclasses.replace(
                pol, max_corrections=max(0, pol.max_corrections - self.steals))
            self._corrector = WorkStealingCorrector(
                plan.partition, plane="serve", policy=pol,
                metrics=self.metrics, tracer=self.tracer,
                gauge_name="fleet_drift")
        self.tracer.event("replan", track="controller", lane="routing",
                          alive=alive)
        self.metrics.counter("replans").inc()

    def _kill(self, name: str, reason: str) -> None:
        rep = self.replicas[name]
        if not rep.alive:
            return
        rep.alive = False
        # requeue everything the dead replica still owed, under the SAME
        # fleet rid — it is never harvested again, so tokens recorded so
        # far plus the survivor's regeneration are exactly-once
        lost = rep.outstanding()
        self.tracer.event("kill", track="controller", lane="membership",
                          replica=name, reason=reason, lost=len(lost))
        for r in lost:
            # the dead engine's open spans for this request will never be
            # closed by the engine itself — close them here so the trace
            # shows the residency ending at the kill tick
            self.tracer.end(("qw", rep.engine.name, r.rid))
            self.tracer.end(("req", rep.engine.name, r.rid),
                            outcome="killed")
            rid = self._owner.pop((name, r.rid), None)
            if rid is None or rid in self.results:
                continue
            fr = self.requests[rid]
            fr.replica, fr.local_rid = None, None
            fr.n_requeues += 1
            self._unassigned.append(fr)
            self.requeues += 1
            self.metrics.counter("requeues").inc()
            self.tracer.event("requeue", track="controller",
                              lane="membership", rid=rid, replica=name)
        self.kills.append((self.tick_count, name))
        self.events.append(
            f"tick {self.tick_count}: kill {name} ({reason}), requeued "
            f"{len(lost)}")
        # shrink the live layer split through runtime.rebalance
        idx = self._rb_names.index(name)
        speeds = [self.replicas[n].rate for n in self._rb_names]
        if len(self._rb_names) > 1:
            self.rebalance = drop_devices(
                self.rebalance.assignment, [idx], speeds, quantum=1,
                mode="PCSS")
        self._rb_names.pop(idx)
        self._replan()

    def _join(self, replica: Replica) -> None:
        self.replicas[replica.name] = replica
        replica.alive = True
        replica.last_heartbeat = self.tick_count
        # grow the live layer split through runtime.rebalance
        speeds = [self.replicas[n].rate for n in self._rb_names]
        if self._rb_names:
            self.rebalance = join_devices(
                self.rebalance.assignment, [replica.rate], speeds,
                quantum=1, mode="PCSS")
        else:
            self.rebalance = plan_rebalance(
                self.rebalance.assignment.K, [replica.rate], quantum=1,
                mode="PCSS")
        self._rb_names.append(replica.name)
        self.joins.append((self.tick_count, replica.name))
        self.events.append(f"tick {self.tick_count}: join {replica.name}")
        self.tracer.event("join", track="controller", lane="membership",
                          replica=replica.name)
        self._replan()

    # -- dynamic correction ------------------------------------------------
    def _effective_rates(self, work: Sequence[float]) -> List[float]:
        """Utilization-normalized work vector for the corrector: tokens
        per ACTIVE-SLOT tick (per-slot throughput — ~1 healthy at any
        occupancy, 1/slow_factor contended), re-scaled so the vector's
        total equals the window's token mass (the policy's ``min_window``
        is a token mass).  A replica with no slot tick in the window has
        no measurement — it is pinned to its planned fraction (neutral:
        contributes zero drift, the ``measure_speeds`` median trick)."""
        names = self._drift_names
        rates: List[Optional[float]] = []
        for n, dt in zip(names, work):
            st = self.replicas[n].slot_ticks - self._drift_lbase[n]
            # a loaded-but-silent replica is measured as (nearly)
            # stalled, not unmeasured — floor at half a token so the
            # corrector can rank it instead of dividing by zero
            rates.append(max(float(dt), 0.5) / st if st > 0 else None)
        frac = self._corrector.plan.k / max(float(self._corrector.plan.load),
                                            1.0)
        s_m = sum(r for r in rates if r is not None)
        f_m = sum(f for r, f in zip(rates, frac) if r is not None)
        if f_m <= 0 or s_m <= 0:
            return [float(f) for f in frac]   # nothing measured: on-plan
        full = [r if r is not None else float(f) * s_m / f_m
                for r, f in zip(rates, frac)]
        scale = max(float(sum(work)), 0.0) / sum(full)
        return [r * scale for r in full]

    def _apply_steal(self, ev, work: Sequence[float]) -> None:
        """Apply one corrector event: the straggler sheds queued (never
        in-flight) requests into the exactly-once requeue path, then the
        router is re-planned on observation-smoothed rates so new work
        stops piling onto the contended replica.  Shed requests were
        never admitted — zero tokens generated — so the greedy fleet
        oracle survives their regeneration elsewhere, exactly like the
        kill path's requeues."""
        names = self._drift_names
        src, dst = names[ev.src], names[ev.dst]
        # steal-half: the corrector's event grants ONE correction; the
        # controller sheds half the straggler's queued backlog (the
        # classic work-stealing amount — enough to matter, never the
        # FIFO head, bounded by what is actually queued)
        n_shed = max(ev.amount, (self.replicas[src].queued() + 1) // 2)
        shed = self.replicas[src].shed(n_shed)
        if not shed:
            # the straggler had nothing queued to give up — the trip is
            # recorded by the corrector but no steal is applied
            self.events.append(
                f"tick {self.tick_count}: steal {src}->{dst} suppressed "
                f"(no queued backlog)")
            return
        self.steals += 1
        for r in shed:
            rid = self._owner.pop((src, r.rid), None)
            if rid is None or rid in self.results:
                continue
            # same exactly-once bookkeeping as the kill path's requeue —
            # but placed straight onto the corrector's absorber replica,
            # not back through the router (which would hand a share of
            # them straight back to the straggler)
            fr = self.requests[rid]
            fr.replica = dst
            fr.local_rid = self.replicas[dst].submit(fr.prompt, fr.max_new)
            fr.n_requeues += 1
            self._owner[(dst, fr.local_rid)] = rid
            self.requeues += 1
            self.metrics.counter("requeues").inc()
            self.tracer.event("shed", track="controller", lane="correction",
                              rid=rid, src=src, dst=dst)
        self.events.append(
            f"tick {self.tick_count}: steal {src}->{dst} "
            f"(drift {ev.drift:.3f}), shed {len(shed)}")
        # smoothed observed rates: same total capacity as the catalogue,
        # split the way the fleet actually served — half-weight blended
        # so one noisy window cannot whipsaw the router
        nominal = np.array([self.replicas[n].rate for n in names],
                           dtype=np.float64)
        w = np.asarray(work, dtype=np.float64)
        observed = w / w.sum() * nominal.sum()
        blended = 0.5 * nominal + 0.5 * observed
        self._replan(rates=dict(zip(names, blended)))

    # -- request surface ---------------------------------------------------
    def submit(self, prompt, max_new: int, arrival: float = 0.0) -> int:
        fr = FleetRequest(
            rid=self._next_rid,
            prompt=np.asarray(prompt, np.int32).reshape(-1),
            max_new=int(max_new), arrival=float(arrival))
        self._next_rid += 1
        self.requests[fr.rid] = fr
        self._unassigned.append(fr)
        return fr.rid

    @property
    def depth(self) -> int:
        """Unfinished requests fleet-wide (the backpressure signal)."""
        return len(self.requests) - len(self.results)

    @property
    def has_work(self) -> bool:
        return self.depth > 0

    def tokens_so_far(self, rid: int) -> np.ndarray:
        """Host view of a fleet request's tokens (streaming surface).
        Harvested results are final; in-flight requests read through to
        their replica; unassigned/requeued requests are empty."""
        if rid in self.results:
            return self.results[rid]
        fr = self.requests.get(rid)
        if fr is None or fr.replica is None:
            return np.zeros(0, np.int32)
        rep = self.replicas[fr.replica]
        if not rep.alive:
            return np.zeros(0, np.int32)
        return rep.tokens_so_far(fr.local_rid)

    def _next_replica(self) -> Optional[str]:
        for _ in range(len(self._route_seq)):
            name = self._route_seq[self._route_pos
                                   % len(self._route_seq)]
            self._route_pos += 1
            if self.replicas[name].alive:
                return name
        return None

    def _dispatch(self) -> None:
        """Assign every arrived, unplaced request to the next replica in
        the planner's routing sequence (FIFO among eligible)."""
        if not self._unassigned:
            return
        self._unassigned.sort(key=lambda fr: (fr.arrival, fr.rid))
        rest: List[FleetRequest] = []
        for fr in self._unassigned:
            name = (self._next_replica()
                    if fr.arrival <= self.tick_count else None)
            if name is None:
                rest.append(fr)
                continue
            fr.replica = name
            fr.local_rid = self.replicas[name].submit(fr.prompt, fr.max_new)
            self._owner[(name, fr.local_rid)] = fr.rid
            self.tracer.event("route", track="controller", lane="routing",
                              rid=fr.rid, replica=name,
                              requeues=fr.n_requeues)
        self._unassigned = rest

    # -- the fleet iteration ------------------------------------------------
    def tick(self) -> bool:
        """One fleet iteration: apply scheduled rescale events, dispatch
        arrivals, step every live replica once, harvest completions,
        health-check heartbeats.  Returns True while work remains."""
        t = self.tick_count
        for at, name in [e for e in self._kill_schedule if e[0] <= t]:
            self._kill_schedule.remove((at, name))
            self._kill(name, reason="scheduled")
        for at, rep in [e for e in self._join_schedule if e[0] <= t]:
            self._join_schedule.remove((at, rep))
            self._join(rep)
        self._dispatch()
        for name in list(self.replicas):
            rep = self.replicas[name]
            if not rep.alive:
                continue
            try:
                rep.step(t)
            except ReplicaDead as e:
                self._kill(name, reason=str(e))
                continue
            for local_rid, toks in rep.harvest().items():
                rid = self._owner.get((name, local_rid))
                if rid is not None and rid not in self.results:
                    self.results[rid] = toks
        for name, rep in self.replicas.items():
            if (rep.alive
                    and t - rep.last_heartbeat > self.miss_threshold):
                self.metrics.counter("heartbeat_misses").inc()
                self._kill(name, reason="heartbeat-miss")
        # plan-vs-actual: decode tokens served since the current plan,
        # scored against its share fractions (skipped when a membership
        # change mid-tick already rebuilt the monitor).  With stealing
        # on, the corrector's monitor IS the fleet_drift publisher — and
        # a tripped observation sheds work off the straggler.
        if (self._drift is not None
                and all(self.replicas[n].alive for n in self._drift_names)):
            work = [self.replicas[n].progress()["decode_tokens"]
                    - self._drift_base[n] for n in self._drift_names]
            if sum(work) > 0:
                reps = [self.replicas[n] for n in self._drift_names]
                if (self._corrector is not None
                        and any(r.queued() > 0 for r in reps)):
                    # corrector observations are gated on the existence
                    # of QUEUED (stealable) backlog — without one there
                    # is neither congestion nor anything to shed.  The
                    # work vector is utilization-normalized (tokens per
                    # LOADED tick) so an idle-for-lack-of-work replica
                    # keeps its measured speed instead of looking slow.
                    rates = self._effective_rates(work)
                    ev = self._corrector.observe(rates)
                    if ev is not None:
                        self._apply_steal(ev, rates)
                else:
                    self._drift.observe_shares(work)
        self.metrics.gauge("fleet_depth").set(self.depth)
        self.tracer.counter("fleet_depth", self.depth, track="controller")
        self.tick_count += 1
        if self.has_work and not self.alive_names() \
                and not self._join_schedule:
            raise RuntimeError(
                f"fleet has {self.depth} unfinished requests but no live "
                f"replica and no scheduled join — the work cannot drain")
        return self.has_work or bool(self._join_schedule
                                     or self._kill_schedule)

    def run(self, max_ticks: int = 1_000_000) -> FleetReport:
        """Drive ticks until drained; returns the fleet report."""
        while self.tick():
            if self.tick_count >= max_ticks:
                raise RuntimeError(
                    f"fleet did not drain in {max_ticks} ticks "
                    f"(depth={self.depth})")
        return self.report()

    def report(self) -> FleetReport:
        occ = {n: r.progress()["occupancy"]
               for n, r in self.replicas.items()}
        dec = {n: int(r.progress()["decode_tokens"])
               for n, r in self.replicas.items()}
        return FleetReport(
            completed=dict(self.results), ticks=self.tick_count,
            requeues=self.requeues, kills=list(self.kills),
            joins=list(self.joins), occupancy=occ, decode_tokens=dec,
            events=list(self.events), steals=self.steals)
