"""Fleet controller: route, step, harvest, heal — N replicas as one service.

The controller is the master of the paper's master-worker shape
(Dongarra et al.): requests are the divisible load, replicas the
heterogeneous workers, and BOTH scheduling brains route through the
same §4 solvers —

  * request routing: ``CapacityPlanner.plan()`` over the live replicas'
    measured rates, interleaved by ``route()`` (smooth weighted
    round-robin), re-planned on every kill/join;
  * the fleet's layer split: a ``runtime.rebalance`` ``LayerAssignment``
    over a virtual contraction dimension, re-solved live through
    ``drop_devices`` / ``join_devices`` on every membership change, so a
    co-hosted LBP matmul always knows each survivor's share.

Exactly-once tokens under rescale (the fleet oracle invariant):

  * a fleet request's tokens are recorded at most once, keyed by its
    fleet rid, from the FIRST harvest that completes it;
  * a dead replica is never harvested again — everything it still owed
    (``Replica.outstanding``: queued, in flight, completed-but-
    unharvested) is requeued under the same fleet rid and regenerated
    from scratch on a survivor;
  * greedy decoding is deterministic and batching-invariant (the
    single-engine oracle property), so the regenerated tokens are
    byte-identical to what the dead replica would have produced — the
    stream loses nothing and duplicates nothing, under ANY kill/join
    schedule.

Time is the controller's tick counter (injectable by construction: the
async front-end advances it explicitly, tests drive it directly), never
the wall clock.

Fault domains: step failures are CLASSIFIED, not uniformly fatal —
``TransientError`` retries on the same replica with capped exponential
backoff on the tick clock, and only exhausting the ``RetryPolicy``
budget escalates to the kill + exactly-once-requeue path that
``ReplicaDead`` and heartbeat-miss take immediately.  When checkpointing
is configured, every membership change additionally restores the
co-hosted LBP state from the newest INTACT resharding snapshot,
re-sliced onto the new plan (``CorruptShard`` snapshots are skipped; the
typed error escapes only when no epoch survives).  A fleet below its
``min_alive`` floor reports ``degraded`` — the frontend's signal to
reject new work with a typed ``FleetDegraded`` + retry-after instead of
queueing unboundedly.
"""

from __future__ import annotations

import dataclasses
import pathlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..checkpoint.reshard import (CorruptShard, restore_resharded,
                                  save_sharded)
from ..obs.drift import DriftMonitor
from ..obs.metrics import MetricsRegistry
from ..obs.trace import NullTracer
from ..runtime.correct import CorrectionPolicy, WorkStealingCorrector
from ..runtime.rebalance import (RebalancePlan, drop_devices, join_devices,
                                 plan_rebalance)
from ..serve.engine.planner import CapacityPlanner
from .replica import Replica, ReplicaDead, TransientError


class FleetDegraded(RuntimeError):
    """The fleet is below its alive-capacity floor (or has lost every
    replica with work outstanding).  ``retry_after`` is the tick delta
    until the next scheduled join — the caller's hint for when capacity
    returns — or None when no recovery is scheduled.  A typed rejection
    instead of unbounded queueing / an unbounded hang: the graceful-
    degradation contract."""

    def __init__(self, message: str, *, retry_after: Optional[int] = None):
        super().__init__(message)
        self.retry_after = retry_after


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff for TRANSIENT step failures, entirely
    on the tick clock (zero wall-clock reads, so retry schedules replay
    deterministically).  The n-th consecutive failure of a replica backs
    it off ``min(backoff_cap, backoff_base * 2**(n-1))`` ticks; a
    successful step resets the incident.  Once a single incident exceeds
    ``max_retries`` failures, the controller escalates to the fatal
    path: kill + exactly-once requeue, same as a crash."""

    max_retries: int = 3
    backoff_base: int = 1
    backoff_cap: int = 8

    def backoff(self, attempt: int) -> int:
        """Ticks to wait after the ``attempt``-th failure (1-based)."""
        return min(int(self.backoff_cap),
                   int(self.backoff_base) << max(0, attempt - 1))


@dataclasses.dataclass
class FleetRequest:
    """A request as the fleet sees it: fleet-level identity + placement."""

    rid: int
    prompt: np.ndarray
    max_new: int
    arrival: float = 0.0          # fleet ticks
    replica: Optional[str] = None
    local_rid: Optional[int] = None
    n_requeues: int = 0


@dataclasses.dataclass
class FleetReport:
    completed: Dict[int, np.ndarray]     # fleet rid -> tokens
    ticks: int
    requeues: int
    kills: List[Tuple[int, str]]         # (tick, replica name)
    joins: List[Tuple[int, str]]
    occupancy: Dict[str, float]          # per-replica mean decode occupancy
    decode_tokens: Dict[str, int]
    events: List[str]
    steals: int = 0                      # drift-triggered work steals
    retries: int = 0                     # transient failures retried
    recoveries: int = 0                  # transient incidents that cleared
    restores: int = 0                    # checkpoint restores on rescale
    corrupt_shards: int = 0              # torn snapshots skipped on restore

    @property
    def n_completed(self) -> int:
        return len(self.completed)


class FleetController:
    def __init__(self, replicas: Sequence[Replica], *,
                 miss_threshold: int = 3, route_window: int = 16,
                 virtual_k: int = 1024, mode: str = "PCCS",
                 steal: bool = False,
                 steal_policy: Optional[CorrectionPolicy] = None,
                 retry: Optional[RetryPolicy] = None,
                 min_alive: int = 1,
                 checkpoint_dir=None, checkpoint_state: Any = None,
                 checkpoint_every: int = 0,
                 tracer=None, metrics=None):
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"replica names must be unique, got {names}")
        if not replicas:
            raise ValueError("a fleet needs at least one replica")
        self.replicas: Dict[str, Replica] = {r.name: r for r in replicas}
        self.miss_threshold = int(miss_threshold)
        self.route_window = int(route_window)
        self.mode = mode
        self.tick_count = 0
        # observability plane.  The controller is the outermost timeline
        # owner: it overrides whatever clock the replica engines adopted
        # so the whole fleet renders on ONE tick axis.
        self.tracer = tracer if tracer is not None else NullTracer()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer.use_clock(lambda: float(self.tick_count))
        # request bookkeeping
        self.requests: Dict[int, FleetRequest] = {}
        self.results: Dict[int, np.ndarray] = {}
        self._next_rid = 0
        self._unassigned: List[FleetRequest] = []
        self._owner: Dict[Tuple[str, int], int] = {}  # (name, local) -> rid
        # rescale bookkeeping
        self.requeues = 0
        # retry/backoff plane: transient step failures back their
        # replica off on the TICK clock; exhausting the budget escalates
        # to the fatal kill + exactly-once-requeue path
        self.retry = retry if retry is not None else RetryPolicy()
        self.retries = 0
        self.recoveries = 0
        self._retry_attempts: Dict[str, int] = {}   # current incident
        self._retry_not_before: Dict[str, int] = {}  # name -> earliest tick
        # graceful degradation: below this many alive replicas the
        # frontend rejects new work with a typed FleetDegraded
        self.min_alive = max(1, int(min_alive))
        # dynamic correction (runtime.correct): drift-tripped replicas
        # shed queued work through the exactly-once requeue path
        self.steal = bool(steal)
        self.steal_policy = steal_policy
        self.steals = 0
        self._corrector: Optional[WorkStealingCorrector] = None
        self.kills: List[Tuple[int, str]] = []
        self.joins: List[Tuple[int, str]] = []
        self.events: List[str] = []
        self._kill_schedule: List[Tuple[int, str]] = []
        self._join_schedule: List[Tuple[int, Replica]] = []
        # live layer split over a virtual contraction dim: re-solved
        # through runtime.rebalance on every membership change
        self._rb_names: List[str] = list(names)
        self.rebalance: RebalancePlan = plan_rebalance(
            int(virtual_k), [r.rate for r in replicas], quantum=1,
            mode="PCSS")
        self._route_seq: List[str] = []
        self._route_pos = 0
        # live checkpoint-recovery: periodic resharding snapshots of the
        # co-hosted state (the LBP params the rebalance plan splits),
        # restored re-sliced onto every new membership's plan
        self.checkpoint_dir = (pathlib.Path(checkpoint_dir)
                               if checkpoint_dir is not None else None)
        self._ckpt_state = checkpoint_state
        self.checkpoint_every = int(checkpoint_every)
        self._ckpt_steps: List[int] = []
        self.restores = 0
        self.corrupt_shards = 0
        self.shards: Optional[List[Any]] = None  # per-member restored views
        self._replan()
        if self._ckpt_enabled:
            self._save_checkpoint()   # the epoch-0 snapshot: a kill at
            # ANY tick has something intact to restore from

    # -- membership ------------------------------------------------------
    def alive_names(self) -> List[str]:
        return [n for n in self._rb_names if self.replicas[n].alive]

    @property
    def degraded(self) -> bool:
        """Alive capacity below the configured floor — the frontend's
        typed-rejection signal (and the state a scheduled join exits)."""
        return len(self.alive_names()) < self.min_alive

    def retry_after_hint(self) -> Optional[int]:
        """Ticks until the next scheduled join restores capacity, or
        None when no recovery is scheduled — the degraded rejection's
        retry-after."""
        if not self._join_schedule:
            return None
        nxt = min(at for at, _ in self._join_schedule)
        return max(1, nxt - self.tick_count)

    # -- checkpoint-recovery plane ----------------------------------------
    @property
    def _ckpt_enabled(self) -> bool:
        return (self.checkpoint_dir is not None
                and self._ckpt_state is not None
                and self._rb_names != [])

    def _save_checkpoint(self) -> None:
        """One resharding snapshot of the co-hosted state under the
        CURRENT rebalance plan (one shard per member replica), then the
        torn-shard fault injection: a member whose ``FaultPlan`` marks
        torn shards gets its payload of THIS snapshot truncated — the
        deterministic stand-in for a mid-write crash."""
        step = self.tick_count
        plan = self.rebalance.plan
        d = save_sharded(self.checkpoint_dir, step, self._ckpt_state, plan)
        if step not in self._ckpt_steps:
            self._ckpt_steps.append(step)
        for i, name in enumerate(self._rb_names):
            f = self.replicas[name].fault
            if (f.torn_shard_at is not None
                    and self.replicas[name].ticks >= f.torn_shard_at):
                for fn in sorted(d.glob(f"*__shard{i:03d}.npy")):
                    data = fn.read_bytes()
                    fn.write_bytes(data[:max(1, len(data) // 2)])
        self.tracer.event("checkpoint", track="controller", lane="recovery",
                          step=step, members=list(self._rb_names))
        self.metrics.counter("checkpoints").inc()

    def _restore_on_rescale(self, cause: str) -> None:
        """The live-recovery path: after a membership change re-solved
        the rebalance plan, re-slice the checkpointed state onto the new
        members.  Scans snapshots newest-first; a torn/corrupt one is
        counted, traced, and skipped (fall back to the previous intact
        epoch).  Only when EVERY snapshot is corrupt does the typed
        ``CorruptShard`` escape — loud failure, never garbage params."""
        if not self._ckpt_enabled:
            return
        plan = self.rebalance.plan
        last_err: Optional[CorruptShard] = None
        for step in sorted(self._ckpt_steps, reverse=True):
            try:
                _, full, shards = restore_resharded(
                    self.checkpoint_dir, step, self._ckpt_state, plan)
            except CorruptShard as e:
                last_err = e
                self.corrupt_shards += 1
                self.metrics.counter("corrupt_shards").inc()
                self.tracer.event("corrupt_shard", track="controller",
                                  lane="recovery", step=step, error=str(e))
                self.events.append(
                    f"tick {self.tick_count}: snapshot step {step} corrupt "
                    f"({e}), falling back")
                continue
            self.shards = shards
            self.restores += 1
            self.metrics.counter("restores").inc()
            self.tracer.event("restore", track="controller", lane="recovery",
                              step=step, cause=cause,
                              shares=[int(k) for k in plan.k])
            self.events.append(
                f"tick {self.tick_count}: restored snapshot step {step} "
                f"re-sliced onto {len(self._rb_names)} members ({cause})")
            # re-seed a snapshot under the NEW plan so the next rescale
            # restores from this epoch, not an older membership's
            self._save_checkpoint()
            return
        raise last_err if last_err is not None else CorruptShard(
            f"no snapshot to restore for {cause}")

    def schedule_kill(self, name: str, at_tick: int) -> None:
        """Declare ``name`` dead at ``at_tick`` (operator-initiated drain
        — the crash path is ``FaultPlan`` on the replica itself)."""
        if name not in self.replicas:
            raise KeyError(f"unknown replica {name!r}")
        self._kill_schedule.append((int(at_tick), name))

    def schedule_join(self, replica: Replica, at_tick: int) -> None:
        if replica.name in self.replicas:
            raise ValueError(f"replica {replica.name!r} already exists")
        self._join_schedule.append((int(at_tick), replica))

    def _replan(self, rates: Optional[Dict[str, float]] = None) -> None:
        """Rebuild the routing sequence from the live replicas' rates via
        the capacity planner (the §4 equal-finish split + smooth WRR).

        ``rates`` overrides the nominal per-replica rates (the steal path
        passes observation-smoothed rates so routing follows the measured
        platform, not the stale catalogue numbers).  The ``fleet_drift``
        gauge resets with the plan: drift is plan-relative, so a stale
        pre-replan value must never outlive the plan it scored.
        """
        # the gauge baseline resets with the plan on EVERY replan path
        # (corrector, kill, join) — first post-replan observation scores
        # against the fresh plan, not the old one's residue
        self.metrics.gauge("fleet_drift").set(0.0)
        alive = self.alive_names()
        if not alive:
            self._route_seq, self._route_pos = [], 0
            self._drift, self._drift_names = None, []
            self._corrector = None
            return
        rate_of = rates if rates is not None else {}
        planner = CapacityPlanner(
            rates=[rate_of.get(n, self.replicas[n].rate) for n in alive],
            mode=self.mode, quantum=1)
        plan = planner.plan(max(self.route_window, len(alive)))
        self._route_seq = [alive[i] for i in planner.route(plan)]
        self._route_pos = 0
        # plan-vs-actual: score decode tokens served SINCE this plan
        # against the plan's share fractions (obs.drift); the gauge is
        # the runtime.rebalance re-plan trigger signal
        self._drift = DriftMonitor(plan.partition, metrics=self.metrics,
                                   gauge_name="fleet_drift")
        self._drift_names = list(alive)
        self._drift_base = {
            n: self.replicas[n].progress()["decode_tokens"] for n in alive}
        self._drift_lbase = {
            n: self.replicas[n].slot_ticks for n in alive}
        self._drift_tick = self.tick_count
        # dynamic correction: a serve-plane corrector seeded on THIS plan.
        # The steal budget is fleet-lifetime, not plan-lifetime — each
        # fresh corrector gets only what the fleet has not yet spent, so
        # the correction count is bounded across any replan sequence.
        self._corrector = None
        if self.steal and len(alive) > 1:
            pol = self.steal_policy if self.steal_policy is not None else \
                CorrectionPolicy(hysteresis=1.5, cooldown=2,
                                 max_corrections=8, persistence=2,
                                 min_window=32.0 * len(alive))
            pol = dataclasses.replace(
                pol, max_corrections=max(0, pol.max_corrections - self.steals))
            self._corrector = WorkStealingCorrector(
                plan.partition, plane="serve", policy=pol,
                metrics=self.metrics, tracer=self.tracer,
                gauge_name="fleet_drift")
        self.tracer.event("replan", track="controller", lane="routing",
                          alive=alive)
        self.metrics.counter("replans").inc()

    def _kill(self, name: str, reason: str) -> None:
        rep = self.replicas[name]
        if not rep.alive:
            return
        rep.alive = False
        # requeue everything the dead replica still owed, under the SAME
        # fleet rid — it is never harvested again, so tokens recorded so
        # far plus the survivor's regeneration are exactly-once
        lost = rep.outstanding()
        self.tracer.event("kill", track="controller", lane="membership",
                          replica=name, reason=reason, lost=len(lost))
        for r in lost:
            # the dead engine's open spans for this request will never be
            # closed by the engine itself — close them here so the trace
            # shows the residency ending at the kill tick
            self.tracer.end(("qw", rep.engine.name, r.rid))
            self.tracer.end(("req", rep.engine.name, r.rid),
                            outcome="killed")
            rid = self._owner.pop((name, r.rid), None)
            if rid is None or rid in self.results:
                continue
            fr = self.requests[rid]
            fr.replica, fr.local_rid = None, None
            fr.n_requeues += 1
            self._unassigned.append(fr)
            self.requeues += 1
            self.metrics.counter("requeues").inc()
            self.tracer.event("requeue", track="controller",
                              lane="membership", rid=rid, replica=name)
        self.kills.append((self.tick_count, name))
        self.events.append(
            f"tick {self.tick_count}: kill {name} ({reason}), requeued "
            f"{len(lost)}")
        # the dead replica's retry state dies with it
        self._retry_attempts.pop(name, None)
        self._retry_not_before.pop(name, None)
        # shrink the live layer split through runtime.rebalance
        idx = self._rb_names.index(name)
        speeds = [self.replicas[n].rate for n in self._rb_names]
        if len(self._rb_names) > 1:
            self.rebalance = drop_devices(
                self.rebalance.assignment, [idx], speeds, quantum=1,
                mode="PCSS")
        self._rb_names.pop(idx)
        # live recovery: the dead member's checkpointed shard rows land
        # re-sliced on the survivors' new plan (ROADMAP item 3's gap)
        self._restore_on_rescale(f"kill:{name}")
        self._replan()

    def _join(self, replica: Replica) -> None:
        self.replicas[replica.name] = replica
        replica.alive = True
        replica.last_heartbeat = self.tick_count
        # grow the live layer split through runtime.rebalance
        speeds = [self.replicas[n].rate for n in self._rb_names]
        if self._rb_names:
            self.rebalance = join_devices(
                self.rebalance.assignment, [replica.rate], speeds,
                quantum=1, mode="PCSS")
        else:
            self.rebalance = plan_rebalance(
                self.rebalance.assignment.K, [replica.rate], quantum=1,
                mode="PCSS")
        self._rb_names.append(replica.name)
        self.joins.append((self.tick_count, replica.name))
        self.events.append(f"tick {self.tick_count}: join {replica.name}")
        self.tracer.event("join", track="controller", lane="membership",
                          replica=replica.name)
        # live recovery onto the GROWN fleet: the joiner picks up its
        # re-sliced share of the checkpointed state
        self._restore_on_rescale(f"join:{replica.name}")
        self._replan()

    # -- dynamic correction ------------------------------------------------
    def _effective_rates(self, work: Sequence[float]) -> List[float]:
        """Utilization-normalized work vector for the corrector: tokens
        per ACTIVE-SLOT tick (per-slot throughput — ~1 healthy at any
        occupancy, 1/slow_factor contended), re-scaled so the vector's
        total equals the window's token mass (the policy's ``min_window``
        is a token mass).  A replica with no slot tick in the window has
        no measurement — it is pinned to its planned fraction (neutral:
        contributes zero drift, the ``measure_speeds`` median trick)."""
        names = self._drift_names
        rates: List[Optional[float]] = []
        for n, dt in zip(names, work):
            st = self.replicas[n].slot_ticks - self._drift_lbase[n]
            # a loaded-but-silent replica is measured as (nearly)
            # stalled, not unmeasured — floor at half a token so the
            # corrector can rank it instead of dividing by zero
            rates.append(max(float(dt), 0.5) / st if st > 0 else None)
        frac = self._corrector.plan.k / max(float(self._corrector.plan.load),
                                            1.0)
        s_m = sum(r for r in rates if r is not None)
        f_m = sum(f for r, f in zip(rates, frac) if r is not None)
        if f_m <= 0 or s_m <= 0:
            return [float(f) for f in frac]   # nothing measured: on-plan
        full = [r if r is not None else float(f) * s_m / f_m
                for r, f in zip(rates, frac)]
        scale = max(float(sum(work)), 0.0) / sum(full)
        return [r * scale for r in full]

    def _apply_steal(self, ev, work: Sequence[float]) -> None:
        """Apply one corrector event: the straggler sheds queued (never
        in-flight) requests into the exactly-once requeue path, then the
        router is re-planned on observation-smoothed rates so new work
        stops piling onto the contended replica.  Shed requests were
        never admitted — zero tokens generated — so the greedy fleet
        oracle survives their regeneration elsewhere, exactly like the
        kill path's requeues."""
        names = self._drift_names
        src, dst = names[ev.src], names[ev.dst]
        # steal-half: the corrector's event grants ONE correction; the
        # controller sheds half the straggler's queued backlog (the
        # classic work-stealing amount — enough to matter, never the
        # FIFO head, bounded by what is actually queued)
        n_shed = max(ev.amount, (self.replicas[src].queued() + 1) // 2)
        shed = self.replicas[src].shed(n_shed)
        if not shed:
            # the straggler had nothing queued to give up — the trip is
            # recorded by the corrector but no steal is applied
            self.events.append(
                f"tick {self.tick_count}: steal {src}->{dst} suppressed "
                f"(no queued backlog)")
            return
        self.steals += 1
        for r in shed:
            rid = self._owner.pop((src, r.rid), None)
            if rid is None or rid in self.results:
                continue
            # same exactly-once bookkeeping as the kill path's requeue —
            # but placed straight onto the corrector's absorber replica,
            # not back through the router (which would hand a share of
            # them straight back to the straggler)
            fr = self.requests[rid]
            fr.replica = dst
            fr.local_rid = self.replicas[dst].submit(fr.prompt, fr.max_new)
            fr.n_requeues += 1
            self._owner[(dst, fr.local_rid)] = rid
            self.requeues += 1
            self.metrics.counter("requeues").inc()
            self.tracer.event("shed", track="controller", lane="correction",
                              rid=rid, src=src, dst=dst)
        self.events.append(
            f"tick {self.tick_count}: steal {src}->{dst} "
            f"(drift {ev.drift:.3f}), shed {len(shed)}")
        # smoothed observed rates: same total capacity as the catalogue,
        # split the way the fleet actually served — half-weight blended
        # so one noisy window cannot whipsaw the router
        nominal = np.array([self.replicas[n].rate for n in names],
                           dtype=np.float64)
        w = np.asarray(work, dtype=np.float64)
        observed = w / w.sum() * nominal.sum()
        blended = 0.5 * nominal + 0.5 * observed
        self._replan(rates=dict(zip(names, blended)))

    # -- request surface ---------------------------------------------------
    def submit(self, prompt, max_new: int, arrival: float = 0.0) -> int:
        fr = FleetRequest(
            rid=self._next_rid,
            prompt=np.asarray(prompt, np.int32).reshape(-1),
            max_new=int(max_new), arrival=float(arrival))
        self._next_rid += 1
        self.requests[fr.rid] = fr
        self._unassigned.append(fr)
        return fr.rid

    @property
    def depth(self) -> int:
        """Unfinished requests fleet-wide (the backpressure signal)."""
        return len(self.requests) - len(self.results)

    @property
    def has_work(self) -> bool:
        return self.depth > 0

    def tokens_so_far(self, rid: int) -> np.ndarray:
        """Host view of a fleet request's tokens (streaming surface).
        Harvested results are final; in-flight requests read through to
        their replica; unassigned/requeued requests are empty."""
        if rid in self.results:
            return self.results[rid]
        fr = self.requests.get(rid)
        if fr is None or fr.replica is None:
            return np.zeros(0, np.int32)
        rep = self.replicas[fr.replica]
        if not rep.alive:
            return np.zeros(0, np.int32)
        return rep.tokens_so_far(fr.local_rid)

    def _next_replica(self) -> Optional[str]:
        for _ in range(len(self._route_seq)):
            name = self._route_seq[self._route_pos
                                   % len(self._route_seq)]
            self._route_pos += 1
            if self.replicas[name].alive:
                return name
        return None

    def _dispatch(self) -> None:
        """Assign every arrived, unplaced request to the next replica in
        the planner's routing sequence (FIFO among eligible)."""
        if not self._unassigned:
            return
        self._unassigned.sort(key=lambda fr: (fr.arrival, fr.rid))
        rest: List[FleetRequest] = []
        for fr in self._unassigned:
            name = (self._next_replica()
                    if fr.arrival <= self.tick_count else None)
            if name is None:
                rest.append(fr)
                continue
            fr.replica = name
            fr.local_rid = self.replicas[name].submit(fr.prompt, fr.max_new)
            self._owner[(name, fr.local_rid)] = fr.rid
            self.tracer.event("route", track="controller", lane="routing",
                              rid=fr.rid, replica=name,
                              requeues=fr.n_requeues)
        self._unassigned = rest

    # -- retry/backoff ------------------------------------------------------
    def _transient(self, name: str, t: int, err: TransientError) -> None:
        """Classify-and-retry: a transient step failure backs the
        replica off (capped exponential, tick clock); the failed attempt
        itself proves the process responsive, so the heartbeat is
        stamped — only BUDGET exhaustion escalates to the fatal
        heartbeat-death / kill + exactly-once-requeue path."""
        rep = self.replicas[name]
        n = self._retry_attempts.get(name, 0) + 1
        self._retry_attempts[name] = n
        self.metrics.counter("transient_errors").inc()
        if n > self.retry.max_retries:
            self._kill(name, reason=f"retry-exhausted after "
                                    f"{self.retry.max_retries} retries: {err}")
            return
        backoff = self.retry.backoff(n)
        self._retry_not_before[name] = t + backoff
        rep.last_heartbeat = t
        self.retries += 1
        self.metrics.counter("retries").inc()
        self.tracer.event("retry", track="controller", lane="health",
                          replica=name, attempt=n, backoff=backoff)
        self.events.append(
            f"tick {t}: transient on {name} (attempt {n}/"
            f"{self.retry.max_retries}), backoff {backoff}")

    # -- the fleet iteration ------------------------------------------------
    def tick(self) -> bool:
        """One fleet iteration: apply scheduled rescale events, dispatch
        arrivals, step every live replica once, harvest completions,
        health-check heartbeats.  Returns True while work remains."""
        t = self.tick_count
        for at, name in [e for e in self._kill_schedule if e[0] <= t]:
            self._kill_schedule.remove((at, name))
            self._kill(name, reason="scheduled")
        for at, rep in [e for e in self._join_schedule if e[0] <= t]:
            self._join_schedule.remove((at, rep))
            self._join(rep)
        if (self._ckpt_enabled and self.checkpoint_every > 0
                and t > 0 and t % self.checkpoint_every == 0):
            self._save_checkpoint()
        self._dispatch()
        for name in list(self.replicas):
            rep = self.replicas[name]
            if not rep.alive:
                continue
            nb = self._retry_not_before.get(name)
            if nb is not None and t < nb:
                # deliberately idle under backoff: the controller is not
                # asking it to work, so stamp the heartbeat itself — a
                # backoff must never be misread as a hang
                rep.last_heartbeat = t
                continue
            try:
                rep.step(t)
            except TransientError as e:
                self._transient(name, t, e)
                continue
            except ReplicaDead as e:
                self._kill(name, reason=str(e))
                continue
            if self._retry_attempts.pop(name, None) is not None:
                # a successful step closes the incident
                self._retry_not_before.pop(name, None)
                self.recoveries += 1
                self.metrics.counter("recoveries").inc()
                self.tracer.event("recover", track="controller",
                                  lane="health", replica=name)
                self.events.append(
                    f"tick {t}: {name} recovered from transient incident")
            for local_rid, toks in rep.harvest().items():
                rid = self._owner.get((name, local_rid))
                if rid is not None and rid not in self.results:
                    self.results[rid] = toks
        for name, rep in self.replicas.items():
            if (rep.alive
                    and t - rep.last_heartbeat > self.miss_threshold):
                self.metrics.counter("heartbeat_misses").inc()
                self._kill(name, reason="heartbeat-miss")
        # plan-vs-actual: decode tokens served since the current plan,
        # scored against its share fractions (skipped when a membership
        # change mid-tick already rebuilt the monitor).  With stealing
        # on, the corrector's monitor IS the fleet_drift publisher — and
        # a tripped observation sheds work off the straggler.
        if (self._drift is not None
                and all(self.replicas[n].alive for n in self._drift_names)):
            work = [self.replicas[n].progress()["decode_tokens"]
                    - self._drift_base[n] for n in self._drift_names]
            if sum(work) > 0:
                reps = [self.replicas[n] for n in self._drift_names]
                if (self._corrector is not None
                        and any(r.queued() > 0 for r in reps)):
                    # corrector observations are gated on the existence
                    # of QUEUED (stealable) backlog — without one there
                    # is neither congestion nor anything to shed.  The
                    # work vector is utilization-normalized (tokens per
                    # LOADED tick) so an idle-for-lack-of-work replica
                    # keeps its measured speed instead of looking slow.
                    rates = self._effective_rates(work)
                    ev = self._corrector.observe(rates)
                    if ev is not None:
                        self._apply_steal(ev, rates)
                else:
                    self._drift.observe_shares(work)
        self.metrics.gauge("fleet_depth").set(self.depth)
        self.tracer.counter("fleet_depth", self.depth, track="controller")
        self.tick_count += 1
        if self.has_work and not self.alive_names() \
                and not self._join_schedule:
            raise FleetDegraded(
                f"fleet has {self.depth} unfinished requests but no live "
                f"replica and no scheduled join — the work cannot drain",
                retry_after=None)
        return self.has_work or bool(self._join_schedule
                                     or self._kill_schedule)

    def run(self, max_ticks: int = 1_000_000) -> FleetReport:
        """Drive ticks until drained; returns the fleet report."""
        while self.tick():
            if self.tick_count >= max_ticks:
                raise RuntimeError(
                    f"fleet did not drain in {max_ticks} ticks "
                    f"(depth={self.depth})")
        return self.report()

    def report(self) -> FleetReport:
        occ = {n: r.progress()["occupancy"]
               for n, r in self.replicas.items()}
        dec = {n: int(r.progress()["decode_tokens"])
               for n, r in self.replicas.items()}
        return FleetReport(
            completed=dict(self.results), ticks=self.tick_count,
            requeues=self.requeues, kills=list(self.kills),
            joins=list(self.joins), occupancy=occ, decode_tokens=dec,
            events=list(self.events), steals=self.steals,
            retries=self.retries, recoveries=self.recoveries,
            restores=self.restores, corrupt_shards=self.corrupt_shards)
