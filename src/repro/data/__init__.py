from .pipeline import SyntheticTokens  # noqa: F401
