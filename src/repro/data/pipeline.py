"""Deterministic synthetic token pipeline (host-sharded, random-access).

Every batch is a pure function of (seed, step) via Philox counter streams,
which gives the two properties a production loader needs here:

  * exact resume: restarting from a checkpoint at step k replays batch k
    bit-identically (tested in tests/test_checkpoint.py);
  * host sharding: each host materializes only its rows
    (``host_slice``), so the loader scales with the fleet.

Token stream: noisy affine bigrams x_{t+1} = (a*x_t + b) mod V with
probability 1-eps (else uniform) — learnable structure so smoke trainings
show decreasing loss, with entropy so it is not trivially memorized.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticTokens:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    noise: float = 0.1
    prefix_len: int = 0          # frontend stub: emit prefix embeddings too
    d_model: int = 0

    def batch_at(self, step: int, host_id: int = 0, n_hosts: int = 1
                 ) -> Dict[str, np.ndarray]:
        assert self.global_batch % n_hosts == 0
        rows = self.global_batch // n_hosts
        rng = np.random.Generator(np.random.Philox(
            np.random.SeedSequence([self.seed, step, host_id, 0xC0FFEE])))
        V = self.vocab_size
        a = 3 + 2 * (self.seed % 5)      # odd multiplier, coprime-ish
        b = 17
        S = self.seq_len - self.prefix_len
        x = np.empty((rows, S), np.int32)
        x[:, 0] = rng.integers(0, V, rows)
        noise = rng.random((rows, S)) < self.noise
        rand = rng.integers(0, V, (rows, S))
        for t in range(1, S):
            nxt = (a * x[:, t - 1] + b) % V
            x[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        out: Dict[str, np.ndarray] = {"tokens": x}
        if self.prefix_len:
            out["prefix_embeds"] = rng.standard_normal(
                (rows, self.prefix_len, self.d_model)).astype(np.float32) * 0.02
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def put_global(batch: Dict[str, np.ndarray], mesh, specs) -> Dict:
    """device_put a host batch with the profile's shardings."""
    import jax
    from jax.sharding import NamedSharding

    return {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
            for k, v in batch.items()}
