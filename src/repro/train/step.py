"""train_step: microbatched gradient accumulation + AdamW, fully sharded.

One jitted step consumes the GLOBAL batch (sharded over ("pod","data")),
scans over ``grad_accum`` microbatches (each rematerialized), accumulates
float32 gradients sharded like the parameters, and applies AdamW.

This is what the dry-run lowers for every ``train_4k`` cell.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import transformer as T
from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update, opt_state_specs
from ..sharding.rules import Rules

TrainState = Dict[str, Any]   # {"params", "opt", "rng"}


def init_train_state(cfg: ModelConfig, key) -> TrainState:
    params = T.init_params(cfg, key)
    return {"params": params, "opt": adamw_init(params)}


def train_state_specs(cfg: ModelConfig, rules: Rules):
    p = T.param_specs(cfg, rules)
    return {"params": p, "opt": opt_state_specs(p)}


def batch_specs(cfg: ModelConfig, rules: Rules):
    s: Dict[str, Any] = {"tokens": rules.spec("batch", None)}
    if cfg.frontend != "none":
        s["prefix_embeds"] = rules.spec("batch", None, None)
    return s


def make_train_step(cfg: ModelConfig, rules: Rules, opt_cfg: AdamWConfig,
                    grad_accum: int = 1, *,
                    overlap_streaming: Optional[bool] = None,
                    overlap_bidir: Optional[bool] = None):
    """Returns step(state, batch) -> (state, metrics).

    ``overlap_streaming`` (None = leave the global tuning untouched)
    selects the overlapped layer-streaming execution plane for every
    row-parallel matmul in the step: the FSDP weight gather and the layer
    aggregation become ppermute rings (``core/overlap.py``) so the lowered
    step contains no monolithic all-gather and is bounded by
    max(comm, compute) per the paper's simultaneous-start analysis.  It
    implies the explicit shard_map LBP path — a plain einsum cannot
    stream.  ``overlap_bidir`` additionally splits the aggregation rings
    into two opposite-direction half-rings (halved sequential hop depth
    at identical bytes).  The flags are applied around the TRACE of
    ``step`` (set on entry, restored on exit), so steps built with
    different settings coexist and the process-global tuning is left
    untouched.
    """

    def _apply_tuning() -> Dict[str, bool]:
        if overlap_streaming is None and overlap_bidir is None:
            return {}
        from ..models.tuning import TUNING, set_tuning
        saved = {"overlap_streaming": TUNING.overlap_streaming,
                 "explicit_lbp_scatter": TUNING.explicit_lbp_scatter,
                 "overlap_bidir": TUNING.overlap_bidir}
        if overlap_streaming is not None:
            set_tuning(overlap_streaming=bool(overlap_streaming))
            if overlap_streaming:
                set_tuning(explicit_lbp_scatter=True)
        if overlap_bidir is not None:
            set_tuning(overlap_bidir=bool(overlap_bidir))
        return saved

    def _restore_tuning(saved: Dict[str, bool]) -> None:
        if saved:
            from ..models.tuning import set_tuning
            set_tuning(**saved)

    def loss(params, micro):
        return T.loss_fn(params, cfg, rules, micro)

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, Any]]:
        saved = _apply_tuning()
        try:
            return _step(state, batch)
        finally:
            _restore_tuning(saved)

    def _step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, Any]]:
        params = state["params"]

        if grad_accum == 1:
            l, grads = jax.value_and_grad(loss)(params, batch)
        else:
            def split(x):
                return x.reshape((grad_accum, x.shape[0] // grad_accum)
                                 + x.shape[1:])
            micro_batches = jax.tree.map(split, batch)

            def accum(carry, micro):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss)(params, micro)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)), micro_batches)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            l = lsum / grad_accum

        new_params, new_opt, metrics = adamw_update(
            params, grads, state["opt"], opt_cfg)
        metrics["loss"] = l
        return {"params": new_params, "opt": new_opt}, metrics

    return step


def default_grad_accum(cfg: ModelConfig) -> int:
    """train_4k microbatching: enough accumulation that per-device
    activations fit 16 GB HBM (batch 256 over 32-512 data shards)."""
    n = cfg.n_params()
    if n > 60e9:
        return 8
    if n > 8e9 or cfg.is_moe:
        return 4
    return 2
