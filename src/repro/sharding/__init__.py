from .rules import Rules, shard  # noqa: F401
