"""Logical-axis sharding rules: one table drives every constraint in the zoo.

Models never name mesh axes directly; they call ``shard(x, "batch", "seq",
"embed")`` and the active ``Rules`` maps logical names to mesh axes (or None
for replication).  Smoke tests pass ``Rules.null()`` (single device, every
constraint a no-op); the dry-run/launcher installs a per-shape profile from
``sharding.profiles``.

Logical axes:
  batch     global batch                      (train/prefill: ("pod","data"))
  seq       sequence                          (sequence-parallel regions)
  embed     d_model                           (FSDP param shard dim)
  heads     attention heads / q features      (TP)
  kv_heads  KV heads                          (TP for caches)
  ff        FFN hidden                        (TP; LBP contraction on down-proj)
  vocab     vocabulary                        (TP'd embedding/logits)
  expert    MoE experts                       (EP)
  kv_time   KV-cache time axis                (serving: LBP over the sequence
                                               contraction = flash-decoding)
  layers    stacked-layer leading dim         (never sharded; pipeline reserve)
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

AxisName = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Rules:
    batch: AxisName = None
    seq: AxisName = None
    embed: AxisName = None
    heads: AxisName = None
    kv_heads: AxisName = None
    ff: AxisName = None
    vocab: AxisName = None
    expert: AxisName = None
    kv_time: AxisName = None
    layers: AxisName = None
    # the concrete mesh (for explicit shard_map sub-blocks; None in smoke)
    mesh: object = dataclasses.field(default=None, compare=False, hash=False)

    @staticmethod
    def null() -> "Rules":
        """All-replicated (single-device smoke tests)."""
        return Rules()

    def spec(self, *logical: Optional[str]) -> P:
        """PartitionSpec for a tensor whose dims carry these logical names."""
        return P(*(getattr(self, n) if n is not None else None
                   for n in logical))

    def shard_map(self, fn, in_specs, out_specs):
        """shard_map ``fn`` over this rules' mesh (via ``repro.compat`` so
        the jax-version drift is handled in one place).  The explicit
        sub-blocks (LBP linear, EP MoE) all go through here."""
        from ..compat import shard_map as _shard_map
        assert self.mesh is not None, "shard_map needs concrete mesh rules"
        return _shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)


def shard(x: jax.Array, rules: Rules, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the active rules (no-op for null)."""
    assert len(logical) == x.ndim, (logical, x.shape)
    spec = rules.spec(*logical)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Per-shape profiles (DESIGN.md §5).  "pod" exists only on the multi-pod mesh;
# make_rules() drops axis names that are absent from the active mesh.
# ---------------------------------------------------------------------------

def _filter(axis: AxisName, present) -> AxisName:
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis if axis in present else None
    kept = tuple(a for a in axis if a in present)
    return kept if kept else None


def make_rules(profile: str, mesh) -> Rules:
    """profile in {"train", "prefill", "decode", "long"}."""
    present = set(mesh.axis_names)
    if profile == "train":
        r = Rules(batch=("pod", "data"), embed="data", heads="model",
                  kv_heads="model", ff="model", vocab="model", expert="model")
    elif profile == "train_sp":
        # beyond-paper: sequence parallelism — deferred aggregation
        # (reduce-scatter) between blocks instead of eager all-reduce.
        r = Rules(batch=("pod", "data"), seq="model", embed="data",
                  heads="model", kv_heads="model", ff="model", vocab="model",
                  expert="model")
    elif profile == "prefill":
        r = Rules(batch=("pod", "data"), embed="data", heads="model",
                  kv_heads="model", ff="model", vocab="model", expert="model",
                  kv_time="model")
    elif profile == "prefill_sp":
        # beyond-paper: deferred aggregation between blocks during prefill
        r = Rules(batch=("pod", "data"), seq="model", embed="data",
                  heads="model", kv_heads="model", ff="model", vocab="model",
                  expert="model", kv_time="model")
    elif profile == "decode":
        r = Rules(batch=("pod", "data"), heads="model", kv_heads="model",
                  ff="model", vocab="model", expert="model", kv_time="model")
    elif profile == "long":
        # batch=1: nothing to shard on data; spread state over model.
        r = Rules(batch=None, heads="model", kv_heads="model", ff="model",
                  vocab="model", expert="model", embed="data",
                  kv_time="model")
    else:
        raise ValueError(profile)
    filtered = {f.name: _filter(getattr(r, f.name), present)
                for f in dataclasses.fields(r) if f.name != "mesh"}
    return Rules(mesh=mesh, **filtered)
