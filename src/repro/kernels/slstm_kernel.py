"""Pallas TPU kernel: weight-stationary sLSTM recurrence.

§Perf finding (EXPERIMENTS.md): at the XLA level the sLSTM scan re-reads
its per-head recurrent matrices R_{z,i,f,o} (hd x hd each) from HBM on
EVERY timestep — ~16.8 MB x S x n_blocks, the dominant memory term of the
xlstm arch (hundreds of seconds on the roofline).  The fix is structural
and kernel-shaped: keep R resident in VMEM across the time loop
(weight-stationary), stream only the 4 gate pre-activations per step.

Grid: (B, H) — one cell per (batch row, head).  VMEM per cell
(hd=512, f32): 4 R matrices = 4 MB, gate streams (S_chunk, 4*hd) and the
carry vectors — well under 16 MB for hd <= 512 with single buffering.
HBM traffic becomes: R once per (B,H) cell + gates once + h once — the
per-step weight re-reads disappear.

The ops.py wrapper chunks long sequences (carrying c/n/h) like rglru.
Recurrence (simplified gates, matching models/xlstm.slstm_block):
    z = tanh(pz_t + h R_z);  i = sig(pi_t + h R_i)
    f = sig(pf_t + 1 + h R_f);  o = sig(po_t + h R_o)
    c' = f c + i z;  n' = f n + i;  h' = o c' / max(n', 1e-6)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _slstm_kernel(pz_ref, pi_ref, pf_ref, po_ref, rz_ref, ri_ref, rf_ref,
                  ro_ref, c0_ref, n0_ref, h0_ref, hs_ref, c_ref, n_ref,
                  h_ref, *, seq_len: int):
    rz = rz_ref[0]          # (hd, hd) — VMEM-resident across the time loop
    ri = ri_ref[0]
    rf = rf_ref[0]
    ro = ro_ref[0]

    def body(t, carry):
        c, n, h = carry
        hz = jnp.dot(h, rz, preferred_element_type=jnp.float32)
        hi = jnp.dot(h, ri, preferred_element_type=jnp.float32)
        hf = jnp.dot(h, rf, preferred_element_type=jnp.float32)
        ho = jnp.dot(h, ro, preferred_element_type=jnp.float32)
        z = jnp.tanh(pz_ref[0, t] + hz)
        i = jax.nn.sigmoid(pi_ref[0, t] + hi)
        f = jax.nn.sigmoid(pf_ref[0, t] + 1.0 + hf)
        o = jax.nn.sigmoid(po_ref[0, t] + ho)
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(n, 1e-6)
        hs_ref[0, pl.dslice(t, 1), :] = h[None, :]
        return c, n, h

    c, n, h = jax.lax.fori_loop(
        0, seq_len, body, (c0_ref[0], n0_ref[0], h0_ref[0]))
    c_ref[0] = c
    n_ref[0] = n
    h_ref[0] = h


def slstm_pallas(pre, R, state, *, interpret: bool = False):
    """One chunk of the weight-stationary sLSTM recurrence.

    pre:   dict z/i/f/o -> (B, S, H, hd) gate pre-activations (x-path)
    R:     dict z/i/f/o -> (H, hd, hd) recurrent matrices
    state: (c, n, h) each (B, H, hd)
    Returns (hs: (B, S, H, hd), (c, n, h)).
    """
    B, S, H, hd = pre["z"].shape
    kernel = functools.partial(_slstm_kernel, seq_len=S)
    grid = (B, H)

    # flatten (B, H) into the leading block dim: gates (B*H, S, hd)
    pres = {k: v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
            for k, v in pre.items()}
    Rs = {k: v for k, v in R.items()}
    c0, n0, h0 = (s.reshape(B * H, hd) for s in state)

    gate_spec = pl.BlockSpec((1, S, hd), lambda b, h: (b * H + h, 0, 0))
    r_spec = pl.BlockSpec((1, hd, hd), lambda b, h: (h, 0, 0))
    st_spec = pl.BlockSpec((1, hd), lambda b, h: (b * H + h, 0))

    hs, c, n, h = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[gate_spec] * 4 + [r_spec] * 4 + [st_spec] * 3,
        out_specs=[gate_spec, st_spec, st_spec, st_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, hd), jnp.float32),
        ],
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(pres["z"], pres["i"], pres["f"], pres["o"],
      Rs["z"], Rs["i"], Rs["f"], Rs["o"], c0, n0, h0)

    hs = hs.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    st = tuple(x.reshape(B, H, hd) for x in (c, n, h))
    return hs, st
