"""Jit'd public wrappers around the Pallas kernels.

Every wrapper:
  * pads inputs to block multiples (zero padding is exact for all three
    kernels: matmul layers, attention KV with -inf masking via extra keys
    being zero... see notes), slices the result back;
  * runs the kernel in ``interpret=True`` when not on a TPU backend (this
    container is CPU-only; TPU is the deployment target);
  * has a pure-jnp oracle in ref.py used by the test sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention_kernel import flash_attention_pallas
from .lbp_matmul_kernel import lbp_matmul_pallas
from .rglru_kernel import rglru_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def matmul(x: jax.Array, w: jax.Array, *, block_m: int = 512,
           block_n: int = 512, block_k: int = 512, out_dtype=None,
           interpret: bool | None = None) -> jax.Array:
    """Layer-accumulating blocked matmul; pads (M, K, F) to block multiples.

    Zero-padding K adds all-zero layers — exact by Theorem-1 linearity.
    """
    if interpret is None:
        interpret = not _on_tpu()
    m, k = x.shape
    f = w.shape[1]
    xp = _pad_to(_pad_to(x, 0, block_m), 1, block_k)
    wp = _pad_to(_pad_to(w, 0, block_k), 1, block_n)
    out = lbp_matmul_pallas(xp, wp, block_m=block_m, block_n=block_n,
                            block_k=block_k, out_dtype=out_dtype,
                            interpret=interpret)
    return out[:m, :f]


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def rglru(a: jax.Array, b: jax.Array, h0: jax.Array, *, block_d: int = 512,
          chunk: int = 256, interpret: bool | None = None):
    """Gated linear recurrence h_t = a_t h_{t-1} + b_t over long sequences.

    Chunks the sequence (kernel holds one chunk in VMEM) and carries h
    between chunks with lax.scan.  Channel dim padded to block_d (padded
    channels recur on zeros — exact).
    Returns (h: (B,S,D), h_end: (B,D)).
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, S, D = a.shape
    ap = _pad_to(a, 2, block_d)
    bp = _pad_to(b, 2, block_d)
    h0p = _pad_to(h0, 1, block_d)
    Dp = ap.shape[2]

    chunk = min(chunk, S)
    if S % chunk != 0:
        # pad sequence with a=1, b=0 (identity updates)
        pad = (-S) % chunk
        ap = jnp.concatenate([ap, jnp.ones((B, pad, Dp), ap.dtype)], axis=1)
        bp = jnp.concatenate([bp, jnp.zeros((B, pad, Dp), bp.dtype)], axis=1)
    n_chunks = ap.shape[1] // chunk

    def step(h, ab):
        ac, bc = ab
        hs, h_end = rglru_pallas(ac, bc, h, block_d=block_d,
                                 interpret=interpret)
        return h_end, hs

    a_c = ap.reshape(B, n_chunks, chunk, Dp).transpose(1, 0, 2, 3)
    b_c = bp.reshape(B, n_chunks, chunk, Dp).transpose(1, 0, 2, 3)
    h_end, hs = jax.lax.scan(step, h0p, (a_c, b_c))
    h = hs.transpose(1, 0, 2, 3).reshape(B, n_chunks * chunk, Dp)
    return h[:, :S, :D], h_end[:, :D]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def slstm(pre, R, state, *, chunk: int = 256, interpret: bool | None = None):
    """Weight-stationary sLSTM over long sequences (chunked, carried state).

    pre: dict z/i/f/o -> (B,S,H,hd); R: dict -> (H,hd,hd);
    state: (c,n,h) each (B,H,hd).
    """
    from .slstm_kernel import slstm_pallas

    if interpret is None:
        interpret = not _on_tpu()
    B, S, H, hd = pre["z"].shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    n_chunks = S // c

    def step(st, gates):
        hs, st = slstm_pallas(
            {k: v for k, v in zip("zifo", gates)}, R, st,
            interpret=interpret)
        return st, hs

    seqs = tuple(pre[g].reshape(B, n_chunks, c, H, hd).swapaxes(0, 1)
                 for g in "zifo")
    st, hs = jax.lax.scan(step, tuple(state), seqs)
    return hs.swapaxes(0, 1).reshape(B, S, H, hd), st


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool | None = None) -> jax.Array:
    """Blocked online-softmax attention, (B, H, S, D) layout.

    Query padding rows attend causally to real keys (sliced away); key/value
    padding is masked with an explicit validity mask folded into the causal
    comparison — we pad T to block_k with keys at positions > S which the
    causal mask of every real query row excludes.  For non-causal use, T
    must already be a block multiple (asserted).
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, H, S, D = q.shape
    T = k.shape[2]
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, T, D)
    vf = v.reshape(B * H, T, D)
    if not causal:
        assert S % min(block_q, S) == 0 and T % min(block_k, T) == 0, (
            "non-causal path requires block-aligned S/T")
    qf = _pad_to(qf, 1, block_q)
    # key padding sits at positions >= T; causal masking of real rows
    # (row < T <= padded col) excludes it exactly.
    kf = _pad_to(kf, 1, block_k)
    vf = _pad_to(vf, 1, block_k)
    out = flash_attention_pallas(qf, kf, vf, causal=causal, scale=scale,
                                 block_q=block_q, block_k=block_k,
                                 interpret=interpret)
    return out[:, :S].reshape(B, H, S, D)
