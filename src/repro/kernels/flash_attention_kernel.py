"""Pallas TPU kernel: blocked online-softmax (flash) attention.

Attention is the second compute hot-spot of the assigned LM architectures
(32k prefill).  The same LBP idea used for the matmul kernel applies to the
KV axis: the KV-block grid dimension plays the role of the paper's layers —
each step contributes one partial (softmax-weighted) layer of the output
tile, accumulated in VMEM with the numerically-stable online rescaling, and
the output is written to HBM once, on the last KV block.

Grid ``(BH, S/bq, T/bk)`` with KV innermost (arbitrary semantics — the
running max / denominator / accumulator carry across KV steps in VMEM
scratch).  Causal masking skips fully-masked KV blocks via pl.when.

VMEM per cell (bq=bk=512, D<=256, f32): q 0.5 + k 0.5 + v 0.5 + acc 0.5 MB
+ m/l negligible — comfortably under v5e's 16 MB with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, n_kv: int, block_q: int, block_k: int, causal: bool,
                  scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: KV block j is live iff its first col <= last row of q block i
    live = (j * block_k <= i * block_q + block_q - 1) if causal else True

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)            # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal:
            rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                       # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _done():
        o_ref[0, ...] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q: (BH, S, D), k/v: (BH, T, D) -> (BH, S, D).

    S % block_q == 0 and T % block_k == 0 (ops.py pads).
    """
    BH, S, D = q.shape
    _, T, _ = k.shape
    assert k.shape == (BH, T, D) and v.shape == (BH, T, D)
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0
    scale = float(scale) if scale is not None else float(D) ** -0.5
    n_kv = T // block_k

    kernel = functools.partial(
        _flash_kernel, n_kv=n_kv, block_q=block_q, block_k=block_k,
        causal=causal, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(BH, S // block_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denominator
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
