"""Pallas TPU kernel: blocked RG-LRU linear recurrence (recurrentgemma).

The RG-LRU update (Griffin, arXiv:2402.19427) after gate precomputation is a
per-channel gated linear recurrence

    h_t = a_t * h_{t-1} + b_t,        a_t in (0,1),  b_t = sqrt(1-a_t^2) * gated_x_t

which has no contraction dimension, so the paper's layer partition does not
apply (DESIGN.md §Arch-applicability); it is instead embarrassingly parallel
over (batch, channel).  The kernel tiles channels into VMEM blocks — grid
``(B, D/bd)`` — and runs the time loop inside the kernel with the carry held
in VREGs, streaming one (1, S_chunk, bd) block of a/b per grid cell.  Long
sequences are chunked by the ops.py wrapper, carrying h between chunks.

VMEM per cell (defaults S_chunk=256, bd=512, f32):
  a + b blocks: 2 * 256*512*4 = 1.0 MB, out 0.5 MB, carry negligible.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, hend_ref, *, seq_len: int):
    def body(t, h):
        a = a_ref[0, t, :]
        b = b_ref[0, t, :]
        h = a * h + b
        o_ref[0, pl.dslice(t, 1), :] = h[None, :]
        return h

    h = jax.lax.fori_loop(0, seq_len, body, h0_ref[0, :])
    hend_ref[0, :] = h


def rglru_pallas(
    a: jax.Array,
    b: jax.Array,
    h0: jax.Array,
    *,
    block_d: int = 512,
    interpret: bool = False,
):
    """One chunk of the recurrence.

    a, b: (B, S, D) decay / input;  h0: (B, D) carry.
    Returns (h: (B, S, D), h_end: (B, D)).  D must divide by block_d
    (ops.py pads).
    """
    B, S, D = a.shape
    assert b.shape == (B, S, D) and h0.shape == (B, D)
    block_d = min(block_d, D)
    assert D % block_d == 0, (D, block_d)

    kernel = functools.partial(_rglru_kernel, seq_len=S)
    grid = (B, D // block_d)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, block_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, S, block_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, block_d), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, block_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, block_d), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), a.dtype),
            jax.ShapeDtypeStruct((B, D), h0.dtype),
        ],
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(a, b, h0)
