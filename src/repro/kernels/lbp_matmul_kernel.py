"""Pallas TPU kernel: layer-accumulating blocked matmul (LBP at VMEM level).

The paper's layer decomposition ``C = sum_i A[:,K_i] @ B[K_i,:]`` maps onto
the TPU memory hierarchy as the k-innermost blocked matmul: each K grid step
computes one *layer* of a ``(bm, bn)`` output tile and accumulates it into a
float32 VMEM scratch accumulator — the kernel-level form of the paper's
"aggregate layers lazily" (the accumulator is written back to HBM exactly
once, at the last layer).  Pipelining across the K grid is the paper's
*simultaneous start* mode: the DMA fetching layer j+1's operands overlaps the
MXU computing layer j.

Grid: ``(M/bm, N/bn, K/bk)`` with K innermost ("arbitrary" semantics so the
accumulator carries across steps; M/N are parallel).  Blocks default to
(512, 512, 512): MXU-aligned (multiples of 128) and a VMEM working set of
  x(512x512xbf16) + w(512x512xbf16) + acc(512x512xf32) = 0.5+0.5+1.0 MB
plus double buffering ~ 3 MB << 16 MB v5e VMEM.

Validated against ``ref.matmul_ref`` with ``interpret=True`` (CPU executes
the kernel body; the TPU is the deployment target).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; interpret mode falls back to ANY
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # one LBP layer of this output tile: A[:, K_k] @ B[K_k, :]
    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def lbp_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 512,
    block_n: int = 512,
    block_k: int = 512,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
) -> jax.Array:
    """``x @ w`` with layer-accumulating VMEM tiling.

    x: (M, K), w: (K, F).  M, K, F must be divisible by the block sizes
    (the ops.py wrapper pads).  Accumulation is always float32.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    out_dtype = out_dtype or x.dtype
    n_k = k // block_k

    grid = (m // block_m, n // block_n, n_k)
    kernel = functools.partial(_matmul_kernel, n_k=n_k)

    scratch = [pltpu.VMEM((block_m, block_n), jnp.float32)]

    compiler_params = None
    if not interpret:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        compiler_params=compiler_params,
        interpret=interpret,
    )(x, w)
