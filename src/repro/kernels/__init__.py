"""Pallas TPU kernels for the compute hot-spots, with pure-jnp oracles.

  lbp_matmul_kernel    layer-accumulating blocked matmul (the paper's layers
                       as K-grid steps with a VMEM accumulator)
  flash_attention_kernel  blocked online-softmax attention (KV blocks as layers)
  rglru_kernel         RG-LRU gated linear recurrence (recurrentgemma)
  slstm_kernel         weight-stationary sLSTM (recurrent R matrices VMEM-
                       resident across the time loop — kills the per-step
                       HBM weight re-reads that dominate xlstm's roofline)

ops.py holds the jit'd padded wrappers (interpret=True off-TPU); ref.py the
oracles; tests/test_kernels.py the shape/dtype sweeps.
"""

from . import ops, ref  # noqa: F401
