"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array, out_dtype=None) -> jax.Array:
    """f32-accumulated matmul."""
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return out.astype(out_dtype or x.dtype)


def rglru_ref(a: jax.Array, b: jax.Array, h0: jax.Array):
    """Sequential scan: h_t = a_t * h_{t-1} + b_t.

    a, b: (B, S, D); h0: (B, D).  Returns (h: (B,S,D), h_end: (B,D)).
    """
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a_t = jnp.swapaxes(a, 0, 1)  # (S, B, D)
    b_t = jnp.swapaxes(b, 0, 1)
    h_end, hs = jax.lax.scan(step, h0, (a_t, b_t))
    return jnp.swapaxes(hs, 0, 1), h_end


def slstm_ref(pre, R, state):
    """Sequential sLSTM oracle (same math as models/xlstm.slstm_block).

    pre: dict z/i/f/o -> (B,S,H,hd); R: dict -> (H,hd,hd);
    state: (c,n,h) each (B,H,hd).  Returns (hs (B,S,H,hd), (c,n,h)).
    """
    def step(carry, gates):
        c, n, h = carry
        pz, pi, pf, po = gates
        z = jnp.tanh(pz + jnp.einsum("bhk,hkv->bhv", h, R["z"]))
        i = jax.nn.sigmoid(pi + jnp.einsum("bhk,hkv->bhv", h, R["i"]))
        f = jax.nn.sigmoid(pf + 1.0 + jnp.einsum("bhk,hkv->bhv", h, R["f"]))
        o = jax.nn.sigmoid(po + jnp.einsum("bhk,hkv->bhv", h, R["o"]))
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(n, 1e-6)
        return (c, n, h), h

    seq = tuple(pre[g].swapaxes(0, 1) for g in ("z", "i", "f", "o"))
    (c, n, h), hs = jax.lax.scan(step, state, seq)
    return hs.swapaxes(0, 1), (c, n, h)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, scale: float | None = None) -> jax.Array:
    """Full-materialization softmax attention. q: (BH,S,D), k/v: (BH,T,D)."""
    BH, S, D = q.shape
    T = k.shape[1]
    scale = float(scale) if scale is not None else float(D) ** -0.5
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
