"""musicgen-medium [audio]: decoder-only over EnCodec tokens (arXiv:2306.05284).

48L d_model=1536 24H (MHA: kv=24) d_ff=6144 vocab=2048.  The EnCodec /
conditioning frontend is a STUB: input_specs provides precomputed frame
embeddings as a prefix (DESIGN.md §frontends); the backbone is the standard
transformer decoder.  MHA heads (24) pad to 32 for 16-way TP.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen_medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    frontend="audio_frames",
    prefix_len=256,
)

REDUCED = CONFIG.reduced(n_heads=4, n_kv_heads=4)
