"""Assigned input-shape cells (LM-family: seq_len x global_batch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one token + KV cache of
seq_len); ``train_*`` lower ``train_step``; ``prefill_*`` lower the prefill.
``long_500k`` requires sub-quadratic attention: only recurrentgemma (local
window) and xlstm (constant state) run it — the 8 pure full-attention archs
skip with a note (DESIGN.md §long-context skips).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}

# archs with sub-quadratic sequence mixing (run long_500k)
SUBQUADRATIC = {"recurrentgemma_9b", "xlstm_1_3b"}


def cells_for(arch: str) -> List[Tuple[str, ShapeCell]]:
    out = []
    for name, cell in SHAPES.items():
        if name == "long_500k" and arch not in SUBQUADRATIC:
            continue  # full-attention: O(S^2)/KV>HBM — documented skip
        out.append((name, cell))
    return out


def all_cells() -> List[Tuple[str, str]]:
    from . import ARCH_IDS
    return [(a, n) for a in ARCH_IDS for (n, _) in cells_for(a)]
