"""qwen3-14b [dense]: 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936, per-head q/k RMSNorm [hf:Qwen/Qwen3-14B].  q heads pad
40->48 for 16-way TP."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1000000.0,
)

REDUCED = CONFIG.reduced(qk_norm=True)
