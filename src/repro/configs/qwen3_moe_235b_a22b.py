"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) vocab=151936,
128 experts top-8, d_ff(expert)=1536, qk_norm [hf:Qwen/Qwen3-235B-A22B].
KV=4 repeats 4x in flash tiles; decode shards cache time (flash-decoding)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_moe_235b_a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    n_experts=128,
    experts_per_token=8,
    rope_theta=1000000.0,
)

REDUCED = CONFIG.reduced(qk_norm=True)
