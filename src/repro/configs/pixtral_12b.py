"""pixtral-12b [vlm]: pixtral-ViT + mistral-nemo decoder
[hf:mistralai/Pixtral-12B-2409].  40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072.  The ViT frontend is a STUB: input_specs provides
precomputed patch embeddings as a (B, 256, d) prefix."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral_12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1000000.0,
    frontend="vision_patches",
    prefix_len=256,
)

REDUCED = CONFIG.reduced()
