"""Architecture registry: one module per assigned arch, ``--arch <id>``."""

from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

ARCH_IDS: List[str] = [
    "musicgen_medium",
    "llama3_2_3b",
    "mistral_large_123b",
    "granite_8b",
    "qwen3_14b",
    "olmoe_1b_7b",
    "qwen3_moe_235b_a22b",
    "pixtral_12b",
    "recurrentgemma_9b",
    "xlstm_1_3b",
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}


def canonical(arch: str) -> str:
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return arch


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(arch)}", __package__)
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(arch)}", __package__)
    return mod.REDUCED


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
