"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16) vocab=50304,
64 experts top-8, d_ff(expert)=1024 (arXiv:2409.02060).  Experts shard
over the model axis (EP); the all-to-all dispatch dominates collectives."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe_1b_7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    head_dim=128,
    n_experts=64,
    experts_per_token=8,
)

REDUCED = CONFIG.reduced(n_heads=4, n_kv_heads=4)
