"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks 7:1 (arXiv:2405.04517).
48 blocks = 6 x (7 mLSTM + 1 sLSTM), d_model=2048, 4 heads head_dim=512,
d_ff=0 (cell-internal projections only), vocab=50304.  Constant-size
state -> runs long_500k.  mLSTM value dim shards over model (4 heads
cannot split 16 ways); chunkwise form makes the cell matmul-bound."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm_1_3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=512,
    mlstm_per_group=7,
    mlstm_chunk=64,
)

REDUCED = CONFIG.reduced()
