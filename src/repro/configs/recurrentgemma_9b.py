"""recurrentgemma-9b [hybrid]: Griffin RG-LRU + local attention 1:2
(arXiv:2402.19427).  38L = 12 x (R,R,A) + 2R tail, d_model=4096,
16H MQA (kv=1) head_dim=256, d_ff=12288, window=2048, lru_width=4096,
vocab=256000.  Sub-quadratic -> runs long_500k."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma_9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("R", "R", "A"),
    window=2048,
    lru_width=4096,
)

REDUCED = CONFIG.reduced(n_heads=4, n_kv_heads=1, head_dim=16)
