from .step import (cached_decode_step, cached_prefill_step,  # noqa: F401
                   greedy_generate, make_decode_step,
                   make_paged_decode_scan, make_paged_decode_step,
                   make_prefill_step)
from .engine import (CapacityPlanner, EngineConfig, EngineReport,  # noqa: F401
                     ManualClock, PagedReplicaPlan, PagedTransformerModel,
                     ReplicaPlan, ServingEngine, TransformerModel,
                     serve_requests)
