"""Continuous-batching serving engine with LBP capacity planning.

The layer between the §4 solvers and the user-facing launcher:

  queue.py       FIFO admission-controlled request queue
  cache_pool.py  slot-row AND paged KV-cache pools (one admission surface)
  prefix.py      prefix index: shared prompt pages + refcount lifecycle
  scheduler.py   per-iteration batch former (retire / admit / decode)
  engine.py      the engine loop + slot/paged transformer model adapters
  planner.py     star-network traffic split across heterogeneous replicas
                 (page-seconds capacity for memory-bounded fleets)
"""

from .cache_pool import (PagedCachePool, SlotCachePool,  # noqa: F401
                         gather_page_view, scatter_page_view, write_slot)
from .prefix import PrefixIndex, page_key  # noqa: F401
from .engine import (EngineConfig, EngineReport, ManualClock,  # noqa: F401
                     PagedTransformerModel, ServingEngine,
                     TransformerModel, serve_requests)
from .planner import (CapacityPlanner, DCN_LINK, ICI_LINK,  # noqa: F401
                      PagedReplicaPlan, ReplicaPlan)
from .queue import AdmissionError, AdmissionLimits, RequestQueue  # noqa: F401
from .request import Request  # noqa: F401
from .scheduler import Scheduler, StepPlan  # noqa: F401
from .workload import (shared_prefix_workload,  # noqa: F401
                       synthetic_workload)
