"""Continuous-batching serving engine with LBP capacity planning.

The layer between the §4 solvers and the user-facing launcher:

  queue.py       FIFO admission-controlled request queue
  cache_pool.py  slot-based ragged KV-cache pool
  scheduler.py   per-iteration batch former (retire / admit / decode)
  engine.py      the engine loop + transformer model adapter
  planner.py     star-network traffic split across heterogeneous replicas
"""

from .cache_pool import SlotCachePool, write_slot  # noqa: F401
from .engine import (EngineConfig, EngineReport, ServingEngine,  # noqa: F401
                     TransformerModel, serve_requests)
from .planner import (CapacityPlanner, DCN_LINK, ICI_LINK,  # noqa: F401
                      ReplicaPlan)
from .queue import AdmissionError, AdmissionLimits, RequestQueue  # noqa: F401
from .request import Request  # noqa: F401
from .scheduler import Scheduler, StepPlan  # noqa: F401
from .workload import synthetic_workload  # noqa: F401
