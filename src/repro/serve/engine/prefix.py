"""Prefix index: shared prompt pages for the paged KV plane.

Production traffic is dominated by shared prompt prefixes — system
prompts, few-shot templates, multi-turn history.  On the paged plane
(``cache_pool.PagedCachePool``) a prefix is a chain of FULL token pages
whose KV content is a pure function of the token ids that produced it,
so two requests whose prompts agree on the first ``k * page_size``
tokens can map those ``k`` logical pages onto the SAME physical pages.
This index is the map that makes the match: it keys each shareable page
by the exact byte string of every token from the start of the prompt up
to and including that page (a chain hash over token ids — two different
prefixes can never collide because the dict compares the full key), and
hands back the longest *materialized* chain of physical pages a new
prompt can attach to.

Lifecycle contract (enforced by the pool, property-tested):

  * ``register`` happens at admit time by the first request to bring a
    prefix in (the *creator*): the page is claimed privately and keyed,
    but stays **pending** — it holds no KV bytes yet.
  * ``materialize`` happens right after the creator's prefill dispatch
    wrote the page (``PagedCachePool.seal_prefilled``).  Only
    materialized pages are attachable: a same-step follower that admits
    before the creator's prefill ran claims private copies instead, so
    no request ever attaches to (or shares) a page that has not been
    written — and therefore no request ever *writes* a page whose
    refcount exceeds one.
  * ``evict`` happens when the last holder releases (refcount hits
    zero) and the physical page returns to the free list.  Index
    entries never outlive the pages they name, so the pool's
    conservation invariant (allocated == freed at drain) is untouched
    by sharing.

The index is pure host-side bookkeeping: matching is a dict walk over
token bytes, and nothing here adds a jitted dispatch.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np


def page_key(prompt: np.ndarray, page_index: int, page_size: int) -> bytes:
    """Identity of shareable page ``page_index``: the exact bytes of
    every prompt token up to and including that page.  Keying on the
    whole prefix (not just the page's own tokens) is what makes sharing
    sound — KV at position ``i`` depends on tokens ``0..i``, so a page
    is reusable only when the entire history that produced it matches.
    """
    end = (page_index + 1) * page_size
    return np.ascontiguousarray(prompt[:end], dtype=np.int32).tobytes()


class PrefixIndex:
    """Hash map from full-page token prefixes to physical page ids."""

    def __init__(self, page_size: int):
        assert page_size >= 1
        self.page_size = int(page_size)
        self._by_key: Dict[bytes, int] = {}       # prefix bytes -> page id
        self._key_of: Dict[int, bytes] = {}       # page id -> its key
        self._materialized: set = set()           # page ids holding real KV
        # counters (benchmark / regression-gate evidence)
        self.n_registered = 0
        self.n_hits = 0          # pages attached through a match
        self.n_evicted = 0

    def __len__(self) -> int:
        return len(self._by_key)

    def shareable_pages(self, prompt_len: int) -> int:
        """How many leading pages of a prompt are shareable: only pages
        the prompt fills COMPLETELY (a partial page mixes prompt and
        decode tokens, so its content is request-private)."""
        return prompt_len // self.page_size

    def match(self, prompt: np.ndarray) -> List[int]:
        """Longest materialized chain of indexed pages this prompt can
        attach to, as physical page ids (possibly empty).  The walk
        stops at the first miss — a later page can only be shared if
        every page before it is, because its key embeds the whole
        prefix."""
        out: List[int] = []
        for i in range(self.shareable_pages(prompt.shape[0])):
            page = self._by_key.get(page_key(prompt, i, self.page_size))
            if page is None or page not in self._materialized:
                break
            out.append(page)
        self.n_hits += len(out)
        return out

    def register(self, key: bytes, page: int) -> bool:
        """Claim the index slot for ``key`` with pending page ``page``.
        Returns False (and indexes nothing) if the key is already held —
        e.g. two creators of the same template admitted in one step; the
        loser's page simply stays private and unindexed."""
        if key in self._by_key:
            return False
        self._by_key[key] = page
        self._key_of[page] = key
        self.n_registered += 1
        return True

    def materialize(self, page: int) -> None:
        """Mark ``page`` as holding real KV bytes (its creator's prefill
        dispatch ran) — only from this moment may ``match`` return it."""
        if page in self._key_of:
            self._materialized.add(page)

    def is_indexed(self, page: int) -> bool:
        return page in self._key_of

    def evict(self, page: int) -> None:
        """Forget ``page`` (its refcount hit zero and it returned to the
        free list).  No-op for unindexed pages."""
        key = self._key_of.pop(page, None)
        if key is not None:
            del self._by_key[key]
            self._materialized.discard(page)
            self.n_evicted += 1
