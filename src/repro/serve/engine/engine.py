"""Continuous-batching serving engine.

One engine iteration = (retire, admit+prefill, one slot-batched decode
step).  Prefill runs per request at its exact prompt length (B=1, no
padding) and the resulting cache row is spliced into the slot pool;
decode runs once per iteration over the *whole* slot batch with per-row
token/position vectors, so requests at different depths share the step.
Inactive slots decode garbage rows that are simply never read — the jit
cost of a fixed batch shape buys a single decode compilation for the
engine's lifetime.

The decode loop never syncs with the device: per-slot token/position
state stays on device (inactive slots carry garbage that admission
overwrites), each step's next-token vector is appended to a trace, and
completion is detected by *count* (a request joins every decode batch
from admission until it has max_new tokens, so its tokens are consecutive
trace rows).  The trace is materialized once at drain — host round-trips
per served token would otherwise dominate small-model serving.

Under greedy decoding the engine is token-identical to per-request
``serve.step.greedy_generate`` (the reference oracle): decode attention
masks cache positions beyond each request's own depth, so neither the
shared (longer) cache length nor the co-batched neighbours change a
request's logits' argmax.

The engine is model-agnostic: anything with ``init_pool`` / ``prefill``
/ ``decode`` (see ``TransformerModel``) can serve, which is how the
scheduling-invariant property tests run against a tensor-free fake.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...models import transformer as T
from ...models.config import ModelConfig
from ...obs import clock as obs_clock
from ...obs.metrics import MetricsRegistry, throughput_summary
from ...obs.trace import NullTracer
from ...sharding.rules import Rules
from .cache_pool import PagedCachePool, SlotCachePool, write_slot
from .queue import AdmissionError, AdmissionLimits, RequestQueue
from .request import Request
from .scheduler import Scheduler

# fixed deterministic bucket edges for the TTFT histogram (seconds) —
# fixed edges keep per-replica histograms mergeable order-invariantly
TTFT_EDGES = (0.001, 0.003, 0.01, 0.03, 0.1, 0.3, 1.0, 3.0, 10.0)


class TransformerModel:
    """Adapter binding the engine to ``models.transformer`` serving steps.

    Every engine operation is ONE jitted dispatch — serving small models
    is dispatch-bound, so prefill fuses cache init + forward + argmax +
    slot splice + token-state update into a single call (compiled once
    per distinct prompt length; the slot index is traced), and decode
    fuses the position advance.  ``decode_multi`` runs k decode steps in
    one ``lax.scan`` dispatch (compiled once per k) for the drain phase.
    """

    def __init__(self, params, cfg: ModelConfig, rules: Rules):
        if cfg.family == "ssm":
            raise NotImplementedError(
                "ssm caches mix batch axes; the slot pool assumes batch "
                "axis 1 on every cache leaf")
        from ..step import make_decode_step
        self.params = params
        self.cfg = cfg
        self.rules = rules
        self._decode_step = make_decode_step(cfg, rules)

        def group_prefill(cache_len, params, tokens, lengths, slots, pool,
                          tok_vec, pos_vec):
            """Prefill B requests right-padded to one length, splice each
            row into its slot.  Valid because causal attention keeps pad
            positions out of real rows, and decode overwrites each pad
            cache entry before the position mask exposes it.

            ``cache_len`` is static (the pool's time length, recorded by
            init_pool) — it cannot be sniffed from pool leaf shapes, which
            for hybrid caches lead with the conv-state width."""
            B = tokens.shape[0]
            batch = T.init_cache(cfg, B, cache_len)
            batch, logits = T.prefill(params, cfg, rules, tokens, batch,
                                      last_index=lengths - 1)
            firsts = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for b in range(B):   # static unroll: B is a compile-time const
                row = jax.tree_util.tree_map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, b, 1, axis=1),
                    batch)
                pool = write_slot(pool, row, slots[b])
                tok_vec = jax.lax.dynamic_update_slice(
                    tok_vec, firsts[b:b + 1], (slots[b],))
                pos_vec = jax.lax.dynamic_update_slice(
                    pos_vec, lengths[b:b + 1], (slots[b],))
            return pool, firsts, tok_vec, pos_vec

        def decode1(params, tok, pos, cache):
            nxt, _, cache = self._decode_step(params, tok[:, None], pos,
                                              cache)
            return cache, nxt, nxt, pos + 1

        def decode_k(k):
            def run(params, tok, pos, cache):
                def body(carry, _):
                    tok, pos, cache = carry
                    nxt, _, cache = self._decode_step(params, tok[:, None],
                                                      pos, cache)
                    return (nxt, pos + 1, cache), nxt

                (tok, pos, cache), stack = jax.lax.scan(
                    body, (tok, pos, cache), None, length=k)
                return cache, stack, tok, pos
            return run

        self._group_prefill = jax.jit(group_prefill, static_argnums=0)
        self._cache_len = None            # recorded by init_pool
        self._decode1 = jax.jit(decode1)
        self._decode_k = {}
        self._decode_k_builder = decode_k
        # right-padded grouped prefill needs a purely causal stack: any
        # recurrent state (hybrid/ssm) or ring-windowed cache would absorb
        # the pad tokens, so those families prefill one request at a time.
        self.can_group_prefill = (cfg.family in ("dense", "moe")
                                  and cfg.window == 0)

    def init_pool(self, n_slots: int, cache_len: int):
        self._cache_len = int(cache_len)
        return T.init_cache(self.cfg, n_slots, cache_len)

    def token_state(self, n_slots: int):
        """Initial per-slot (token, position) decode inputs (on device)."""
        return jnp.zeros(n_slots, jnp.int32), jnp.zeros(n_slots, jnp.int32)

    def prefill(self, pool, prompts, slots, tok, pos):
        """Prefill a group of requests into their slots in ONE dispatch
        (right-padded to the group max; compiled once per (B, max_len)).

        Returns (pool, firsts (B,) device array, tok, pos) with every
        slot's token-state entries updated — no host sync.  Families that
        cannot pad (recurrent state) fall back to per-request calls.
        """
        if not self.can_group_prefill and len(prompts) > 1:
            firsts = []
            for prompt, slot in zip(prompts, slots):
                pool, f, tok, pos = self.prefill(pool, [prompt], [slot],
                                                 tok, pos)
                firsts.append(f)
            return pool, jnp.concatenate(firsts), tok, pos
        assert self._cache_len is not None, "init_pool must run first"
        B = len(prompts)
        lengths = np.array([p.shape[0] for p in prompts], np.int32)
        smax = int(lengths.max())
        batch = np.zeros((B, smax), np.int32)
        for b, p in enumerate(prompts):
            batch[b, :p.shape[0]] = p
        return self._group_prefill(self._cache_len, self.params,
                                   jnp.asarray(batch), jnp.asarray(lengths),
                                   jnp.asarray(np.asarray(slots, np.int32)),
                                   pool, tok, pos)

    def decode(self, pool, tok, pos):
        """One decode step over the full slot batch.

        Returns (pool, next (n_slots,), tok, pos) — the position advance
        is fused; nothing syncs with the host.
        """
        return self._decode1(self.params, tok, pos, pool)

    def decode_multi(self, pool, tok, pos, k: int):
        """k fused decode steps in one dispatch; next tokens stacked
        (k, n_slots).  Compiles once per distinct k (the engine buckets
        k to powers of two)."""
        if k == 1:
            pool, nxt, tok, pos = self.decode(pool, tok, pos)
            return pool, nxt[None], tok, pos
        if k not in self._decode_k:
            self._decode_k[k] = jax.jit(self._decode_k_builder(k))
        return self._decode_k[k](self.params, tok, pos, pool)


class PagedTransformerModel(TransformerModel):
    """Transformer adapter for the paged KV plane.

    Same dispatch discipline as the slot adapter — grouped prefill and
    every decode stretch are ONE jitted call — but the cache pytree is a
    physical page pool (``n_pages + 1`` pages of ``page_size`` token rows
    per layer; the extra page is the trash page) and every dispatch takes
    the host-maintained page table as an argument.  Gather/scatter via
    the table happens *inside* the jit (serve.step paged builders), so
    the paged plane adds zero dispatches over the slot plane.

    Restricted to purely-causal attention caches (dense/moe, no window):
    recurrent state mixes batch axes and ring-windowed caches wrap
    positions mod the window, neither of which pages cleanly.
    """

    def __init__(self, params, cfg: ModelConfig, rules: Rules):
        super().__init__(params, cfg, rules)
        if not self.can_group_prefill:
            raise NotImplementedError(
                "paged KV serving supports purely-causal attention caches "
                "(dense/moe families, window == 0); recurrent and "
                "windowed caches do not page cleanly")
        from ..step import make_paged_decode_scan, make_paged_decode_step
        from .cache_pool import scatter_page_view
        self._paged: Optional[PagedCachePool] = None

        def paged_group_prefill(view_len, params, tokens, lengths, slots,
                                tables, pool, tok_vec, pos_vec):
            """Prefill B requests right-padded to one length, scatter each
            row through its page table.  Unclaimed logical pages map to
            the trash page; claimed pages receive the freshly-initialized
            row (zero tail included), so no stale bytes from a previous
            page owner are ever visible below a request's depth."""
            B = tokens.shape[0]
            batch = T.init_cache(self.cfg, B, view_len)
            batch, logits = T.prefill(params, self.cfg, self.rules, tokens,
                                      batch, last_index=lengths - 1)
            firsts = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for b in range(B):   # static unroll: B is a compile-time const
                row = jax.tree_util.tree_map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, b, 1, axis=1),
                    batch)
                pool = scatter_page_view(pool, row, tables[b:b + 1])
                tok_vec = jax.lax.dynamic_update_slice(
                    tok_vec, firsts[b:b + 1], (slots[b],))
                pos_vec = jax.lax.dynamic_update_slice(
                    pos_vec, lengths[b:b + 1], (slots[b],))
            return pool, firsts, tok_vec, pos_vec

        step1 = make_paged_decode_step(self.cfg, rules)

        def paged_decode1(params, tok, pos, pool, table, write_table):
            nxt, _, pool = step1(params, tok[:, None], pos, pool, table,
                                 write_table)
            return pool, nxt, nxt, pos + 1

        self._paged_prefill = jax.jit(paged_group_prefill, static_argnums=0)
        self._paged_decode1 = jax.jit(paged_decode1)
        self._paged_decode_k = {}
        self._paged_scan_builder = (
            lambda k: make_paged_decode_scan(self.cfg, rules, k))

    def init_paged_pool(self, pool: PagedCachePool):
        """Bind the page allocator and build the device-side page pool:
        one batch row per physical page (+ the trash page)."""
        self._paged = pool
        return T.init_cache(self.cfg, pool.n_pages + 1, pool.page_size)

    def _tables(self):
        # snapshots, never aliases: on CPU jnp.asarray can be ZERO-COPY
        # over the host numpy buffer, and the allocator mutates the page
        # maps in place while the previous async dispatch may still be
        # reading them — without the copies the maps race the device
        return (jnp.asarray(self._paged.table.copy()),
                jnp.asarray(self._paged.write_table.copy()))

    def prefill(self, pool, prompts, slots, tok, pos):
        assert self._paged is not None, "init_paged_pool must run first"
        B = len(prompts)
        lengths = np.array([p.shape[0] for p in prompts], np.int32)
        batch = np.zeros((B, int(lengths.max())), np.int32)
        for b, p in enumerate(prompts):
            batch[b, :p.shape[0]] = p
        slots_np = np.asarray(slots, np.int32)
        # prefill scatters through the WRITE map: attached shared-prefix
        # pages are trash there, so a follower's recomputed prefix KV is
        # discarded and the creator's pages are never overwritten (the
        # fancy index copies — no alias of the live host map)
        tables = self._paged.write_table[slots_np]  # (B, pages_per_slot)
        return self._paged_prefill(self._paged.view_len, self.params,
                                   jnp.asarray(batch), jnp.asarray(lengths),
                                   jnp.asarray(slots_np),
                                   jnp.asarray(tables), pool, tok, pos)

    def decode(self, pool, tok, pos):
        table, write_table = self._tables()
        return self._paged_decode1(self.params, tok, pos, pool,
                                   table, write_table)

    def decode_multi(self, pool, tok, pos, k: int):
        if k == 1:
            pool, nxt, tok, pos = self.decode(pool, tok, pos)
            return pool, nxt[None], tok, pos
        if k not in self._paged_decode_k:
            self._paged_decode_k[k] = jax.jit(self._paged_scan_builder(k))
        table, write_table = self._tables()
        return self._paged_decode_k[k](self.params, tok, pos, pool,
                                       table, write_table)


class ManualClock:
    """Deterministic injectable clock for wall-clock arrival replay in
    tests: ``clock()`` reads the time, ``sleep`` advances it (the engine
    calls ``sleep`` when idle until the next arrival)."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        self.t += float(dt)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 8
    max_prompt_len: int = 64
    max_new_cap: int = 64
    max_queue: int = 4096
    max_prefill_per_step: int = 2
    cache_len: Optional[int] = None   # default: max_prompt_len + max_new_cap
    # paged KV plane: set page_size to gate admission on free pages
    # instead of free slots (n_slots then only caps decode-batch width)
    page_size: Optional[int] = None
    n_pages: Optional[int] = None     # default: n_slots * pages_per_slot
    # prefix sharing (paged plane only): requests whose prompts agree on
    # leading FULL pages share those physical pages (refcounted, CoW);
    # admission reserves shared + private instead of the worst case
    prefix_sharing: bool = False
    # arrival units: "steps" (engine iterations, the default) or
    # "seconds" (wall-clock replay against a monotonic clock)
    arrival_mode: str = "steps"

    @property
    def pool_len(self) -> int:
        return (self.cache_len if self.cache_len is not None
                else self.max_prompt_len + self.max_new_cap)

    @property
    def paged(self) -> bool:
        return self.page_size is not None

    @property
    def pages_per_slot(self) -> int:
        assert self.page_size is not None
        return -(-self.pool_len // self.page_size)

    @property
    def pool_pages(self) -> int:
        """Physical page budget (default: slot-pool-equivalent memory)."""
        return (self.n_pages if self.n_pages is not None
                else self.n_slots * self.pages_per_slot)


@dataclasses.dataclass
class EngineReport:
    completed: Dict[int, np.ndarray]       # rid -> generated tokens
    steps: int
    decode_steps: int
    prefill_count: int
    decode_tokens: int
    prefill_tokens: int
    occupancy: float                       # mean active/n_slots over decode steps
    ttft: Dict[int, float]                 # rid -> seconds to first token
    wall: float
    prefill_wall: float
    decode_wall: float
    page_occupancy: float = 0.0            # mean used/total pages (paged only)

    @property
    def total_tokens(self) -> int:
        # every completed request's first token came from its prefill
        return self.decode_tokens + len(self.completed)

    @property
    def tokens_per_sec(self) -> float:
        return self.total_tokens / max(self.wall, 1e-9)

    @property
    def decode_tokens_per_sec(self) -> float:
        return self.decode_tokens / max(self.decode_wall, 1e-9)

    @property
    def ttft_mean(self) -> float:
        return float(np.mean(list(self.ttft.values()))) if self.ttft else 0.0

    def as_dict(self) -> Dict[str, float]:
        """Benchmark-facing view via the ONE metric derivation
        (``obs.metrics.throughput_summary``) — benchmarks read this dict
        instead of re-deriving tok/s / TTFT / occupancy themselves, so
        bench-vs-engine metric skew is impossible by construction."""
        out = throughput_summary(
            useful_tokens=self.total_tokens, wall_s=self.wall,
            ttfts_s=self.ttft.values(),
            occupancy_sum=self.occupancy * self.decode_steps,
            decode_steps=self.decode_steps,
            decode_tokens=self.decode_tokens,
            decode_wall_s=self.decode_wall)
        out.update(steps=self.steps, prefill_count=self.prefill_count,
                   n_completed=len(self.completed),
                   page_occupancy=self.page_occupancy)
        return out


class ServingEngine:
    def __init__(self, model, config: EngineConfig = EngineConfig(),
                 clock=None, tracer=None, metrics=None,
                 name: str = "engine"):
        if config.arrival_mode not in ("steps", "seconds"):
            raise ValueError(
                f"arrival_mode must be 'steps' or 'seconds', got "
                f"{config.arrival_mode!r}")
        self.model = model
        self.config = config
        self.name = name
        # observability plane (host-side only — hooks never add a jitted
        # dispatch; the NullTracer default makes every hook one no-op)
        self.tracer = tracer if tracer is not None else NullTracer()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.queue = RequestQueue(AdmissionLimits(
            max_prompt_len=config.max_prompt_len,
            max_new_cap=config.max_new_cap,
            max_queue=config.max_queue,
            max_total_len=config.pool_len))
        if config.paged:
            if not hasattr(model, "init_paged_pool"):
                raise TypeError(
                    "page_size is set but the model adapter has no "
                    "init_paged_pool — use PagedTransformerModel (or a "
                    "paged-capable fake) for the paged KV plane")
            self.pool = PagedCachePool(
                n_pages=config.pool_pages, page_size=config.page_size,
                n_slots=config.n_slots,
                pages_per_slot=config.pages_per_slot,
                share_prefixes=config.prefix_sharing)
            self.cache = model.init_paged_pool(self.pool)
        elif config.prefix_sharing:
            raise ValueError(
                "prefix_sharing requires the paged KV plane — set "
                "page_size (slot rows have no page granularity to share)")
        else:
            self.pool = SlotCachePool(config.n_slots)
            self.cache = model.init_pool(config.n_slots, config.pool_len)
        self.scheduler = Scheduler(self.queue, self.pool,
                                   config.max_prefill_per_step,
                                   metrics=self.metrics)
        self._tok, self._pos = model.token_state(config.n_slots)
        self._trace = []                  # (k_i, n_slots) next-token blocks
        self._rows = 0                    # total trace rows so far
        self.completed: Dict[int, Request] = {}
        # incremental drain state (the fleet plane's step-callable surface):
        # host-side copies of the trace, fetched block-by-block on demand
        self.results: Dict[int, np.ndarray] = {}   # harvested tokens
        self._host_trace = np.zeros((0, config.n_slots), np.int32)
        self._fetched_blocks = 0
        self._firsts_cache: Dict[int, np.ndarray] = {}
        self.steps = 0
        self.clock = 0.0
        # wall-clock arrival replay: arrivals are seconds on an injectable
        # monotonic clock (tests pass ManualClock; the default comes from
        # obs.clock, the one sanctioned home of wall-clock reads)
        self._wall_arrivals = config.arrival_mode == "seconds"
        self._clock_fn = clock if clock is not None else obs_clock.monotonic
        self._clock_t0: Optional[float] = None
        # timeline adoption: if the tracer has no clock yet, this engine's
        # arrival clock becomes the timeline (a fleet controller built
        # later overrides it with its tick counter — last owner wins)
        if self.tracer.enabled and self.tracer.clock is None:
            self.tracer.use_clock(lambda: self.clock)
        self._stats = dict(decode_steps=0, prefill_count=0, decode_tokens=0,
                           prefill_tokens=0, occupancy_sum=0.0,
                           prefill_wall=0.0, decode_wall=0.0,
                           page_occupancy_sum=0.0)

    def _now(self) -> float:
        """Engine time in arrival units (seconds since run start in
        wall-clock mode; the iteration counter otherwise)."""
        if self._clock_t0 is None:
            self._clock_t0 = self._clock_fn()
        return self._clock_fn() - self._clock_t0

    def _sleep(self, dt: float) -> None:
        sleep = getattr(self._clock_fn, "sleep", time.sleep)
        sleep(dt)

    def submit(self, prompt, max_new: int, arrival: float = 0.0) -> int:
        try:
            req = self.queue.submit(prompt, max_new, arrival)
        except AdmissionError as e:
            self.metrics.counter("admission_rejections",
                                 reason=e.reason).inc()
            raise
        self.metrics.counter("requests_submitted").inc()
        # queue-wait span: opened at submit, closed when the scheduler
        # admits the request (a keyed cross-step span).  Keys carry the
        # engine name: fleet replicas share one tracer and local rids
        # collide across engines.
        self.tracer.begin("queue_wait", track=self.name,
                          lane=f"req:{req.rid}",
                          key=("qw", self.name, req.rid),
                          rid=req.rid, arrival=req.arrival)
        return req.rid

    @property
    def has_work(self) -> bool:
        """Anything queued or in flight (``step()`` would do work)."""
        return self.scheduler.has_work

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One engine iteration; returns False when fully drained."""
        if not self.scheduler.has_work:
            return False
        self.steps += 1
        if self._wall_arrivals:
            self.clock = self._now()
        now, wall = self.clock, time.perf_counter()
        self.queue.mark_eligible(now, wall)
        plan = self.scheduler.plan(now)
        if not (plan.retired or plan.admit or self.scheduler.active):
            # nothing in flight and nothing eligible: fast-forward the
            # clock to the next arrival instead of spinning no-op steps
            # (in wall-clock mode: actually wait on the injected clock)
            nxt = self.queue.next_arrival()
            if nxt is not None and nxt > self.clock:
                if self._wall_arrivals:
                    self._sleep(nxt - self.clock)
                    self.clock = self._now()
                else:
                    self.clock = float(nxt)
                return True
        for r in plan.retired:
            r.finish_wall = r.finish_wall or wall
            self.completed[r.rid] = r
            # close the residency span opened at admit
            self.tracer.end(("req", self.name, r.rid), tokens=r.max_new)
            self.tracer.event("retire", track=self.name,
                              lane=f"req:{r.rid}", rid=r.rid)

        if plan.admit:
            for r in plan.admit:
                self.tracer.end(("qw", self.name, r.rid))
                self.tracer.begin("serve", track=self.name,
                                  lane=f"req:{r.rid}",
                                  key=("req", self.name, r.rid),
                                  rid=r.rid, prompt_len=r.prompt_len,
                                  max_new=r.max_new, slot=r.slot)
            pf_key = self.tracer.begin("prefill", track=self.name,
                                       lane="engine", n=len(plan.admit))
            t0 = time.perf_counter()
            self.cache, firsts, self._tok, self._pos = self.model.prefill(
                self.cache, [r.prompt for r in plan.admit],
                [r.slot for r in plan.admit], self._tok, self._pos)
            if hasattr(firsts, "block_until_ready"):
                firsts.block_until_ready()  # TTFT is a real latency metric
            t1 = time.perf_counter()
            for b, r in enumerate(plan.admit):
                r.first_token = (firsts, b)   # sliced lazily at drain
                r.n_generated = 1
                r.trace_start = self._rows
                r.trace_slot = r.slot
                r.eligible_wall = (t0 if r.eligible_wall is None
                                   else r.eligible_wall)
                r.first_token_wall = t1
                self._stats["prefill_tokens"] += r.prompt_len
                # TTFT lands in the metrics plane as an observed value
                # (wall seconds never enter the trace timeline)
                self.metrics.histogram("ttft_s", TTFT_EDGES).observe(
                    r.first_token_wall - r.eligible_wall)
            self._stats["prefill_count"] += len(plan.admit)
            self._stats["prefill_wall"] += t1 - t0
            self.tracer.end(pf_key)
            self.metrics.counter("prefill_tokens").inc(
                sum(r.prompt_len for r in plan.admit))
            # the prefill dispatch above wrote these requests' prompt
            # pages: publish the shareable ones (materialize their index
            # entries and write-protect them) BEFORE any decode runs —
            # from the next scheduler step on, followers attach instead
            # of claiming.  No-op without prefix sharing / on slot pools.
            self.pool.seal_prefilled(plan.admit)

        # the decode batch was planned BEFORE prefill handed max_new == 1
        # admits their first (and only) token — drop the already-done ones
        # so budget math (k, page growth, token accounting) can't overshoot
        live = [r for r in plan.decode if not r.done]
        if live:
            # decode fusion: when nothing was admitted this step AND no
            # admission can happen before the next retirement (queue empty,
            # or every slot busy), the next k iterations are pure decode —
            # run them as ONE dispatch.  k is the smallest remaining budget
            # among in-flight requests (nobody overshoots and the next
            # retirement lands exactly at the call boundary), bucketed to
            # a power of two to bound compilations.
            k = 1
            if not plan.admit and (len(self.queue) == 0
                                   or self.pool.free_count == 0):
                k = min(r.max_new - r.n_generated for r in live)
                k = 1 << max(0, k.bit_length() - 1)
            # paged plane: claim every page the next k steps will write
            # BEFORE the dispatch (the page map is an argument of the
            # fused call); reservations make the claims infallible
            self.pool.prepare_decode(live, k)
            dk_key = self.tracer.begin("decode", track=self.name,
                                       lane="engine", k=k, batch=len(live))
            t0 = time.perf_counter()
            self.cache, rows, self._tok, self._pos = self.model.decode_multi(
                self.cache, self._tok, self._pos, k)
            self._trace.append(rows)       # (k, n_slots)
            self._rows += k
            for r in live:
                r.n_generated += k
            t1 = time.perf_counter()
            self._stats["decode_steps"] += k
            self._stats["decode_tokens"] += k * len(live)
            self._stats["occupancy_sum"] += (k * len(live)
                                             / self.config.n_slots)
            if isinstance(self.pool, PagedCachePool):
                self._stats["page_occupancy_sum"] += (
                    k * self.pool.used_pages / self.pool.n_pages)
            self._stats["decode_wall"] += t1 - t0
            self.metrics.counter("decode_tokens").inc(k * len(live))
        if not self._wall_arrivals:   # wall mode reads the clock per step
            self.clock += float(max(k, 1) if live else 1)
        if live:
            # close after the clock advance so a fused k-step decode spans
            # k ticks on the trace timeline
            self.tracer.end(dk_key)
        # end-of-step gauges: queue depth + pool occupancy (host state the
        # loop already owns — no device sync, no extra dispatch)
        self.metrics.gauge("queue_depth").set(len(self.queue))
        self.metrics.gauge("pool_occupancy").set(self.pool.occupancy)
        self.tracer.counter("queue_depth", len(self.queue), track=self.name)
        return True

    # -- host materialization (incremental: the fleet drain surface) ----
    def _trace_upto(self, rows: int) -> np.ndarray:
        """Host trace covering at least ``rows`` rows: fetch every
        still-on-device block in ONE transfer when the prefix is short
        (blocks are append-only, so earlier fetches stay valid)."""
        if self._host_trace.shape[0] < rows:
            pend = self._trace[self._fetched_blocks:]
            if pend:
                got = np.asarray(jax.device_get(
                    jnp.concatenate(pend) if len(pend) > 1 else pend[0]))
                self._host_trace = np.concatenate([self._host_trace, got])
                self._fetched_blocks = len(self._trace)
        return self._host_trace

    def _firsts(self, r: Request) -> np.ndarray:
        """The request's prefill token, from its admit group's argmax
        vector (one transfer per group, cached for the engine's life —
        the group array stays referenced by its requests, so ``id`` keys
        cannot be recycled under us)."""
        firsts, b = r.first_token
        group = self._firsts_cache.get(id(firsts))
        if group is None:
            group = self._firsts_cache[id(firsts)] = np.asarray(
                jax.device_get(firsts))
        return group[b:b + 1]

    def harvest(self) -> Dict[int, np.ndarray]:
        """Materialize tokens of requests completed since the last call.

        The fleet controller's per-tick drain: only newly completed
        requests are sliced (and only the trace blocks they need are
        fetched), results accumulate in ``self.results``, and the return
        value carries just the NEW ones — calling this every tick costs
        nothing when nothing finished.
        """
        out: Dict[int, np.ndarray] = {}
        for rid, r in self.completed.items():
            if rid in self.results:
                continue
            trace = self._trace_upto(r.trace_start + r.max_new - 1)
            dec = trace[r.trace_start:r.trace_start + r.max_new - 1,
                        r.trace_slot]
            assert dec.shape[0] == r.max_new - 1, (rid, dec.shape, r.max_new)
            r.tokens = np.concatenate([self._firsts(r), dec]).astype(np.int32)
            out[rid] = r.tokens
        self.results.update(out)
        return out

    def tokens_so_far(self, rid: int) -> np.ndarray:
        """Host view of what ``rid`` has generated so far (the streaming
        surface; syncs with the device up to the request's depth).
        Empty for queued/unknown rids."""
        r = self.completed.get(rid)
        if r is None:
            r = self.scheduler.active.get(rid)
        if r is None or r.first_token is None:
            return np.zeros(0, np.int32)
        if r.tokens is not None:
            return r.tokens
        n_dec = min(r.n_generated, r.max_new) - 1
        trace = self._trace_upto(r.trace_start + n_dec)
        dec = trace[r.trace_start:r.trace_start + n_dec, r.trace_slot]
        return np.concatenate([self._firsts(r), dec]).astype(np.int32)

    def shed_queued(self, n: int) -> List[Request]:
        """Give up ``n`` still-QUEUED requests, latest-arrival first — the
        work-stealing shed surface.  Only un-admitted requests are
        sheddable: they have generated zero tokens, so requeuing them on
        another replica preserves the greedy oracle byte-for-byte.  Their
        queue-wait spans are closed ``outcome="stolen"`` (the thief opens
        a fresh one on its own track)."""
        victims = self.queue.steal_latest(n)
        for r in victims:
            self.tracer.end(("qw", self.name, r.rid), outcome="stolen")
        return victims

    def outstanding(self) -> List[Request]:
        """Every request whose tokens are NOT yet harvested to the host:
        queued, in flight, and completed-but-unharvested, in rid order.
        This is the failover set — what a dead replica still owes."""
        queued = self.queue.pending()
        active = [self.scheduler.active[rid]
                  for rid in sorted(self.scheduler.active)]
        unharvested = [r for rid, r in sorted(self.completed.items())
                       if rid not in self.results]
        return sorted(queued + active + unharvested, key=lambda r: r.rid)

    def progress(self) -> Dict[str, float]:
        """Cheap host-side stats snapshot (fleet replicas never ``run()``
        to completion, so occupancy must be readable mid-flight)."""
        s = self._stats
        occ = (s["occupancy_sum"] / s["decode_steps"]
               if s["decode_steps"] else 0.0)
        return dict(steps=self.steps, decode_steps=s["decode_steps"],
                    decode_tokens=s["decode_tokens"],
                    prefill_count=s["prefill_count"], occupancy=occ,
                    n_queued=len(self.queue),
                    n_active=len(self.scheduler.active),
                    n_completed=len(self.completed),
                    n_rejected=self.queue.n_rejected,
                    pool_occupancy=self.pool.occupancy)

    def _materialize(self) -> Dict[int, np.ndarray]:
        """Pull the step trace from device and slice per request."""
        self.harvest()
        return dict(self.results)

    def run(self, max_steps: Optional[int] = None) -> EngineReport:
        """Drive until drained; returns the report for this run."""
        t_start = time.perf_counter()
        n = 0
        while self.step():
            n += 1
            if max_steps is not None and n >= max_steps:
                break
        completed = self._materialize()   # blocks on all in-flight work
        wall = time.perf_counter() - t_start
        if max_steps is None:
            assert self.pool.drained, "drained engine still holds slots"
            assert self.pool.n_allocated == self.pool.n_freed, (
                self.pool.n_allocated, self.pool.n_freed)
        s = self._stats
        ttft = {r.rid: (r.first_token_wall - r.eligible_wall)
                for r in self.completed.values()
                if r.first_token_wall is not None
                and r.eligible_wall is not None}
        occ = (s["occupancy_sum"] / s["decode_steps"]
               if s["decode_steps"] else 0.0)
        pocc = (s["page_occupancy_sum"] / s["decode_steps"]
                if s["decode_steps"] else 0.0)
        return EngineReport(
            completed=completed,
            steps=n, decode_steps=s["decode_steps"],
            prefill_count=s["prefill_count"],
            decode_tokens=s["decode_tokens"],
            prefill_tokens=s["prefill_tokens"],
            occupancy=occ, ttft=ttft, wall=wall,
            prefill_wall=s["prefill_wall"], decode_wall=s["decode_wall"],
            page_occupancy=pocc)


def serve_requests(params, cfg: ModelConfig, rules: Rules, requests,
                   n_slots: int = 8, max_prefill_per_step: int = 2,
                   page_size: Optional[int] = None,
                   n_pages: Optional[int] = None,
                   prefix_sharing: bool = False) -> EngineReport:
    """Convenience one-shot: serve [(prompt, max_new, arrival), ...].

    ``page_size`` switches to the paged KV plane (``n_pages`` defaults to
    slot-pool-equivalent memory); ``prefix_sharing`` additionally maps
    matching prompt prefixes onto shared pages — outputs must be
    token-identical in every mode.
    """
    reqs = [(np.asarray(p, np.int32).reshape(-1), int(m), float(a))
            for p, m, a in requests]
    max_len = max(p.shape[0] + m for p, m, _ in reqs)
    ec = EngineConfig(n_slots=n_slots,
                      max_prompt_len=max(p.shape[0] for p, _, _ in reqs),
                      max_new_cap=max(m for _, m, _ in reqs),
                      cache_len=max_len,
                      max_prefill_per_step=max_prefill_per_step,
                      page_size=page_size, n_pages=n_pages,
                      prefix_sharing=prefix_sharing)
    model_cls = PagedTransformerModel if ec.paged else TransformerModel
    # engines are built through the fleet plane's factory (CI grep-gates
    # direct ServingEngine construction outside repro.fleet and launch/);
    # imported lazily because fleet imports this module
    from ...fleet.replica import build_engine
    eng = build_engine(model_cls(params, cfg, rules), ec)
    for p, m, a in reqs:
        eng.submit(p, m, arrival=a)
    return eng.run()
