"""Synthetic staggered-arrival workload generator.

One generator for every serving surface (benchmark, launcher demo,
example, tests) so the trace model — seeded mixed prompt/max-new lengths,
arrival i * stagger in engine-clock units — cannot drift between them.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def synthetic_workload(n: int, vocab: int, *,
                       lens: Sequence[int] = (8, 16, 24, 32),
                       news: Sequence[int] = (4, 8, 12, 16),
                       stagger: float = 0.5,
                       seed: int = 0
                       ) -> List[Tuple[np.ndarray, int, float]]:
    """[(prompt (S,) int32, max_new, arrival), ...] for n requests."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        S = int(rng.choice(list(lens)))
        m = int(rng.choice(list(news)))
        out.append((rng.integers(0, vocab, S, dtype=np.int64)
                    .astype(np.int32), m, float(i) * stagger))
    return out


def shared_prefix_workload(n: int, vocab: int, *,
                           n_templates: int = 4,
                           template_len: int = 16,
                           suffix_lens: Sequence[int] = (4, 8, 12),
                           news: Sequence[int] = (4, 8, 12, 16),
                           stagger: float = 0.5,
                           seed: int = 0
                           ) -> List[Tuple[np.ndarray, int, float]]:
    """Template-heavy trace: each prompt = one of ``n_templates`` fixed
    system-prompt templates (``template_len`` tokens, round-robin over
    requests) + a per-request random suffix.  This is the production
    shape prefix sharing targets: requests agreeing on their leading
    tokens can map those pages onto shared physical pages.  Suffixes are
    drawn from ``[1, vocab)`` with the templates from ``[0, vocab)`` —
    sharing must come from REAL prefix matches, not suffix collisions
    (a colliding suffix page key would need the whole prefix to match
    anyway; this just keeps the trace's sharing structure legible)."""
    rng = np.random.default_rng(seed)
    templates = [rng.integers(0, vocab, template_len, dtype=np.int64)
                 .astype(np.int32) for _ in range(n_templates)]
    out = []
    for i in range(n):
        t = templates[i % n_templates]
        S = int(rng.choice(list(suffix_lens)))
        suffix = rng.integers(1, vocab, S, dtype=np.int64).astype(np.int32)
        m = int(rng.choice(list(news)))
        out.append((np.concatenate([t, suffix]), m, float(i) * stagger))
    return out
