"""Synthetic staggered-arrival workload generator.

One generator for every serving surface (benchmark, launcher demo,
example, tests) so the trace model — seeded mixed prompt/max-new lengths,
arrival i * stagger in engine-clock units — cannot drift between them.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def synthetic_workload(n: int, vocab: int, *,
                       lens: Sequence[int] = (8, 16, 24, 32),
                       news: Sequence[int] = (4, 8, 12, 16),
                       stagger: float = 0.5,
                       seed: int = 0
                       ) -> List[Tuple[np.ndarray, int, float]]:
    """[(prompt (S,) int32, max_new, arrival), ...] for n requests."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        S = int(rng.choice(list(lens)))
        m = int(rng.choice(list(news)))
        out.append((rng.integers(0, vocab, S, dtype=np.int64)
                    .astype(np.int32), m, float(i) * stagger))
    return out
