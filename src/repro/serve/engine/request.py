"""Request lifecycle datatypes for the continuous-batching serving engine.

A ``Request`` moves QUEUED -> PREFILL -> DECODE -> FINISHED.  The first
generated token comes out of the prefill logits (so a ``max_new == 1``
request never enters decode); the remaining ``max_new - 1`` come from the
slot-batched decode step, one per engine iteration.

Arrival times are in *engine-clock* units (one unit per engine iteration):
a request is eligible for admission once ``clock >= arrival``.  Wall-clock
timestamps (for time-to-first-token reporting) are tracked separately by
the engine.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

QUEUED = "QUEUED"
PREFILL = "PREFILL"
DECODE = "DECODE"
FINISHED = "FINISHED"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32 token ids
    max_new: int                 # tokens to generate (>= 1)
    arrival: float = 0.0         # engine-clock units
    state: str = QUEUED
    slot: Optional[int] = None   # cache-pool slot while in flight
    n_generated: int = 0
    # async decode bookkeeping: tokens live on device until drain.  The
    # first token is the prefill argmax; decode tokens are rows
    # [trace_start, trace_start + max_new - 1) of the engine's step trace
    # at column ``trace_slot`` (a request joins every decode batch from
    # admission to completion, so its rows are consecutive).
    first_token: Optional[Any] = None       # (group_array, row) pair
    trace_start: Optional[int] = None
    trace_slot: Optional[int] = None
    tokens: Optional[np.ndarray] = None     # materialized at drain
    # wall-clock bookkeeping (engine-owned)
    eligible_wall: Optional[float] = None   # first moment clock >= arrival
    first_token_wall: Optional[float] = None
    finish_wall: Optional[float] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, dtype=np.int32).reshape(-1)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return self.n_generated >= self.max_new
