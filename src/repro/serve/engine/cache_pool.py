"""Slot-based ragged KV-cache pool.

The engine's cache is one pytree with ``n_slots`` rows on the batch axis
(axis 1 for every cache leaf in the dense/moe/hybrid families — the ssm
family mixes batch axes and is rejected by the model adapter).  A *slot*
is one row; a request owns exactly one slot from prefill to retirement.

``SlotCachePool`` is pure bookkeeping — slot ids, a free list, and the
conservation counters the property tests check (``n_allocated ==
n_freed`` once drained).  The tensor side is the two functions below:
``write_slot`` splices a freshly prefilled single-request cache into the
pool (overwriting the whole row, so no stale bytes from the previous
occupant survive), and the pool tree itself is threaded functionally
through the jitted decode step.
"""

from __future__ import annotations

import heapq
from typing import List, Tuple

import jax

BATCH_AXIS = 1  # cache-leaf batch axis for the supported families


class SlotCachePool:
    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.n_slots = int(n_slots)
        # min-heap: allocate() hands out the LOWEST free slot (test-pinned)
        # in O(log n) — the old sorted list paid an O(n) shift per pop(0)
        # and an O(n log n) re-sort per free
        self._free: List[int] = list(range(n_slots))
        self._used: set = set()
        self.n_allocated = 0
        self.n_freed = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return len(self._used)

    @property
    def drained(self) -> bool:
        return not self._used

    def active_slots(self) -> Tuple[int, ...]:
        return tuple(sorted(self._used))

    def allocate(self) -> int:
        if not self._free:
            raise RuntimeError("cache pool exhausted: no free slots")
        slot = heapq.heappop(self._free)
        self._used.add(slot)
        self.n_allocated += 1
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._used:
            raise RuntimeError(f"slot {slot} is not allocated")
        self._used.remove(slot)
        heapq.heappush(self._free, slot)
        self.n_freed += 1


def write_slot(pool_tree, request_tree, slot: int):
    """Splice a single-request cache (batch dim 1) into pool row ``slot``.

    Every leaf is written whole, including its zero tail beyond the
    prompt, so the slot carries no state from a previous occupant.
    """
    return jax.tree_util.tree_map(
        lambda pool, one: jax.lax.dynamic_update_slice_in_dim(
            pool, one.astype(pool.dtype), slot, axis=BATCH_AXIS),
        pool_tree, request_tree)
