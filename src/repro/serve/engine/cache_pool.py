"""KV-cache pools: whole-row slots and fixed-size token pages.

Two bookkeeping planes share one admission interface (``can_admit`` /
``admit`` / ``release`` / ``prepare_decode``) so the scheduler and engine
are pool-agnostic:

``SlotCachePool`` — the original plane: the cache is one pytree with
``n_slots`` batch rows; a request owns one whole row from prefill to
retirement.  Admission is gated on free *slots*.

``PagedCachePool`` — the paged plane: the device cache is a pool of
``n_pages`` fixed-size token pages (``page_size`` rows each, one physical
page axis per cache leaf) plus one reserved *trash* page, and each live
request holds a page *table* mapping its logical pages to physical ones.
A request's cache can therefore span non-contiguous fragments, and
admission is gated on free **pages**, not free slots:

  * admission reserves the request's worst-case page count
    (``ceil((prompt_len + max_new - 1) / page_size)``) so decode growth
    can never be starved mid-flight (preemption-free reservation);
  * prefill claims only the pages the prompt needs; decode claims more
    lazily (*grow-on-decode*), structurally bounded by the reservation;
  * unclaimed logical pages point at the trash page, so whole-view
    scatters are always well-defined (the trash page absorbs garbage
    rows that are never read back — decode attention masks positions
    beyond each request's depth).

Both pools are pure id bookkeeping with conservation counters
(``n_allocated == n_freed`` once drained, property-tested).  The tensor
side lives in the helper functions: ``write_slot`` splices a prefilled
row into the slot pool; ``gather_page_view`` / ``scatter_page_view``
translate between the physical page pool and the per-slot contiguous
*view* the decode math runs on (one gather + one scatter inside the same
jitted dispatch, so the step count stays identical to the slot plane).
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Tuple

import jax
import numpy as np

BATCH_AXIS = 1  # cache-leaf batch axis for the supported families


class SlotCachePool:
    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.n_slots = int(n_slots)
        # min-heap: allocate() hands out the LOWEST free slot (test-pinned)
        # in O(log n) — the old sorted list paid an O(n) shift per pop(0)
        # and an O(n log n) re-sort per free
        self._free: List[int] = list(range(n_slots))
        self._used: set = set()
        self.n_allocated = 0
        self.n_freed = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return len(self._used)

    @property
    def drained(self) -> bool:
        return not self._used

    @property
    def occupancy(self) -> float:
        """Instantaneous used fraction (the metrics-plane gauge)."""
        return len(self._used) / self.n_slots

    def active_slots(self) -> Tuple[int, ...]:
        return tuple(sorted(self._used))

    def allocate(self) -> int:
        if not self._free:
            raise RuntimeError("cache pool exhausted: no free slots")
        slot = heapq.heappop(self._free)
        self._used.add(slot)
        self.n_allocated += 1
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._used:
            raise RuntimeError(f"slot {slot} is not allocated")
        self._used.remove(slot)
        heapq.heappush(self._free, slot)
        self.n_freed += 1

    # ---- pool-agnostic admission interface (scheduler/engine) ----------
    def can_admit(self, request) -> bool:
        return self.free_count > 0

    def admit(self, request) -> int:
        return self.allocate()

    def release(self, request) -> None:
        self.free(request.slot)

    def prepare_decode(self, requests, k: int) -> None:
        """Slot rows are whole — nothing to claim before a decode batch."""


class PagedCachePool:
    """Page allocator + per-request page tables for the paged KV plane.

    ``table`` is the host-side (numpy) page map, shape
    ``(n_slots, pages_per_slot)`` int32: row = decode-batch slot, column =
    logical page index, value = physical page id (``trash_page`` when
    unclaimed).  The engine pushes it to device as an argument of every
    jitted dispatch — values change per step, shapes never do.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 pages_per_slot: int):
        assert n_pages >= 1 and page_size >= 1
        assert n_slots >= 1 and pages_per_slot >= 1
        # a pool smaller than one slot's view could never admit a
        # worst-case request: the engine would spin forever un-admitting
        assert n_pages >= pages_per_slot, (
            f"n_pages={n_pages} < pages_per_slot={pages_per_slot}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.n_slots = int(n_slots)
        self.pages_per_slot = int(pages_per_slot)
        # free-page STACK (LIFO), not a heap: page identity is
        # interchangeable (the table indirection absorbs any order), so
        # claims are O(1) pops off the end instead of O(log n) sifts —
        # the allocator sits on the per-decode-step path via
        # prepare_decode.  Seeded descending so the first claims still
        # hand out low page ids.  Rows stay a min-heap: slot order is
        # test-pinned.
        self._free_pages: List[int] = list(range(n_pages - 1, -1, -1))
        self._free_rows: List[int] = list(range(n_slots))
        # rid -> (slot, reserved page count, claimed physical page list)
        self._live: Dict[int, Tuple[int, int, List[int]]] = {}
        self._reserved_total = 0
        self.table = np.full((n_slots, pages_per_slot), self.trash_page,
                             np.int32)
        # rid -> final claimed page tuple, recorded at release (tests and
        # benchmarks assert fragmentation: requests span non-contiguous
        # physical pages)
        self.page_history: Dict[int, Tuple[int, ...]] = {}
        self.n_allocated = 0   # pages claimed (conservation counters)
        self.n_freed = 0       # pages returned

    @property
    def trash_page(self) -> int:
        """Reserved physical page absorbing writes from inactive slots and
        unclaimed logical pages (index ``n_pages``: one past the real
        pool, so leaves carry ``n_pages + 1`` physical pages)."""
        return self.n_pages

    @property
    def view_len(self) -> int:
        return self.pages_per_slot * self.page_size

    @property
    def free_page_count(self) -> int:
        return len(self._free_pages)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free_pages)

    @property
    def reserved_pages(self) -> int:
        return self._reserved_total

    @property
    def free_count(self) -> int:
        """Admittable-request lower bound (kept for engine fast-paths):
        0 when no row or no unreserved page remains."""
        if not self._free_rows:
            return 0
        return max(0, self.n_pages - self._reserved_total)

    @property
    def used_count(self) -> int:
        return len(self._live)

    @property
    def drained(self) -> bool:
        return not self._live

    @property
    def occupancy(self) -> float:
        """Instantaneous used-page fraction (the metrics-plane gauge)."""
        return self.used_pages / self.n_pages

    def pages_needed(self, prompt_len: int, max_new: int) -> int:
        """Worst-case pages a request can ever hold: prompt positions plus
        the ``max_new - 1`` decode writes (the final token is returned but
        never written back)."""
        tokens = prompt_len + max(max_new - 1, 0)
        return -(-tokens // self.page_size)

    def prefill_pages(self, prompt_len: int) -> int:
        return -(-prompt_len // self.page_size)

    def live_pages(self, rid: int) -> Tuple[int, ...]:
        return tuple(self._live[rid][2])

    def _claim_one(self, rid: int) -> int:
        slot, reserved, pages = self._live[rid]
        if len(pages) >= reserved:
            raise RuntimeError(
                f"request {rid} grew past its reservation of {reserved} "
                f"pages — admission must reserve the worst-case decode "
                f"length")
        if not self._free_pages:
            raise RuntimeError(
                "page pool exhausted despite reservations — allocator "
                "invariant broken (claimed pages must never exceed the "
                "reserved total)")
        page = self._free_pages.pop()
        pages.append(page)
        self.table[slot, len(pages) - 1] = page
        self.n_allocated += 1
        return page

    # ---- pool-agnostic admission interface -----------------------------
    def can_admit(self, request) -> bool:
        """Free decode row AND enough unreserved pages for the request's
        worst case.  Reserving up front is what makes the plane
        preemption-free: grow-on-decode can never fail mid-flight."""
        if not self._free_rows:
            return False
        need = self.pages_needed(request.prompt_len, request.max_new)
        if need > self.pages_per_slot:
            raise RuntimeError(
                f"request needs {need} pages but a slot's view holds only "
                f"{self.pages_per_slot} — admission control must bound "
                f"prompt_len + max_new to the configured cache length")
        return self._reserved_total + need <= self.n_pages

    def admit(self, request) -> int:
        if not self.can_admit(request):
            raise RuntimeError("page pool cannot admit this request")
        slot = heapq.heappop(self._free_rows)
        need = self.pages_needed(request.prompt_len, request.max_new)
        self._reserved_total += need
        self._live[request.rid] = (slot, need, [])
        for _ in range(self.prefill_pages(request.prompt_len)):
            self._claim_one(request.rid)
        return slot

    def grow_to(self, rid: int, n_tokens: int) -> None:
        """Claim pages until the request's claimed region covers
        ``n_tokens`` cache positions (grow-on-decode)."""
        _, _, pages = self._live[rid]
        while len(pages) * self.page_size < n_tokens:
            self._claim_one(rid)

    def prepare_decode(self, requests, k: int) -> None:
        """Claim every page the next ``k`` fused decode steps will write:
        step i writes position ``prompt_len + (n_generated - 1) + i``, so
        the claimed region must cover ``prompt_len + n_generated - 1 + k``
        tokens.  Reservations make this infallible."""
        for r in requests:
            self.grow_to(r.rid, r.prompt_len + r.n_generated - 1 + k)

    def release(self, request) -> None:
        rid = request.rid
        if rid not in self._live:
            raise RuntimeError(f"request {rid} holds no pages")
        slot, reserved, pages = self._live.pop(rid)
        self.page_history[rid] = tuple(pages)
        # push in reverse so the request's FIRST page is on top of the
        # stack — the next claim reuses the hottest line first
        for page in reversed(pages):
            self._free_pages.append(page)
            self.n_freed += 1
        self._reserved_total -= reserved
        self.table[slot, :] = self.trash_page
        heapq.heappush(self._free_rows, slot)


# ===========================================================================
# tensor helpers
# ===========================================================================

def write_slot(pool_tree, request_tree, slot: int):
    """Splice a single-request cache (batch dim 1) into pool row ``slot``.

    Every leaf is written whole, including its zero tail beyond the
    prompt, so the slot carries no state from a previous occupant.
    """
    return jax.tree_util.tree_map(
        lambda pool, one: jax.lax.dynamic_update_slice_in_dim(
            pool, one.astype(pool.dtype), slot, axis=BATCH_AXIS),
        pool_tree, request_tree)


def _trash_mask(table, n_phys: int, rank: int):
    """(1, S, npp, 1, ...) bool: True where a table entry is the trash
    page (id ``n_phys - 1``), broadcastable against gathered pages."""
    mask = table == (n_phys - 1)
    return mask.reshape((1,) + mask.shape + (1,) * (rank - 3))


def gather_page_view(pool_tree, table):
    """Physical page pool -> per-slot contiguous view.

    Leaves are ``(L, n_pages + 1, page_size, ...)``; ``table`` is
    ``(n_slots, pages_per_slot)`` int32.  Returns leaves of shape
    ``(L, n_slots, pages_per_slot * page_size, ...)`` — exactly the slot
    plane's layout, so the unchanged decode math runs on the view and
    positions beyond a request's depth (stale bytes in freshly claimed
    pages) are masked by decode attention.

    Trash-backed logical pages are forced to exact ZEROS rather than the
    trash page's bytes: the trash page absorbs racing duplicate scatter
    writes, and a torn write could leave inf/NaN bit patterns there —
    attention masking zeroes the *probability* of those positions, but
    ``0 * inf`` in the value contraction would still be NaN.  Zeros are
    inert under masking exactly.
    """
    def gather(leaf):
        g = leaf[:, table]                     # (L, S, npp, ps, ...)
        g = jax.numpy.where(_trash_mask(table, leaf.shape[1], g.ndim),
                            jax.numpy.zeros((), g.dtype), g)
        L, S, npp, ps = g.shape[:4]
        return g.reshape(L, S, npp * ps, *g.shape[4:])
    return jax.tree_util.tree_map(gather, pool_tree)


def scatter_page_view(pool_tree, view_tree, table):
    """Per-slot contiguous view -> physical page pool (inverse gather).

    Page ownership is exclusive among live requests, so slot views write
    disjoint physical pages.  Every DUPLICATE index in ``table`` is the
    trash page; its updates are forced to zero so all racing writers
    carry identical bytes — the scatter's nondeterministic duplicate
    ordering then cannot produce torn values (and the trash page stays
    all-zero for the pool's lifetime).
    """
    def scatter(leaf, view):
        L, S, Tv = view.shape[:3]
        npp = table.shape[1]
        pages = view.reshape(L, S, npp, Tv // npp, *view.shape[3:])
        pages = jax.numpy.where(_trash_mask(table, leaf.shape[1],
                                            pages.ndim),
                                jax.numpy.zeros((), pages.dtype), pages)
        return leaf.at[:, table].set(pages)
    return jax.tree_util.tree_map(scatter, pool_tree, view_tree)
