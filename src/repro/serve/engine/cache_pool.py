"""KV-cache pools: whole-row slots and fixed-size token pages.

Two bookkeeping planes share one admission interface (``can_admit`` /
``admit`` / ``release`` / ``prepare_decode`` / ``seal_prefilled``) so the
scheduler and engine are pool-agnostic:

``SlotCachePool`` — the original plane: the cache is one pytree with
``n_slots`` batch rows; a request owns one whole row from prefill to
retirement.  Admission is gated on free *slots*.

``PagedCachePool`` — the paged plane: the device cache is a pool of
``n_pages`` fixed-size token pages (``page_size`` rows each, one physical
page axis per cache leaf) plus one reserved *trash* page, and each live
request holds a page *table* mapping its logical pages to physical ones.
A request's cache can therefore span non-contiguous fragments, and
admission is gated on free **pages**, not free slots:

  * admission reserves the request's worst-case page count
    (``ceil((prompt_len + max_new - 1) / page_size)``) so decode growth
    can never be starved mid-flight (preemption-free reservation);
  * prefill claims only the pages the prompt needs; decode claims more
    lazily (*grow-on-decode*), structurally bounded by the reservation;
  * unclaimed logical pages point at the trash page, so whole-view
    scatters are always well-defined (the trash page absorbs garbage
    rows that are never read back — decode attention masks positions
    beyond each request's depth).

**Prefix sharing (``share_prefixes=True``)** adds the production
capacity lever: prompts that agree on their leading FULL pages map those
logical pages onto the SAME physical pages, tracked by per-page
refcounts and a ``prefix.PrefixIndex``.  The cost model changes from
worst-case private reservation to ``shared + private``: a follower
reserves (and can ever claim) only the pages the index did NOT already
hold, so a template-heavy workload admits far more concurrency out of
the same pool.  Copy-on-write happens at page granularity inside the
dispatches that already exist:

  * only pages the prompt fills completely are shareable; a partial
    last prompt page (prompt tokens + upcoming decode writes) is
    *copied* — claimed privately and written by the request's own
    prefill scatter — which is the only place a request's token stream
    diverges from the shared region;
  * every pool keeps TWO host page maps: ``table`` (the read map the
    decode gather uses) and ``write_table`` (the write map the
    scatters use), and a shared page's write entries are the trash
    page for every holder — once a page is sealed, no dispatch can
    write it, so "no request ever writes a page with refcount > 1"
    holds structurally (property-tested) and the scatter never sees
    duplicate non-trash indices;
  * growth pages (decode writes) are always private, so grow-on-decode
    and the reservation argument are unchanged.

Both pools are pure id bookkeeping with conservation counters
(``n_allocated == n_freed`` once drained, property-tested; a shared
page is allocated once and freed once — when its refcount hits zero —
no matter how many requests attached to it).  The tensor side lives in
the helper functions: ``write_slot`` splices a prefilled row into the
slot pool; ``gather_page_view`` / ``scatter_page_view`` translate
between the physical page pool and the per-slot contiguous *view* the
decode math runs on (one gather + one scatter inside the same jitted
dispatch, so the step count stays identical to the slot plane).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from .prefix import PrefixIndex, page_key

BATCH_AXIS = 1  # cache-leaf batch axis for the supported families


class SlotCachePool:
    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.n_slots = int(n_slots)
        # min-heap: allocate() hands out the LOWEST free slot (test-pinned)
        # in O(log n) — the old sorted list paid an O(n) shift per pop(0)
        # and an O(n log n) re-sort per free
        self._free: List[int] = list(range(n_slots))
        self._used: set = set()
        self.n_allocated = 0
        self.n_freed = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return len(self._used)

    @property
    def drained(self) -> bool:
        return not self._used

    @property
    def occupancy(self) -> float:
        """Instantaneous used fraction (the metrics-plane gauge)."""
        return len(self._used) / self.n_slots

    def active_slots(self) -> Tuple[int, ...]:
        return tuple(sorted(self._used))

    def allocate(self) -> int:
        if not self._free:
            raise RuntimeError("cache pool exhausted: no free slots")
        slot = heapq.heappop(self._free)
        self._used.add(slot)
        self.n_allocated += 1
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._used:
            raise RuntimeError(f"slot {slot} is not allocated")
        self._used.remove(slot)
        heapq.heappush(self._free, slot)
        self.n_freed += 1

    # ---- pool-agnostic admission interface (scheduler/engine) ----------
    def can_admit(self, request) -> bool:
        """True iff ``admit`` would succeed right now.

        Callers may rely on: (a) no side effects — safe to probe
        speculatively; (b) consistency — ``can_admit`` followed by
        ``admit`` in the same scheduler step cannot fail, because only
        ``admit``/``release`` mutate capacity and the engine loop is
        single-threaded.  The slot plane's only resource is a free row.
        """
        return self.free_count > 0

    def admit(self, request) -> int:
        """Take a whole cache row for ``request`` and return the slot id.

        Callers may rely on: the row is exclusively owned until
        ``release``; ``write_slot`` overwrites it whole at prefill so no
        previous occupant's bytes are ever visible.  Raises if no row is
        free (callers must gate on ``can_admit``)."""
        return self.allocate()

    def release(self, request) -> None:
        """Return ``request``'s row to the free list.

        Callers may rely on: capacity freed here is admissible in the
        SAME scheduler step (retire-before-admit), and conservation —
        every ``admit`` is matched by exactly one ``release`` before
        ``drained`` can be True."""
        self.free(request.slot)

    def prepare_decode(self, requests, k: int) -> None:
        """Claim whatever the next ``k`` fused decode steps will write.

        Slot rows are whole — nothing to claim — so this is a no-op;
        the paged plane overrides it with page growth.  Callers may rely
        on it being infallible for admitted requests on BOTH planes."""

    def seal_prefilled(self, requests) -> None:
        """Hook the engine calls right after the prefill dispatch that
        wrote ``requests``'s cache state.  Slot rows need no sealing;
        the paged plane uses it to publish shareable prefix pages (and
        write-protect them).  Callers may rely on: after this returns,
        every page/row the prefill wrote is safe to share per the pool's
        sharing policy, and no writable alias of a shared page remains.
        """


@dataclasses.dataclass
class _PagedLive:
    """Host bookkeeping for one in-flight request on the paged plane."""

    slot: int
    private_reserved: int        # pages this request may claim itself
    pages: List[int]             # logical order; head may be shared
    n_shared: int                # attached (refcount > 1 capable) head pages
    pending_keys: List[Tuple[int, bytes]]   # pages to index at seal time


class PagedCachePool:
    """Page allocator + per-request page tables for the paged KV plane.

    ``table`` is the host-side (numpy) READ page map, shape
    ``(n_slots, pages_per_slot)`` int32: row = decode-batch slot, column =
    logical page index, value = physical page id (``trash_page`` when
    unclaimed).  ``write_table`` is the WRITE map the scatters use: it
    equals ``table`` except that shared (sealed) pages are replaced by
    the trash page, so no dispatch can ever write a page two requests
    read.  The engine pushes both to device as arguments of every jitted
    dispatch — values change per step, shapes never do.

    With ``share_prefixes=False`` (the default) the two tables are
    always equal and every page has refcount 1: behaviour is exactly
    the PR 5 private-reservation plane.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 pages_per_slot: int, share_prefixes: bool = False):
        assert n_pages >= 1 and page_size >= 1
        assert n_slots >= 1 and pages_per_slot >= 1
        # a pool smaller than one slot's view could never admit a
        # worst-case request: the engine would spin forever un-admitting
        assert n_pages >= pages_per_slot, (
            f"n_pages={n_pages} < pages_per_slot={pages_per_slot}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.n_slots = int(n_slots)
        self.pages_per_slot = int(pages_per_slot)
        self.share_prefixes = bool(share_prefixes)
        self.prefix_index: Optional[PrefixIndex] = (
            PrefixIndex(page_size) if share_prefixes else None)
        # free-page STACK (LIFO), not a heap: page identity is
        # interchangeable (the table indirection absorbs any order), so
        # claims are O(1) pops off the end instead of O(log n) sifts —
        # the allocator sits on the per-decode-step path via
        # prepare_decode.  Seeded descending so the first claims still
        # hand out low page ids.  Rows stay a min-heap: slot order is
        # test-pinned.
        self._free_pages: List[int] = list(range(n_pages - 1, -1, -1))
        self._free_rows: List[int] = list(range(n_slots))
        self._live: Dict[int, _PagedLive] = {}
        # refcount per CLAIMED physical page (1 for private pages, +1 per
        # attached sharer); a page leaves the dict when it is freed
        self._rc: Dict[int, int] = {}
        # page-budget accounting: claimed pages (counted ONCE each, no
        # matter how many requests share them) + every live request's
        # not-yet-claimed private reservation.  Admission gates new
        # private needs against this, which is what makes grow-on-decode
        # infallible even under sharing.
        self._reserved_total = 0
        self.table = np.full((n_slots, pages_per_slot), self.trash_page,
                             np.int32)
        self.write_table = np.full((n_slots, pages_per_slot),
                                   self.trash_page, np.int32)
        # rid -> final claimed page tuple, recorded at release (tests and
        # benchmarks assert fragmentation: requests span non-contiguous
        # physical pages)
        self.page_history: Dict[int, Tuple[int, ...]] = {}
        self.n_allocated = 0   # pages claimed (conservation counters)
        self.n_freed = 0       # pages returned (refcount hit zero)
        # sharing evidence (benchmark / regression-gate counters)
        self.n_shared_attached = 0   # page attachments through the index
        self.max_refcount = 0        # high-water refcount ever observed
        self.peak_used_pages = 0     # high-water used_pages

    @property
    def trash_page(self) -> int:
        """Reserved physical page absorbing writes from inactive slots and
        unclaimed logical pages (index ``n_pages``: one past the real
        pool, so leaves carry ``n_pages + 1`` physical pages)."""
        return self.n_pages

    @property
    def view_len(self) -> int:
        return self.pages_per_slot * self.page_size

    @property
    def free_page_count(self) -> int:
        return len(self._free_pages)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free_pages)

    @property
    def reserved_pages(self) -> int:
        return self._reserved_total

    @property
    def free_count(self) -> int:
        """Admittable-request lower bound (kept for engine fast-paths):
        0 when no row or no unreserved page remains."""
        if not self._free_rows:
            return 0
        return max(0, self.n_pages - self._reserved_total)

    @property
    def used_count(self) -> int:
        return len(self._live)

    @property
    def drained(self) -> bool:
        return not self._live

    @property
    def occupancy(self) -> float:
        """Instantaneous used-page fraction (the metrics-plane gauge)."""
        return self.used_pages / self.n_pages

    def pages_needed(self, prompt_len: int, max_new: int) -> int:
        """Worst-case pages a request can ever hold: prompt positions plus
        the ``max_new - 1`` decode writes (the final token is returned but
        never written back)."""
        tokens = prompt_len + max(max_new - 1, 0)
        return -(-tokens // self.page_size)

    def prefill_pages(self, prompt_len: int) -> int:
        return -(-prompt_len // self.page_size)

    def live_pages(self, rid: int) -> Tuple[int, ...]:
        return tuple(self._live[rid].pages)

    def shared_pages(self, rid: int) -> Tuple[int, ...]:
        """The attached (index-matched) head of ``rid``'s page chain."""
        e = self._live[rid]
        return tuple(e.pages[:e.n_shared])

    def refcount(self, page: int) -> int:
        return self._rc.get(page, 0)

    def _match(self, request) -> List[int]:
        """Physical pages ``request`` can attach to (empty when sharing
        is off).  Pure read — can_admit probes it speculatively."""
        if self.prefix_index is None:
            return []
        return self.prefix_index.match(request.prompt)

    def _claim_one(self, rid: int) -> int:
        e = self._live[rid]
        if len(e.pages) - e.n_shared >= e.private_reserved:
            raise RuntimeError(
                f"request {rid} grew past its reservation of "
                f"{e.private_reserved} private pages — admission must "
                f"reserve the worst-case decode length")
        if not self._free_pages:
            raise RuntimeError(
                "page pool exhausted despite reservations — allocator "
                "invariant broken (claimed pages must never exceed the "
                "reserved total)")
        page = self._free_pages.pop()
        e.pages.append(page)
        self._rc[page] = 1
        self.max_refcount = max(self.max_refcount, 1)
        col = len(e.pages) - 1
        self.table[e.slot, col] = page
        self.write_table[e.slot, col] = page   # private: writable
        self.n_allocated += 1
        self.peak_used_pages = max(self.peak_used_pages, self.used_pages)
        return page

    # ---- pool-agnostic admission interface -----------------------------
    def can_admit(self, request) -> bool:
        """True iff ``admit`` would succeed right now: a free decode row
        AND enough unreserved pages for the request's worst case *after*
        subtracting the prefix pages the index can already supply.

        Callers may rely on: (a) no side effects — the prefix match is a
        pure dict walk; (b) can_admit-then-admit consistency within one
        scheduler step (nothing mutates capacity or the index between
        them); (c) reserving the PRIVATE worst case up front is what
        keeps the plane preemption-free — grow-on-decode can never fail
        mid-flight, shared or not, because growth pages are always part
        of the private reservation."""
        if not self._free_rows:
            return False
        need = self.pages_needed(request.prompt_len, request.max_new)
        if need > self.pages_per_slot:
            raise RuntimeError(
                f"request needs {need} pages but a slot's view holds only "
                f"{self.pages_per_slot} — admission control must bound "
                f"prompt_len + max_new to the configured cache length")
        private_need = need - len(self._match(request))
        return self._reserved_total + private_need <= self.n_pages

    def admit(self, request) -> int:
        """Admit ``request``: attach the longest materialized shared
        prefix (refcount + 1 per page, zero new pages), claim the rest
        of its prompt pages privately, and reserve its remaining private
        worst case.  Returns the decode-row slot.

        Callers may rely on: (a) the returned slot's ``table`` row maps
        every already-claimed logical page, shared head first;
        (b) ``write_table`` masks attached pages to the trash page from
        the very first dispatch, so the request can never write what it
        shares; (c) full prompt pages this request claims privately are
        *registered* for future sharing but attachable only after
        ``seal_prefilled`` — nobody can share an unwritten page;
        (d) raises instead of over-committing (gate on ``can_admit``)."""
        if not self.can_admit(request):
            raise RuntimeError("page pool cannot admit this request")
        slot = heapq.heappop(self._free_rows)
        need = self.pages_needed(request.prompt_len, request.max_new)
        shared = self._match(request)
        e = _PagedLive(slot=slot, private_reserved=need - len(shared),
                       pages=[], n_shared=len(shared), pending_keys=[])
        self._live[request.rid] = e
        self._reserved_total += e.private_reserved
        for col, page in enumerate(shared):       # attach, never write
            e.pages.append(page)
            self._rc[page] += 1
            self.max_refcount = max(self.max_refcount, self._rc[page])
            self.table[slot, col] = page
            self.write_table[slot, col] = self.trash_page
            self.n_shared_attached += 1
        for _ in range(self.prefill_pages(request.prompt_len)
                       - len(shared)):
            page = self._claim_one(request.rid)
            col = len(e.pages) - 1
            # a full prompt page this request creates becomes shareable
            # once its prefill lands (partial pages stay private: decode
            # writes continue into them — the page-granular CoW copy)
            if (self.prefix_index is not None
                    and (col + 1) * self.page_size <= request.prompt_len):
                key = page_key(request.prompt, col, self.page_size)
                if self.prefix_index.register(key, page):
                    e.pending_keys.append((page, key))
        return slot

    def seal_prefilled(self, requests) -> None:
        """Publish the shareable pages the prefill dispatch just wrote:
        materialize their index entries (followers may attach from the
        NEXT scheduler step on) and write-protect them in
        ``write_table`` — from here on no dispatch carries a writable
        alias of a shareable page.

        Callers may rely on: ordering — the engine calls this after the
        prefill call and before the step's decode dispatch, so a sealed
        page is never gathered before it holds real KV bytes."""
        if self.prefix_index is None:
            return
        for r in requests:
            e = self._live.get(r.rid)
            if e is None:
                continue
            for page, _key in e.pending_keys:
                self.prefix_index.materialize(page)
                col = e.pages.index(page)
                self.write_table[e.slot, col] = self.trash_page
            e.pending_keys = []

    def grow_to(self, rid: int, n_tokens: int) -> None:
        """Claim pages until the request's claimed region covers
        ``n_tokens`` cache positions (grow-on-decode).  Growth pages are
        always private — the shared head never grows."""
        e = self._live[rid]
        while len(e.pages) * self.page_size < n_tokens:
            self._claim_one(rid)

    def prepare_decode(self, requests, k: int) -> None:
        """Claim every page the next ``k`` fused decode steps will write:
        step i writes position ``prompt_len + (n_generated - 1) + i``, so
        the claimed region must cover ``prompt_len + n_generated - 1 + k``
        tokens.

        Callers may rely on: infallibility for admitted requests — the
        admission-time private reservation covers every growth page, so
        this can never raise mid-flight (no preemption, no OOM), with or
        without sharing."""
        for r in requests:
            self.grow_to(r.rid, r.prompt_len + r.n_generated - 1 + k)

    def release(self, request) -> None:
        """Return ``request``'s capacity: decrement every held page's
        refcount, free the pages that hit zero (evicting their index
        entries), give back the unclaimed private reservation, reset the
        slot's table rows, and free the decode row.

        Callers may rely on: (a) retire-before-admit — capacity released
        here is admissible in the same scheduler step; (b) conservation —
        a shared page is freed exactly once, by its LAST holder, so
        ``n_allocated == n_freed`` at drain and every refcount is zero;
        (c) an index entry never names a freed page; (d) safe for
        requests killed mid-flight (the fleet requeue path) — partially
        grown requests release cleanly."""
        rid = request.rid
        if rid not in self._live:
            raise RuntimeError(f"request {rid} holds no pages")
        e = self._live.pop(rid)
        self.page_history[rid] = tuple(e.pages)
        # unclaimed private reservation comes back whole...
        self._reserved_total -= (e.private_reserved
                                 - (len(e.pages) - e.n_shared))
        # ...claimed pages come back one refcount at a time.  Push in
        # reverse so the request's FIRST freed page is on top of the
        # stack — the next claim reuses the hottest line first.
        for page in reversed(e.pages):
            self._rc[page] -= 1
            if self._rc[page] == 0:
                del self._rc[page]
                if self.prefix_index is not None:
                    self.prefix_index.evict(page)
                self._free_pages.append(page)
                self._reserved_total -= 1
                self.n_freed += 1
        self.table[e.slot, :] = self.trash_page
        self.write_table[e.slot, :] = self.trash_page
        heapq.heappush(self._free_rows, e.slot)


# ===========================================================================
# tensor helpers
# ===========================================================================

def write_slot(pool_tree, request_tree, slot: int):
    """Splice a single-request cache (batch dim 1) into pool row ``slot``.

    Every leaf is written whole, including its zero tail beyond the
    prompt, so the slot carries no state from a previous occupant.
    """
    return jax.tree_util.tree_map(
        lambda pool, one: jax.lax.dynamic_update_slice_in_dim(
            pool, one.astype(pool.dtype), slot, axis=BATCH_AXIS),
        pool_tree, request_tree)


def _trash_mask(table, n_phys: int, rank: int):
    """(1, S, npp, 1, ...) bool: True where a table entry is the trash
    page (id ``n_phys - 1``), broadcastable against gathered pages."""
    mask = table == (n_phys - 1)
    return mask.reshape((1,) + mask.shape + (1,) * (rank - 3))


def gather_page_view(pool_tree, table):
    """Physical page pool -> per-slot contiguous view.

    Leaves are ``(L, n_pages + 1, page_size, ...)``; ``table`` is the
    (n_slots, pages_per_slot) int32 READ map — shared physical pages may
    appear in several rows, which is exactly how prefix sharing reuses
    one prompt's KV across requests.  Returns leaves of shape
    ``(L, n_slots, pages_per_slot * page_size, ...)`` — exactly the slot
    plane's layout, so the unchanged decode math runs on the view and
    positions beyond a request's depth (stale bytes in freshly claimed
    pages) are masked by decode attention.

    Trash-backed logical pages are forced to exact ZEROS rather than the
    trash page's bytes: the trash page absorbs racing duplicate scatter
    writes, and a torn write could leave inf/NaN bit patterns there —
    attention masking zeroes the *probability* of those positions, but
    ``0 * inf`` in the value contraction would still be NaN.  Zeros are
    inert under masking exactly.
    """
    def gather(leaf):
        g = leaf[:, table]                     # (L, S, npp, ps, ...)
        g = jax.numpy.where(_trash_mask(table, leaf.shape[1], g.ndim),
                            jax.numpy.zeros((), g.dtype), g)
        L, S, npp, ps = g.shape[:4]
        return g.reshape(L, S, npp * ps, *g.shape[4:])
    return jax.tree_util.tree_map(gather, pool_tree)


def scatter_page_view(pool_tree, view_tree, table):
    """Per-slot contiguous view -> physical page pool (inverse gather).

    ``table`` here is the WRITE map: page ownership of its non-trash
    entries is exclusive among live requests (shared pages are masked to
    the trash page for every holder — the copy-on-write discipline), so
    slot views write disjoint physical pages.  Every DUPLICATE index in
    the map is therefore the trash page; its updates are forced to zero
    so all racing writers carry identical bytes — the scatter's
    nondeterministic duplicate ordering then cannot produce torn values
    (and the trash page stays all-zero for the pool's lifetime).
    """
    def scatter(leaf, view):
        L, S, Tv = view.shape[:3]
        npp = table.shape[1]
        pages = view.reshape(L, S, npp, Tv // npp, *view.shape[3:])
        pages = jax.numpy.where(_trash_mask(table, leaf.shape[1],
                                            pages.ndim),
                                jax.numpy.zeros((), pages.dtype), pages)
        return leaf.at[:, table].set(pages)
    return jax.tree_util.tree_map(scatter, pool_tree, view_tree)
