"""LBP capacity planner: split serving traffic across heterogeneous replicas.

Dynamic request scheduling on heterogeneous workers is the serving-time
analogue of the paper's static layer split, and it routes through the
``repro.plan`` subsystem: the replica fleet is described ONCE as a
Topology — a flat star (``w_i = 1 / measured tokens-per-sec``, ``z_i`` the
link class) or the two-level ``HierarchicalTopology`` when replicas span
pods behind shared DCN trunks — and ``repro.plan.plan()`` returns the
``PartitionPlan`` (equal-finish-time shares, §4.5 integer adjustment;
quantum > 1 models replicas that only accept full micro-batches).

Rate drift (thermal throttling, noisy neighbours) is handled the same way
``runtime/rebalance.py`` handles stragglers: re-measure, and re-solve when
the measured rates have moved past a threshold.

Paged fleets add a MEMORY dimension (Dongarra et al., master-worker with
bounded worker memory): a replica's concurrency is capped by its KV page
pool, so the divisible load is priced in **page-seconds** — a request on
replica i holds ``pages_per_request`` pages for ``w_i`` time-units.  The
equal-finish split is unchanged in shape; memory enters as a per-replica
share cap, enforced by waterfilling (clamp the saturated replicas, re-run
the §4 solver on the survivors for the remaining load).  A replica with a
fast chip but a small page pool therefore splits *honestly*: it gets the
lesser of its compute-fair share and what its memory can hold.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ...core.star import StarSchedule
from ...plan import (DCN_LINK, ICI_LINK, PartitionPlan, StarTopology,
                     Topology, plan as plan_split)
from ...runtime.rebalance import measure_speeds

__all__ = ["CapacityPlanner", "ReplicaPlan", "PagedReplicaPlan",
           "ICI_LINK", "DCN_LINK"]


@dataclasses.dataclass(frozen=True)
class ReplicaPlan:
    schedule: StarSchedule      # real-valued §4-style solution (k sums to N)
    shares: np.ndarray          # (p,) integer requests per replica
    mode: str
    rates: np.ndarray           # tokens/sec the plan was solved against
    partition: Optional[PartitionPlan] = None  # the full repro.plan IR

    @property
    def p(self) -> int:
        return int(self.shares.shape[0])

    @property
    def n_requests(self) -> int:
        return int(self.shares.sum())

    def fractions(self) -> np.ndarray:
        return self.shares / max(self.n_requests, 1)


@dataclasses.dataclass(frozen=True)
class PagedReplicaPlan(ReplicaPlan):
    """A ReplicaPlan whose shares respect per-replica page capacity."""

    pages_per_request: int = 1
    shared_prefix_pages: int = 0                # pages paid once per replica
    capacity: Optional[np.ndarray] = None       # (p,) request cap per replica
    page_seconds: Optional[np.ndarray] = None   # (p,) pages x service time
    saturated: Optional[np.ndarray] = None      # (p,) bool: memory-capped


class CapacityPlanner:
    """Traffic splitter over p replicas with measured token rates.

    Either pass measured ``rates`` (+ optional per-replica ``link_class``)
    for the flat-star fleet, or a full ``repro.plan`` Topology (e.g.
    ``HierarchicalTopology`` for multi-pod fleets) via ``topology=``.
    """

    def __init__(self, rates: Optional[Sequence[float]] = None,
                 link_class: Optional[Sequence[float]] = None,
                 mode: str = "PCCS", quantum: int = 1,
                 drift_threshold: float = 0.2,
                 topology: Optional[Topology] = None,
                 pages: Optional[Sequence[int]] = None):
        if topology is None:
            assert rates is not None, "pass rates=... or topology=..."
            topology = StarTopology.from_rates(rates, link_class)
        if not hasattr(topology, "w"):
            raise ValueError(
                f"CapacityPlanner needs a per-replica topology (star or "
                f"hierarchical), got {topology.kind!r}")
        self.topology = topology
        if rates is not None:
            self.rates = np.asarray(rates, dtype=np.float64)
            if self.rates.shape != (topology.p,):
                raise ValueError(
                    f"rates describe {self.rates.shape[0]} replicas but the "
                    f"topology has {topology.p}; pass consistent views of "
                    f"the fleet (or only topology=)")
            if not np.allclose(1.0 / self.rates, topology.w):
                raise ValueError(
                    "rates disagree with topology.w — the solver would use "
                    "the topology while ReplicaPlan.rates records something "
                    "else; build the topology from the measured rates "
                    "(StarTopology.from_rates / with_rates)")
        else:
            self.rates = 1.0 / topology.w
        assert np.all(self.rates > 0)
        self.mode = mode
        self.quantum = int(quantum)
        self.drift_threshold = float(drift_threshold)
        # per-replica KV page capacity (the paged plane's memory budget);
        # None = unbounded memory, plan_paged then needs an explicit cap
        self.pages = (None if pages is None
                      else np.asarray(pages, dtype=np.int64))
        if self.pages is not None:
            if self.pages.shape != (self.p,) or not np.all(self.pages >= 1):
                raise ValueError(
                    f"pages must give a positive page count for each of "
                    f"the {self.p} replicas, got {pages!r}")

    @property
    def p(self) -> int:
        return int(self.rates.shape[0])

    def network(self):
        """Single-level StarNetwork view of the fleet (hierarchical
        topologies are flattened — use ``self.topology`` for the truth)."""
        topo = self.topology
        if not isinstance(topo, StarTopology):
            topo = topo.flatten()
        return topo.to_network()

    def plan(self, n_requests: int) -> ReplicaPlan:
        assert n_requests >= 1
        if self.quantum > 1 and n_requests % self.quantum:
            raise ValueError(
                f"n_requests={n_requests} must be a multiple of the "
                f"micro-batch quantum {self.quantum} (pad the batch)")
        pp = plan_split(self.topology, n_requests, quantum=self.quantum,
                        objective=self.mode)
        sched = StarSchedule(
            mode=self.mode, k=pp.k_real,
            finish_time=float(pp.meta.get("schedule_finish", pp.finish_time)),
            comm_volume=2.0 * n_requests * float(pp.k_real.sum()))
        return ReplicaPlan(schedule=sched, shares=pp.k, mode=self.mode,
                           rates=self.rates.copy(), partition=pp)

    def plan_paged(self, n_requests: int, pages_per_request: int,
                   shared_prefix_pages: int = 0) -> PagedReplicaPlan:
        """Memory-honest split for paged fleets: equal-finish shares
        capped by each replica's page capacity (waterfilling).

        The load is divisible in *page-seconds*: serving one request on
        replica i costs ``pages_per_request * w_i`` page-seconds of its
        pool.  Replicas whose compute-fair share exceeds their page cap
        are clamped there and the §4 solver re-runs on the survivors for
        the remaining load — the bounded-memory master-worker schedule.

        ``shared_prefix_pages`` prices prefix sharing into the memory
        dimension: when every request carries the same shared prompt
        prefix, a replica pays those pages ONCE (the first request
        creates them; followers attach at zero page cost), so its
        marginal per-request cost drops to ``pages_per_request -
        shared_prefix_pages`` and its cap becomes
        ``(pages_i - shared_prefix_pages) // marginal``.  The default 0
        reproduces the private-reservation pricing exactly.
        """
        assert n_requests >= 1 and pages_per_request >= 1
        assert 0 <= shared_prefix_pages < pages_per_request, (
            "shared_prefix_pages must leave at least one private page "
            "per request (the decode tail is never shareable)")
        if self.pages is None:
            raise ValueError(
                "plan_paged needs per-replica page capacities — build the "
                "planner with pages=[...]")
        if self.quantum != 1:
            raise NotImplementedError(
                "page-capped waterfilling assumes quantum=1 (clamped "
                "shares need not stay quantum-aligned)")
        marginal = int(pages_per_request) - int(shared_prefix_pages)
        caps = np.maximum(self.pages - int(shared_prefix_pages), 0) \
            // marginal
        if int(caps.sum()) < n_requests:
            raise ValueError(
                f"fleet page capacity holds {int(caps.sum())} concurrent "
                f"requests at {marginal} private pages each "
                f"(+{shared_prefix_pages} shared), but the batch has "
                f"{n_requests} — shrink the batch or the per-request "
                f"reservation")
        shares = np.zeros(self.p, dtype=np.int64)
        active = np.arange(self.p)
        remaining = int(n_requests)
        pp = None
        while remaining > 0 and active.shape[0] > 0:
            sub = (self.topology if active.shape[0] == self.p
                   else self.topology.restrict(active))
            pp = plan_split(sub, remaining, quantum=1, objective=self.mode)
            over = pp.k > caps[active]
            if not np.any(over):
                shares[active] = pp.k
                break
            # clamp the memory-saturated replicas, re-solve the rest
            shares[active[over]] = caps[active[over]]
            remaining -= int(caps[active[over]].sum())
            active = active[~over]
        unclamped = pp is not None and active.shape[0] == self.p
        w = 1.0 / self.rates
        sched = StarSchedule(
            mode=self.mode, k=shares.astype(np.float64),
            finish_time=float(np.max(shares * w)),
            comm_volume=2.0 * n_requests * float(shares.sum()))
        # page-seconds per replica: shared prefix pages are paid once
        # (only where the replica serves at least one request), private
        # pages once per request
        held = (shared_prefix_pages * (shares > 0) + shares * marginal)
        return PagedReplicaPlan(
            schedule=sched, shares=shares, mode=self.mode,
            rates=self.rates.copy(),
            partition=pp if unclamped else None,
            pages_per_request=int(pages_per_request),
            shared_prefix_pages=int(shared_prefix_pages),
            capacity=caps, page_seconds=held * w,
            saturated=shares >= caps)

    # ------------------------------------------------------------------
    def drift(self, new_rates: Sequence[float]) -> float:
        """Largest relative per-replica rate change vs the current model."""
        new = np.asarray(new_rates, dtype=np.float64)
        return float(np.max(np.abs(new - self.rates) / self.rates))

    def observe(self, new_rates: Sequence[float],
                n_requests: int) -> Optional[ReplicaPlan]:
        """Adopt new measurements; returns a fresh plan iff they drifted
        past the threshold (else None — keep routing on the old plan)."""
        new = np.asarray(new_rates, dtype=np.float64)
        if new.shape != self.rates.shape or not np.all(new > 0):
            # a 0/negative rate (dead replica) would poison w = 1/rate and
            # every later drift() with inf/NaN — the caller must shrink
            # the replica set instead (cf. runtime.rebalance.drop_devices)
            raise ValueError(
                f"measured rates must be positive for all {self.p} "
                f"replicas (got {new!r}); drop dead replicas and build a "
                f"new planner instead")
        if self.drift(new) <= self.drift_threshold:
            return None
        self.rates = new
        self.topology = self.topology.with_rates(new)
        return self.plan(n_requests)

    def observe_step_times(self, step_times: Sequence[float],
                           n_requests: int,
                           tokens_per_step: float = 1.0
                           ) -> Optional[ReplicaPlan]:
        """Re-plan from measured per-replica step times (the
        ``runtime.rebalance.measure_speeds`` path): rate_i =
        relative_speed_i scaled back to tokens/sec by the mean rate."""
        rel = measure_speeds(step_times)          # mean-1 relative rates
        mean_rate = tokens_per_step * float(np.mean(
            1.0 / np.asarray(step_times, dtype=np.float64)))
        return self.observe(rel * mean_rate, n_requests)

    # ------------------------------------------------------------------
    def route(self, plan: ReplicaPlan) -> np.ndarray:
        """Deterministic request->replica assignment interleaved by share
        (smooth weighted round-robin), so replicas fill evenly in time
        rather than in contiguous blocks."""
        n, shares = plan.n_requests, plan.shares.astype(np.float64)
        total = shares.sum()
        credit = np.zeros(plan.p)
        out = np.empty(n, dtype=np.int64)
        remaining = plan.shares.astype(np.int64).copy()
        for j in range(n):
            credit += shares
            credit[remaining == 0] = -np.inf
            i = int(np.argmax(credit))
            credit[i] -= total
            remaining[i] -= 1
            out[j] = i
        return out

    def finish_times(self, plan: ReplicaPlan) -> np.ndarray:
        """Per-replica predicted finish times of the integer shares (the
        plan IR's timing model — equal-finish within one quantum)."""
        if plan.partition is not None:
            return plan.partition.finish_times
        # plans built without the IR (hand-constructed / pre-PR-3 callers)
        from ...core.star import per_processor_finish
        return per_processor_finish(self.network(), plan.n_requests,
                                    plan.shares, plan.mode)
