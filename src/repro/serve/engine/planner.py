"""LBP capacity planner: split serving traffic across heterogeneous replicas.

Dynamic request scheduling on heterogeneous workers is the serving-time
analogue of the paper's static layer split.  Each serving replica i is a
child of a star network (§4): ``w_i = 1 / measured tokens-per-sec`` and
``z_i`` its link class (ICI near-zero, DCN per-pod).  A batch of N
incoming requests is the divisible load; the §4 equality-based solvers
give the real-valued split with the equal-finish-time property, and §4.5
integer adjustment (``core.integer_adjust``) turns it into whole-request
shares (quantum > 1 models replicas that only accept full micro-batches).

Rate drift (thermal throttling, noisy neighbours) is handled the same way
``runtime/rebalance.py`` handles stragglers: re-measure, and re-solve when
the measured rates have moved past a threshold.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ...core.integer_adjust import adjust_integer
from ...core.network import StarNetwork
from ...core.star import SOLVERS, StarSchedule, per_processor_finish
from ...runtime.rebalance import measure_speeds

ICI_LINK = 1e-9    # near-zero: in-pod replicas, solver balances compute only
DCN_LINK = 1e-3    # cross-pod link class


@dataclasses.dataclass(frozen=True)
class ReplicaPlan:
    schedule: StarSchedule      # real-valued §4 solution (k sums to N)
    shares: np.ndarray          # (p,) integer requests per replica
    mode: str
    rates: np.ndarray           # tokens/sec the plan was solved against

    @property
    def p(self) -> int:
        return int(self.shares.shape[0])

    @property
    def n_requests(self) -> int:
        return int(self.shares.sum())

    def fractions(self) -> np.ndarray:
        return self.shares / max(self.n_requests, 1)


class CapacityPlanner:
    """Traffic splitter over p replicas with measured token rates."""

    def __init__(self, rates: Sequence[float],
                 link_class: Optional[Sequence[float]] = None,
                 mode: str = "PCCS", quantum: int = 1,
                 drift_threshold: float = 0.2):
        self.rates = np.asarray(rates, dtype=np.float64)
        assert np.all(self.rates > 0)
        self.link = (np.full_like(self.rates, ICI_LINK)
                     if link_class is None
                     else np.asarray(link_class, dtype=np.float64))
        assert self.link.shape == self.rates.shape
        self.mode = mode
        self.quantum = int(quantum)
        self.drift_threshold = float(drift_threshold)

    @property
    def p(self) -> int:
        return int(self.rates.shape[0])

    def network(self) -> StarNetwork:
        return StarNetwork(w=1.0 / self.rates, z=self.link.copy())

    def plan(self, n_requests: int) -> ReplicaPlan:
        assert n_requests >= 1
        if self.quantum > 1 and n_requests % self.quantum:
            raise ValueError(
                f"n_requests={n_requests} must be a multiple of the "
                f"micro-batch quantum {self.quantum} (pad the batch)")
        net = self.network()
        sched = SOLVERS[self.mode](net, n_requests)
        shares = adjust_integer(net, n_requests, sched.k, self.mode,
                                quantum=self.quantum)
        return ReplicaPlan(schedule=sched, shares=shares, mode=self.mode,
                           rates=self.rates.copy())

    # ------------------------------------------------------------------
    def drift(self, new_rates: Sequence[float]) -> float:
        """Largest relative per-replica rate change vs the current model."""
        new = np.asarray(new_rates, dtype=np.float64)
        return float(np.max(np.abs(new - self.rates) / self.rates))

    def observe(self, new_rates: Sequence[float],
                n_requests: int) -> Optional[ReplicaPlan]:
        """Adopt new measurements; returns a fresh plan iff they drifted
        past the threshold (else None — keep routing on the old plan)."""
        new = np.asarray(new_rates, dtype=np.float64)
        if new.shape != self.rates.shape or not np.all(new > 0):
            # a 0/negative rate (dead replica) would poison w = 1/rate and
            # every later drift() with inf/NaN — the caller must shrink
            # the replica set instead (cf. runtime.rebalance.drop_devices)
            raise ValueError(
                f"measured rates must be positive for all {self.p} "
                f"replicas (got {new!r}); drop dead replicas and build a "
                f"new planner instead")
        if self.drift(new) <= self.drift_threshold:
            return None
        self.rates = new
        return self.plan(n_requests)

    def observe_step_times(self, step_times: Sequence[float],
                           n_requests: int,
                           tokens_per_step: float = 1.0
                           ) -> Optional[ReplicaPlan]:
        """Re-plan from measured per-replica step times (the
        ``runtime.rebalance.measure_speeds`` path): rate_i =
        relative_speed_i scaled back to tokens/sec by the mean rate."""
        rel = measure_speeds(step_times)          # mean-1 relative rates
        mean_rate = tokens_per_step * float(np.mean(
            1.0 / np.asarray(step_times, dtype=np.float64)))
        return self.observe(rel * mean_rate, n_requests)

    # ------------------------------------------------------------------
    def route(self, plan: ReplicaPlan) -> np.ndarray:
        """Deterministic request->replica assignment interleaved by share
        (smooth weighted round-robin), so replicas fill evenly in time
        rather than in contiguous blocks."""
        n, shares = plan.n_requests, plan.shares.astype(np.float64)
        total = shares.sum()
        credit = np.zeros(plan.p)
        out = np.empty(n, dtype=np.int64)
        remaining = plan.shares.astype(np.int64).copy()
        for j in range(n):
            credit += shares
            credit[remaining == 0] = -np.inf
            i = int(np.argmax(credit))
            credit[i] -= total
            remaining[i] -= 1
            out[j] = i
        return out

    def finish_times(self, plan: ReplicaPlan) -> np.ndarray:
        """Per-replica finish times of the integer shares under the §4
        timing model (for the equal-finish-time check)."""
        return per_processor_finish(self.network(), plan.n_requests,
                                    plan.shares, plan.mode)
