"""FIFO request queue with admission control.

Admission control rejects malformed work at submit time — prompt/max-new
budgets and a queue-depth cap — so shape failures can never reach the
jitted serving steps.  The queue is FIFO *among eligible requests*: order
is (arrival, rid), and ``pop_ready(now)`` only releases requests whose
arrival time has passed, which is how benchmarks replay staggered traces.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from .request import QUEUED, Request


class AdmissionError(ValueError):
    """Request rejected at submit time (budget or capacity violation).

    ``reason`` is a stable machine-readable tag (``empty_prompt`` /
    ``prompt_len`` / ``max_new`` / ``total_len`` / ``queue_full``) — the
    label the metrics plane counts rejections by, so dashboards and the
    bench-regression gate never parse the human message.
    """

    def __init__(self, message: str, reason: str = "other"):
        super().__init__(message)
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class AdmissionLimits:
    max_prompt_len: int = 1024
    max_new_cap: int = 1024
    max_queue: int = 4096
    # per-request total budget: a cache slot's time axis must hold
    # prompt + all generated tokens (None: max_prompt_len + max_new_cap)
    max_total_len: Optional[int] = None


class RequestQueue:
    def __init__(self, limits: AdmissionLimits = AdmissionLimits()):
        self.limits = limits
        # min-heap keyed on (arrival, rid): only the minimum is ever
        # popped, so submit and pop_ready are both O(log n) — the old
        # sorted list paid an O(n) shift per pop_ready's list.pop(0)
        self._pending: List[Tuple[Tuple[float, int], Request]] = []
        # second heap, same key: requests not yet stamped eligible.  Each
        # request is stamped exactly once, so mark_eligible is amortized
        # O(log n) instead of an O(n) scan of the whole queue per engine
        # step (the heap has no early-exit iteration order)
        self._unstamped: List[Tuple[Tuple[float, int], Request]] = []
        self._next_rid = 0
        self.n_submitted = 0
        self.n_rejected = 0
        # rejection counts by AdmissionError.reason (the metrics plane's
        # admission-rejections-by-reason series reads this)
        self.n_rejected_by_reason: Dict[str, int] = {}

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, prompt, max_new: int, arrival: float = 0.0) -> Request:
        prompt = np.asarray(prompt, dtype=np.int32).reshape(-1)
        lim = self.limits
        try:
            if prompt.shape[0] < 1:
                raise AdmissionError("prompt must contain at least 1 token",
                                     reason="empty_prompt")
            if prompt.shape[0] > lim.max_prompt_len:
                raise AdmissionError(
                    f"prompt length {prompt.shape[0]} exceeds the admission "
                    f"budget max_prompt_len={lim.max_prompt_len}",
                    reason="prompt_len")
            if max_new < 1:
                raise AdmissionError(f"max_new must be >= 1, got {max_new}",
                                     reason="max_new")
            if max_new > lim.max_new_cap:
                raise AdmissionError(
                    f"max_new {max_new} exceeds the admission budget "
                    f"max_new_cap={lim.max_new_cap}", reason="max_new")
            total_cap = (lim.max_total_len if lim.max_total_len is not None
                         else lim.max_prompt_len + lim.max_new_cap)
            if prompt.shape[0] + max_new > total_cap:
                raise AdmissionError(
                    f"prompt_len + max_new = {prompt.shape[0] + max_new} "
                    f"exceeds the cache slot length {total_cap}",
                    reason="total_len")
            if len(self._pending) >= lim.max_queue:
                raise AdmissionError(
                    f"queue full ({lim.max_queue} pending requests)",
                    reason="queue_full")
        except AdmissionError as e:
            self.n_rejected += 1
            self.n_rejected_by_reason[e.reason] = (
                self.n_rejected_by_reason.get(e.reason, 0) + 1)
            raise
        req = Request(rid=self._next_rid, prompt=prompt, max_new=int(max_new),
                      arrival=float(arrival), state=QUEUED)
        self._next_rid += 1
        heapq.heappush(self._pending, ((req.arrival, req.rid), req))
        heapq.heappush(self._unstamped, ((req.arrival, req.rid), req))
        self.n_submitted += 1
        return req

    def pop_ready(self, now: float) -> Optional[Request]:
        """Oldest request whose arrival time has passed, or None."""
        if self._pending and self._pending[0][0][0] <= now:
            return heapq.heappop(self._pending)[1]
        return None

    def peek_ready(self, now: float) -> Optional[Request]:
        """The request ``pop_ready(now)`` would return, without removing
        it — schedulers check pool capacity (e.g. the paged plane's page
        budget) before committing to the admission."""
        if self._pending and self._pending[0][0][0] <= now:
            return self._pending[0][1]
        return None

    def mark_eligible(self, now: float, wall: float) -> None:
        """Stamp the wall-clock moment each request became servable (for
        time-to-first-token accounting that includes queueing delay)."""
        while self._unstamped and self._unstamped[0][0][0] <= now:
            r = heapq.heappop(self._unstamped)[1]
            if r.eligible_wall is None:
                r.eligible_wall = wall

    def next_arrival(self) -> Optional[float]:
        return self._pending[0][0][0] if self._pending else None

    def pending(self) -> List[Request]:
        """Snapshot of the queued requests in (arrival, rid) order without
        removing them — failover introspection: a fleet controller
        requeues a dead replica's queue onto the survivors."""
        return [r for _, r in sorted(self._pending)]

    def steal_latest(self, n: int) -> List[Request]:
        """Remove and return up to ``n`` pending requests, LATEST
        (arrival, rid) first — the work-stealing shed surface: a
        drift-tripped replica gives up the work it would serve last (the
        head of the FIFO keeps its place; stolen requests re-enter
        another replica's queue through the fleet requeue path).  Stale
        ``_unstamped`` entries are left behind on purpose: eligibility is
        stamped at most once per request, so a dangling entry is a no-op.
        """
        if n <= 0 or not self._pending:
            return []
        victims = heapq.nlargest(min(n, len(self._pending)), self._pending,
                                 key=lambda kr: kr[0])
        keys = {kr[0] for kr in victims}
        self._pending = [kr for kr in self._pending if kr[0] not in keys]
        heapq.heapify(self._pending)
        return [r for _, r in victims]
