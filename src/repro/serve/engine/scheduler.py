"""Iteration-batch former: retire, admit (capped), continue decodes.

Policy (prefill/decode interleaving):

  1. *Retire* finished requests first, freeing their cache capacity for
     this very iteration's admissions.
  2. *Admit* up to ``max_prefill_per_step`` eligible requests the pool
     can hold.  Capping prefills per iteration is what keeps decode from
     starving: a burst of long prompts is spread over several iterations
     while the in-flight decodes keep producing a token each step.
  3. *Decode* every in-flight request (including ones admitted this very
     step, whose first token already came from prefill logits).

Admission is the pool's call (``pool.can_admit``): the slot plane gates
on a free row, the paged plane on a free decode row AND enough
*unreserved pages* for the request's worst-case decode length — the
reservation is taken whole at admit time, so an in-flight request can
always grow its cache without preempting anyone (grow-on-decode is
infallible by construction).  With prefix sharing the reservation
shrinks to ``shared + private``: prompt pages the prefix index already
holds are attached (refcounted) instead of reserved, so template-heavy
traffic admits more concurrency from the same pool — the scheduler
itself is unchanged, because sharing only moves the pool's capacity
arithmetic.  Admission stays strictly FIFO among eligible requests: a
head-of-queue request that does not fit blocks the ones behind it (no
size-based overtaking, so large requests cannot starve).

Starvation-freedom is structural: every admitted request appears in every
subsequent decode batch until it has its ``max_new`` tokens, so it
finishes after exactly ``max_new - 1`` decode steps; and FIFO admission
plus retire-before-admit means every queued request is eventually
admitted whenever the engine keeps stepping.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from .queue import RequestQueue
from .request import DECODE, FINISHED, PREFILL, Request


@dataclasses.dataclass
class StepPlan:
    retired: List[Request]
    admit: List[Request]     # slot already assigned; need prefill this step
    decode: List[Request]    # the iteration's decode batch


class Scheduler:
    def __init__(self, queue: RequestQueue, pool,
                 max_prefill_per_step: int = 2, metrics=None):
        assert max_prefill_per_step >= 1
        self.queue = queue
        self.pool = pool   # SlotCachePool or PagedCachePool (same surface)
        self.max_prefill_per_step = int(max_prefill_per_step)
        self.active: Dict[int, Request] = {}
        # optional obs.MetricsRegistry: per-plan retire/admit counters and
        # the head-of-queue blocked counter (FIFO capacity stalls) — all
        # host-side dict bumps, nothing touches the dispatch path
        self.metrics = metrics

    @property
    def has_work(self) -> bool:
        return bool(self.active) or len(self.queue) > 0

    def plan(self, now: float) -> StepPlan:
        retired: List[Request] = []
        for rid in list(self.active):
            r = self.active[rid]
            if r.done:
                self.pool.release(r)
                r.slot = None
                r.state = FINISHED
                retired.append(self.active.pop(rid))

        admit: List[Request] = []
        while len(admit) < self.max_prefill_per_step:
            r = self.queue.peek_ready(now)
            if r is None:
                break
            if not self.pool.can_admit(r):
                # FIFO: a head request that doesn't fit waits (and blocks
                # everyone behind it — worth counting: a high stall count
                # with low occupancy means the pool is mis-sized)
                if self.metrics is not None:
                    self.metrics.counter("head_of_queue_stalls").inc()
                break
            self.queue.pop_ready(now)
            r.slot = self.pool.admit(r)
            r.state = PREFILL
            self.active[r.rid] = r
            admit.append(r)

        decode: List[Request] = []
        for rid in sorted(self.active):
            r = self.active[rid]
            if not r.done:       # max_new==1 requests finish at prefill
                r.state = DECODE
                decode.append(r)
        if self.metrics is not None and (retired or admit):
            self.metrics.counter("requests_retired").inc(len(retired))
            self.metrics.counter("requests_admitted").inc(len(admit))
        return StepPlan(retired=retired, admit=admit, decode=decode)
