"""Serving steps: batched prefill and single-token decode.

``decode_32k`` / ``long_500k`` dry-run cells lower ``decode_step`` (one new
token against a seq_len cache); ``prefill_32k`` lowers ``prefill``.
Caches shard their time axis over the model dim (LBP on the sequence
contraction — see models/transformer.cache_specs).

The continuous-batching engine (``serve.engine``) consumes these step
builders through the jit caches below — one decode compilation per
(config, rules) no matter how many requests are served.
``greedy_generate`` is the engine's reference oracle: under greedy
decoding the engine must reproduce its outputs token-for-token
(tests/test_serve_engine.py enforces this).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import transformer as T
from ..models.config import ModelConfig
from ..sharding.rules import Rules


def make_prefill_step(cfg: ModelConfig, rules: Rules):
    def step(params, tokens, cache, prefix_embeds=None):
        return T.prefill(params, cfg, rules, tokens, cache,
                         prefix_embeds=prefix_embeds)
    return step


def make_decode_step(cfg: ModelConfig, rules: Rules):
    def step(params, token, pos, cache):
        logits, cache = T.decode_step(params, cfg, rules, token, pos, cache)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, logits, cache
    return step


# ---------------------------------------------------------------------------
# jit caches: Rules hashes by its axis table (mesh is excluded from hash),
# so the cache key includes id(mesh) to keep two meshes with identical axis
# names from sharing a compiled step.
# ---------------------------------------------------------------------------

_STEP_CACHE: Dict[Tuple[str, ModelConfig, Rules, int], Any] = {}


def _cached(kind: str, cfg: ModelConfig, rules: Rules, builder):
    key = (kind, cfg, rules, id(rules.mesh))
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = jax.jit(builder(cfg, rules))
    return _STEP_CACHE[key]


def cached_prefill_step(cfg: ModelConfig, rules: Rules):
    return _cached("prefill", cfg, rules, make_prefill_step)


def cached_decode_step(cfg: ModelConfig, rules: Rules):
    return _cached("decode", cfg, rules, make_decode_step)


def greedy_generate(params, cfg: ModelConfig, rules: Rules, prompt,
                    max_new: int = 16):
    """Reference generation loop (examples/tests; small models only).

    This is the oracle the serving engine is checked against: one request,
    exact-length cache, greedy argmax at every step.
    """
    B, S = prompt.shape
    cache = T.init_cache(cfg, B, S + max_new)
    cache, logits = cached_prefill_step(cfg, rules)(params, prompt, cache)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    pos = jnp.full((B,), S, jnp.int32)
    step = cached_decode_step(cfg, rules)
    for _ in range(max_new - 1):
        nxt, _, cache = step(params, tok, pos, cache)
        tok = nxt[:, None]
        out.append(tok)
        pos = pos + 1
    return jnp.concatenate(out, axis=1)
