"""Serving steps: batched prefill, single-token decode, paged decode.

``decode_32k`` / ``long_500k`` dry-run cells lower ``decode_step`` (one new
token against a seq_len cache); ``prefill_32k`` lowers ``prefill``.
Caches shard their time axis over the model dim (LBP on the sequence
contraction — see models/transformer.cache_specs).

The continuous-batching engine (``serve.engine``) consumes these step
builders through the jit caches below — one decode compilation per
(config, rules) no matter how many requests are served.  The *paged*
builders wrap the same decode math in a page-table gather/scatter
(``serve.engine.cache_pool``): the physical page pool is reshaped into
the per-slot contiguous view inside the SAME jitted call, so a paged
decode step is still ONE dispatch and its logits are bit-compatible with
the slot plane's (masked positions contribute exact zeros).
``greedy_generate`` is the reference oracle for both planes: under greedy
decoding the engines must reproduce its outputs token-for-token
(tests/test_serve_engine.py enforces this).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import transformer as T
from ..models.config import ModelConfig
from ..sharding.rules import Rules


def make_prefill_step(cfg: ModelConfig, rules: Rules):
    def step(params, tokens, cache, prefix_embeds=None):
        return T.prefill(params, cfg, rules, tokens, cache,
                         prefix_embeds=prefix_embeds)
    return step


def make_decode_step(cfg: ModelConfig, rules: Rules):
    def step(params, token, pos, cache):
        logits, cache = T.decode_step(params, cfg, rules, token, pos, cache)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, logits, cache
    return step


def make_paged_decode_step(cfg: ModelConfig, rules: Rules):
    """One decode step against a paged pool: gather the per-slot view via
    the page table, decode, scatter the view back — one fused dispatch.
    ``pool`` leaves are (L, n_pages + 1, page_size, ...); ``table`` is the
    (n_slots, pages_per_slot) int32 READ page map and ``write_table`` the
    WRITE map (identical unless prefix sharing masks shared pages to the
    trash page — the copy-on-write discipline lives entirely in which
    map each half of the dispatch uses)."""
    from .engine.cache_pool import gather_page_view, scatter_page_view
    base = make_decode_step(cfg, rules)

    def step(params, token, pos, pool, table, write_table):
        view = gather_page_view(pool, table)
        next_token, logits, view = base(params, token, pos, view)
        pool = scatter_page_view(pool, view, write_table)
        return next_token, logits, pool
    return step


def make_paged_decode_scan(cfg: ModelConfig, rules: Rules, k: int):
    """``k`` fused decode steps on the paged plane in one dispatch.  The
    view is gathered once (via the READ map), the scan carries it (the
    page maps are fixed for the whole stretch — the engine claims every
    page the k steps will write *before* dispatching), and the pages are
    written back once via the WRITE map."""
    from .engine.cache_pool import gather_page_view, scatter_page_view
    base = make_decode_step(cfg, rules)

    def run(params, tok, pos, pool, table, write_table):
        view = gather_page_view(pool, table)

        def body(carry, _):
            tok, pos, view = carry
            nxt, _, view = base(params, tok[:, None], pos, view)
            return (nxt, pos + 1, view), nxt

        (tok, pos, view), stack = jax.lax.scan(body, (tok, pos, view),
                                               None, length=k)
        pool = scatter_page_view(pool, view, write_table)
        return pool, stack, tok, pos
    return run


# ---------------------------------------------------------------------------
# jit caches: Rules hashes by its axis table (mesh is excluded from hash),
# so the cache key includes id(mesh) to keep two meshes with identical axis
# names from sharing a compiled step.
# ---------------------------------------------------------------------------

_STEP_CACHE: Dict[Tuple[str, ModelConfig, Rules, int], Any] = {}


def _cached(kind: str, cfg: ModelConfig, rules: Rules, builder):
    key = (kind, cfg, rules, id(rules.mesh))
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = jax.jit(builder(cfg, rules))
    return _STEP_CACHE[key]


def cached_prefill_step(cfg: ModelConfig, rules: Rules):
    return _cached("prefill", cfg, rules, make_prefill_step)


def cached_decode_step(cfg: ModelConfig, rules: Rules):
    return _cached("decode", cfg, rules, make_decode_step)


def greedy_generate(params, cfg: ModelConfig, rules: Rules, prompt,
                    max_new: int = 16):
    """Reference generation loop (examples/tests; small models only).

    This is the oracle the serving engine is checked against: one request,
    exact-length cache, greedy argmax at every step.
    """
    B, S = prompt.shape
    cache = T.init_cache(cfg, B, S + max_new)
    cache, logits = cached_prefill_step(cfg, rules)(params, prompt, cache)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    pos = jnp.full((B,), S, jnp.int32)
    step = cached_decode_step(cfg, rules)
    for _ in range(max_new - 1):
        nxt, _, cache = step(params, tok, pos, cache)
        tok = nxt[:, None]
        out.append(tok)
        pos = pos + 1
    return jnp.concatenate(out, axis=1)
