"""Serving steps: batched prefill and single-token decode.

``decode_32k`` / ``long_500k`` dry-run cells lower ``decode_step`` (one new
token against a seq_len cache); ``prefill_32k`` lowers ``prefill``.
Caches shard their time axis over the model dim (LBP on the sequence
contraction — see models/transformer.cache_specs).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import transformer as T
from ..models.config import ModelConfig
from ..sharding.rules import Rules


def make_prefill_step(cfg: ModelConfig, rules: Rules):
    def step(params, tokens, cache, prefix_embeds=None):
        return T.prefill(params, cfg, rules, tokens, cache,
                         prefix_embeds=prefix_embeds)
    return step


def make_decode_step(cfg: ModelConfig, rules: Rules):
    def step(params, token, pos, cache):
        logits, cache = T.decode_step(params, cfg, rules, token, pos, cache)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, logits, cache
    return step


def greedy_generate(params, cfg: ModelConfig, rules: Rules, prompt,
                    max_new: int = 16):
    """Reference generation loop (examples/tests; small models only)."""
    B, S = prompt.shape
    cache = T.init_cache(cfg, B, S + max_new)
    cache, logits = T.prefill(params, cfg, rules, prompt, cache)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    pos = jnp.full((B,), S, jnp.int32)
    step = jax.jit(make_decode_step(cfg, rules))
    for _ in range(max_new - 1):
        nxt, _, cache = step(params, tok, pos, cache)
        tok = nxt[:, None]
        out.append(tok)
        pos = pos + 1
    return jnp.concatenate(out, axis=1)
