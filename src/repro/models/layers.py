"""Shared layers: RMSNorm, RoPE, SwiGLU, embedding, sharded chunked xent."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..sharding.rules import Rules, shard


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding.  x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu_ffn(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
               w_down: jax.Array, rules: Rules) -> jax.Array:
    """Column-parallel gate/up, LBP row-parallel down-projection.

    The down matmul contracts over the model-sharded ff dim — each device
    computes one layer (partial sum) of the output; GSPMD inserts the
    aggregation (all-reduce, or reduce-scatter under sequence parallelism —
    the paper's eager vs deferred aggregation).
    """
    from .tuning import reduce_pref_dtype
    h = jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
    h = shard(jax.nn.silu(h) * u, rules, "batch", None, "ff")
    from . import lbp_linear
    if lbp_linear.applicable(rules):
        return lbp_linear.lbp_row_parallel(h, w_down.astype(x.dtype), rules)
    out = jnp.einsum("bsf,fd->bsd", h, w_down.astype(x.dtype),
                     preferred_element_type=reduce_pref_dtype(x.dtype))
    return shard(out.astype(x.dtype), rules, "batch", "seq", None)


def embed_tokens(tokens: jax.Array, table: jax.Array, rules: Rules) -> jax.Array:
    """Vocab-sharded embedding lookup via one-hot matmul (TPU-friendly:
    the gather over a vocab-sharded table becomes a masked matmul and the
    cross-shard sum is a small all-reduce)."""
    out = jnp.take(table, tokens, axis=0).astype(jnp.bfloat16)
    return shard(out, rules, "batch", "seq", None)


def chunked_cross_entropy(
    x: jax.Array,                 # (B, S, d) final hidden
    table: jax.Array,             # (V, d) tied embedding (or lm head.T)
    labels: jax.Array,            # (B, S) int32
    rules: Rules,
    *,
    chunk: int = 512,
    z_loss: float = 1e-4,
    mask: Optional[jax.Array] = None,   # (B, S) 1=count
):
    """Mean token cross-entropy without materializing (B, S, V) logits.

    Scans over sequence chunks; per chunk the (B, c, V) logits live
    vocab-sharded on the model axis, and the max/logsumexp/label-pick
    reductions over V become small per-token collectives.  z-loss
    (MaxText-style) keeps the softmax normalizer bounded.
    """
    B, S, d = x.shape
    V = table.shape[0]
    chunk = min(chunk, S)
    if S % chunk:
        chunk = 1 if S < 2 else next(c for c in range(chunk, 0, -1) if S % c == 0)
    n = S // chunk
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)

    xc = x.reshape(B, n, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, n, chunk).swapaxes(0, 1)

    def step(carry, inp):
        loss_sum, z_sum, count = carry
        xi, li, mi = inp
        logits = jnp.einsum("bcd,vd->bcv", xi.astype(jnp.float32),
                            table.astype(jnp.float32))
        logits = shard(logits, rules, "batch", None, "vocab")
        m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        picked = jnp.sum(
            logits * jax.nn.one_hot(li, V, dtype=logits.dtype), axis=-1)
        nll = (lse - picked) * mi
        zl = jnp.square(lse) * mi
        return (loss_sum + nll.sum(), z_sum + zl.sum(), count + mi.sum()), None

    (loss_sum, z_sum, count), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
               jnp.zeros((), jnp.float32)), (xc, lc, mc))
    denom = jnp.maximum(count, 1.0)
    return loss_sum / denom + z_loss * z_sum / denom
