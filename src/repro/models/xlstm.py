"""xLSTM blocks: chunkwise-parallel mLSTM + sequential sLSTM (arXiv:2405.04517).

mLSTM (matrix memory, per head):
    C_t = f_t C_{t-1} + i_t k_t v_t^T      (hd x hd state)
    n_t = f_t n_{t-1} + i_t k_t
    h_t = C_t^T q_t / max(|n_t^T q_t|, 1)
computed in the chunkwise-parallel form: within a chunk everything is
matmuls against a decay matrix D[t,s] = (prod_{r=s+1..t} f_r) i_s — i.e.
the chunk dimension provides the contraction that the paper's layer
partition splits (DESIGN §Arch-applicability: LBP applies to the chunkwise
matmuls and projections; the sLSTM scalar recurrence does not).

sLSTM (scalar memory, per head, with per-head recurrent R matrices):
    z = tanh(Wz x + Rz h),  i/f/o = sigma(W. x + R. h)
    c_t = f c + i z;  n_t = f n + i;  h_t = o * c_t / n_t
inherently sequential -> lax.scan (6 of 48 blocks; documented).

Gates are sigmoid (the exponential-gate stabilizer of the original is
simplified away; DESIGN §assumption-changes).

Sharding: value/output head_dim shards over the model axis (head counts are
tiny — 4 — so head sharding would waste 4x; the hd_v=512 dim splits
cleanly; contraction over it in the out-projection is again LBP).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.rules import Rules, shard


class MLSTMState(NamedTuple):
    C: jax.Array   # (B, H, hd, hd)
    n: jax.Array   # (B, H, hd)
    lf_acc: jax.Array  # (B, H) accumulated log-f within current position (decode unused)


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, H, hd)
    n: jax.Array   # (B, H, hd)
    h: jax.Array   # (B, H, hd)


def _qkv_gates(x, p, H, hd):
    B, S, d = x.shape
    xf = x.astype(jnp.float32)
    q = jnp.einsum("bsd,dk->bsk", xf, p["w_q"].astype(jnp.float32)).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dk->bsk", xf, p["w_k"].astype(jnp.float32)).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,dk->bsk", xf, p["w_v"].astype(jnp.float32)).reshape(B, S, H, hd)
    i_gate = jax.nn.sigmoid(jnp.einsum("bsd,dh->bsh", xf, p["w_i"].astype(jnp.float32)))
    f_gate = jax.nn.sigmoid(jnp.einsum("bsd,dh->bsh", xf, p["w_f"].astype(jnp.float32)) + 1.0)
    return q, k, v, i_gate, f_gate


def mlstm_block(
    x: jax.Array,              # (B, S, d)
    p,
    rules: Rules,
    *,
    n_heads: int,
    head_dim: int,
    chunk: int = 64,
    state: Optional[MLSTMState] = None,
) -> Tuple[jax.Array, Optional[MLSTMState]]:
    B, S, d = x.shape
    H, hd = n_heads, head_dim
    q, k, v, ig, fg = _qkv_gates(x, p, H, hd)
    q = q * (float(hd) ** -0.5)
    v = shard(v, rules, "batch", None, None, "ff")

    if S == 1 and state is not None:
        # decode: recurrent single step
        C, n = state.C, state.n
        f1 = fg[:, 0, :, None, None]
        C = f1 * C + ig[:, 0, :, None, None] * jnp.einsum(
            "bhk,bhv->bhkv", k[:, 0], v[:, 0])
        n = fg[:, 0, :, None] * n + ig[:, 0, :, None] * k[:, 0]
        num = jnp.einsum("bhkv,bhk->bhv", C, q[:, 0])
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, q[:, 0]))
        h = num / jnp.maximum(den, 1.0)[..., None]
        hs = h[:, None]                                     # (B,1,H,hd)
        new_state = MLSTMState(C=C, n=n, lf_acc=state.lf_acc)
    else:
        c = min(chunk, S)
        while S % c:
            c -= 1
        nc = S // c
        qc = q.reshape(B, nc, c, H, hd)
        kc = k.reshape(B, nc, c, H, hd)
        vc = v.reshape(B, nc, c, H, hd)
        igc = ig.reshape(B, nc, c, H)
        lfc = jnp.log(jnp.maximum(fg, 1e-9)).reshape(B, nc, c, H)

        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)

        def step(carry, inp):
            C, n = carry
            qi, ki, vi, ii, lfi = inp                       # (B,c,H,*)
            cum = jnp.cumsum(lfi, axis=1)                   # (B,c,H)
            total = cum[:, -1]                              # (B,H)
            # D[t,s] = exp(cum_t - cum_s) * i_s   (t >= s)
            Dlog = cum[:, :, None] - cum[:, None, :]        # (B,c,c,H)
            tri = jnp.tril(jnp.ones((c, c), bool))
            D = jnp.where(tri[None, :, :, None], jnp.exp(Dlog) *
                          ii[:, None, :, :], 0.0)
            scores = jnp.einsum("bthd,bshd->btsh", qi, ki) * D
            intra = jnp.einsum("btsh,bshv->bthv", scores, vi)
            inter = jnp.einsum("bhkv,bthk->bthv", C,
                               qi * jnp.exp(cum)[..., None])
            den_intra = jnp.einsum("btsh,bshk,bthk->bth", D, ki, qi)
            den_inter = jnp.einsum("bhk,bthk->bth", n,
                                   qi * jnp.exp(cum)[..., None])
            den = jnp.abs(den_intra + den_inter)
            h = (intra + inter) / jnp.maximum(den, 1.0)[..., None]
            # state update
            decay_s = jnp.exp(total[:, None] - cum) * ii    # (B,c,H)
            C = jnp.exp(total)[:, :, None, None] * C + jnp.einsum(
                "bshk,bshv,bsh->bhkv", ki, vi, decay_s)
            n = jnp.exp(total)[..., None] * n + jnp.einsum(
                "bshk,bsh->bhk", ki, decay_s)
            return (C, n), h

        (C, n), hs = jax.lax.scan(
            step, (C0, n0),
            (qc.swapaxes(0, 1), kc.swapaxes(0, 1), vc.swapaxes(0, 1),
             igc.swapaxes(0, 1), lfc.swapaxes(0, 1)))
        hs = hs.swapaxes(0, 1).reshape(B, S, H, hd)
        new_state = None
        if state is not None:
            new_state = MLSTMState(C=C, n=n, lf_acc=jnp.zeros((B, H), jnp.float32))

    o = jax.nn.sigmoid(jnp.einsum("bsd,dk->bsk", x.astype(jnp.float32),
                                  p["w_o"].astype(jnp.float32)))
    hflat = hs.reshape(B, hs.shape[1], H * hd) * o
    hflat = shard(hflat, rules, "batch", None, "ff")
    y = jnp.einsum("bsk,kd->bsd", hflat, p["w_out"].astype(jnp.float32))
    return shard(y.astype(x.dtype), rules, "batch", "seq", None), new_state


def slstm_block(
    x: jax.Array,
    p,
    rules: Rules,
    *,
    n_heads: int,
    head_dim: int,
    state: Optional[SLSTMState] = None,
) -> Tuple[jax.Array, Optional[SLSTMState]]:
    B, S, d = x.shape
    H, hd = n_heads, head_dim
    xf = x.astype(jnp.float32)
    pre = {g: jnp.einsum("bsd,dk->bsk", xf, p[f"w_{g}"].astype(jnp.float32)
                         ).reshape(B, S, H, hd) for g in ("z", "i", "f", "o")}
    R = {g: p[f"r_{g}"].astype(jnp.float32) for g in ("z", "i", "f", "o")}

    if state is None:
        zeros = jnp.zeros((B, H, hd), jnp.float32)
        st = SLSTMState(c=zeros, n=zeros, h=zeros)
    else:
        st = SLSTMState(*(s.astype(jnp.float32) for s in state))

    def step(carry, inp):
        c, n, h = carry
        pz, pi, pf, po = inp
        rec = {g: jnp.einsum("bhk,hkv->bhv", h, R[g]) for g in ("z", "i", "f", "o")}
        z = jnp.tanh(pz + rec["z"])
        i = jax.nn.sigmoid(pi + rec["i"])
        f = jax.nn.sigmoid(pf + rec["f"] + 1.0)
        o = jax.nn.sigmoid(po + rec["o"])
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(n, 1e-6)
        return (c, n, h), h

    seq = tuple(pre[g].swapaxes(0, 1) for g in ("z", "i", "f", "o"))
    (c, n, h), hs = jax.lax.scan(step, (st.c, st.n, st.h), seq)
    hs = hs.swapaxes(0, 1).reshape(B, S, H * hd)

    y = jnp.einsum("bsk,kd->bsd", hs, p["w_out"].astype(jnp.float32))
    y = shard(y.astype(x.dtype), rules, "batch", "seq", None)
    new_state = None
    if state is not None:
        new_state = SLSTMState(c=c.astype(state.c.dtype),
                               n=n.astype(state.n.dtype),
                               h=h.astype(state.h.dtype))
    return y, new_state
