"""Explicit LBP row-parallel linear layer (shard_map) for the model zoo.

The implicit path (einsum + with_sharding_constraint) leaves the layer
aggregation to GSPMD, which under sequence parallelism emits a FULL
all-reduce followed by a local slice — paying 2(p-1)/p bytes where the
paper's deferred aggregation needs only (p-1)/p.  This module IS the
paper's technique wired into the transformer: each device holds k_i = K/p
columns/rows of the weight, computes one layer of the output, and the
layers are combined with reduce-scatter (sequence-sharded output, "scatter"
mode) or all-reduce ("allreduce" mode, the eager paper-faithful default).

FSDP composes inside: the weight's embed dim arrives data-sharded and is
all-gathered in the shard_map body (exactly what GSPMD does implicitly).

Only used when the tuning flag ``explicit_lbp_scatter`` is on AND the rules
carry real mesh axes; the null-rules smoke path keeps the plain einsum.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import collectives
from ..sharding.rules import Rules


def _axis_or_none(ax) -> Optional[str]:
    if ax is None:
        return None
    return ax if isinstance(ax, str) else (ax[0] if len(ax) == 1 else None)


def applicable(rules: Rules) -> bool:
    from .tuning import TUNING
    return (TUNING.explicit_lbp_scatter
            and rules.mesh is not None
            and isinstance(_axis_or_none(rules.ff), str))


def lbp_row_parallel(h: jax.Array, w: jax.Array, rules: Rules) -> jax.Array:
    """h: (B, S, K) with K sharded on the model axis; w: (K, d) sharded
    (model, embed).  Returns (B, S, d); S sharded on model when rules.seq
    is set (deferred aggregation), else replicated (eager psum)."""
    model_ax = _axis_or_none(rules.ff)
    data_ax = _axis_or_none(rules.embed)
    mode = "scatter" if rules.seq is not None else "allreduce"

    in_h = P(rules.batch, None, model_ax)
    in_w = P(model_ax, data_ax)
    out = collectives.out_spec(mode, model_ax, (rules.batch, None, None),
                               scatter_dim=1)

    def local(hl, wl):
        if data_ax is not None:
            wl = jax.lax.all_gather(wl, data_ax, axis=1, tiled=True)
        partial = jnp.einsum("bsf,fd->bsd", hl, wl)   # this device's layer
        return collectives.aggregate(partial, mode, model_ax, scatter_dim=1)

    fn = rules.shard_map(local, in_specs=(in_h, in_w), out_specs=out)
    return fn(h, w)
