"""Explicit LBP row-parallel linear layer (shard_map) for the model zoo.

The implicit path (einsum + with_sharding_constraint) leaves the layer
aggregation to GSPMD, which under sequence parallelism emits a FULL
all-reduce followed by a local slice — paying 2(p-1)/p bytes where the
paper's deferred aggregation needs only (p-1)/p.  This module IS the
paper's technique wired into the transformer: each device holds k_i = K/p
columns/rows of the weight, computes one layer of the output, and the
layers are combined with reduce-scatter (sequence-sharded output, "scatter"
mode) or all-reduce ("allreduce" mode, the eager paper-faithful default).

FSDP composes inside: the weight's embed dim arrives data-sharded and is
all-gathered in the shard_map body (exactly what GSPMD does implicitly).

With ``TUNING.overlap_streaming`` on, the body switches to the overlapped
layer-streaming plane (``core/overlap.py``): the FSDP all-gather becomes a
ppermute ring whose shards are matmul'd one column block per hop while the
next shard is in flight, and the layer aggregation uses the streamed
"stream_scatter"/"stream_gather" modes — the paper's simultaneous start
(distribute layer j+1 while multiplying layer j) lifted from the kernel to
the mesh, so the step is bounded by max(comm, compute) instead of the sum.

Only used when the tuning flag ``explicit_lbp_scatter`` is on AND the rules
carry real mesh axes; the null-rules smoke path keeps the plain einsum.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import collectives, overlap
from ..sharding.rules import Rules


def _axis_or_none(ax) -> Optional[str]:
    if ax is None:
        return None
    return ax if isinstance(ax, str) else (ax[0] if len(ax) == 1 else None)


def applicable(rules: Rules) -> bool:
    from .tuning import TUNING
    return (TUNING.explicit_lbp_scatter
            and rules.mesh is not None
            and isinstance(_axis_or_none(rules.ff), str))


def aggregation_mode(rules: Rules, *, streaming: Optional[bool] = None,
                     bidir: Optional[bool] = None) -> str:
    """The registry mode this layer aggregates with under ``rules``:
    deferred (sequence-sharded) when rules.seq is set, replicated
    otherwise; the stream_* variant when the overlap plane is on, and
    its *_bidir half-ring flavour when ``TUNING.overlap_bidir`` asks for
    direction-split permute chains."""
    from .tuning import TUNING
    if streaming is None:
        streaming = TUNING.overlap_streaming
    if bidir is None:
        bidir = TUNING.overlap_bidir
    suffix = "_bidir" if bidir else ""
    if rules.seq is not None:
        return "stream_scatter" + suffix if streaming else "scatter"
    return "stream_gather" + suffix if streaming else "allreduce"


def lbp_row_parallel(h: jax.Array, w: jax.Array, rules: Rules) -> jax.Array:
    """h: (B, S, K) with K sharded on the model axis; w: (K, d) sharded
    (model, embed).  Returns (B, S, d); S sharded on model when rules.seq
    is set (deferred aggregation), else replicated (eager psum)."""
    from .tuning import TUNING
    streaming = TUNING.overlap_streaming
    model_ax = _axis_or_none(rules.ff)
    data_ax = _axis_or_none(rules.embed)
    mode = aggregation_mode(rules, streaming=streaming)

    in_h = P(rules.batch, None, model_ax)
    in_w = P(model_ax, data_ax)
    out = collectives.out_spec(mode, model_ax, (rules.batch, None, None),
                               scatter_dim=1)

    def local(hl, wl):
        if data_ax is not None:
            if streaming:
                # weight shards ride the ring; one column block of this
                # device's layer is matmul'd per hop
                partial = overlap.streamed_gather_matmul(hl, wl, data_ax)
            else:
                wl = jax.lax.all_gather(wl, data_ax, axis=1, tiled=True)
                partial = jnp.einsum("bsf,fd->bsd", hl, wl)
        elif streaming and mode == "stream_scatter":
            # no FSDP ring: fuse the tile matmuls directly into the
            # accumulate-and-forward aggregation ring
            return overlap.streamed_scatter_matmul(hl, wl, model_ax,
                                                   scatter_dim=1)
        else:
            partial = jnp.einsum("bsf,fd->bsd", hl, wl)
        return collectives.aggregate(partial, mode, model_ax, scatter_dim=1)

    fn = rules.shard_map(local, in_specs=(in_h, in_w), out_specs=out)
    return fn(h, w)
