"""Decoder assembly for every assigned family: init, train forward, serving.

Layer stacking uses ``jax.lax.scan`` over stacked parameters (one traced
layer body -> small HLO even at 94 layers) with ``jax.checkpoint`` (remat)
around the body for training.  Heterogeneous stacks (recurrentgemma's
(R,R,A) pattern, xLSTM's 7:1 mLSTM:sLSTM) scan over macro-groups.

Parameters are float32 masters; compute casts to bfloat16 at use (the cast
sits below the FSDP all-gather, so gathers move bf16 bytes).

Caches (serving):
  attention  k/v: (L, B, T, KVe, hd)
  rg-lru     conv: (L_rec, B, W-1, lru), h: (L_rec, B, lru)
  mLSTM      C: (L_m, B, H, hd, hd), n: (L_m, B, H, hd)
  sLSTM      c/n/h: (L_s, B, H, hd)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.rules import Rules, shard
from .attention import decode_attention, flash_attention_xla
from .config import ModelConfig
from .layers import chunked_cross_entropy, embed_tokens, rms_norm, rope, swiglu_ffn
from .moe import moe_ffn
from .rglru import RGLRUState, recurrent_block
from .xlstm import MLSTMState, SLSTMState, mlstm_block, slstm_block

AUX_COEF = 0.01


def kv_eff(cfg: ModelConfig) -> int:
    """KV head count in parameters and caches (see ModelConfig.kv_param)."""
    return cfg.kv_param


# ===========================================================================
# init
# ===========================================================================

def _norm_init(shape):
    return jnp.zeros(shape, jnp.float32)


def _dense_init(key, shape, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale or fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def _attn_params(key, cfg: ModelConfig, L: int) -> Dict[str, jax.Array]:
    d, hd = cfg.d_model, cfg.hd
    Hp, KVe = cfg.h_padded, kv_eff(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "wq": _dense_init(ks[0], (L, d, Hp * hd)),
        "wk": _dense_init(ks[1], (L, d, KVe * hd)),
        "wv": _dense_init(ks[2], (L, d, KVe * hd)),
        "wo": _dense_init(ks[3], (L, Hp * hd, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((L, hd), jnp.float32)
        p["k_norm"] = jnp.zeros((L, hd), jnp.float32)
    return p


def _ffn_params(key, cfg: ModelConfig, L: int) -> Dict[str, jax.Array]:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if cfg.is_moe:
        E, ffe = cfg.n_experts, cfg.d_ff
        return {
            "router": _dense_init(ks[0], (L, d, E)),
            "w_gate": _dense_init(ks[1], (L, E, d, ffe)),
            "w_up": _dense_init(ks[2], (L, E, d, ffe)),
            "w_down": _dense_init(ks[3], (L, E, ffe, d)),
        }
    return {
        "w_gate": _dense_init(ks[0], (L, d, cfg.d_ff)),
        "w_up": _dense_init(ks[1], (L, d, cfg.d_ff)),
        "w_down": _dense_init(ks[2], (L, cfg.d_ff, d)),
    }


def _rec_params(key, cfg: ModelConfig, L: int) -> Dict[str, jax.Array]:
    d, lru = cfg.d_model, cfg.lru
    ks = jax.random.split(key, 4)
    return {
        "w_gate": _dense_init(ks[0], (L, d, lru)),
        "w_rec": _dense_init(ks[1], (L, d, lru)),
        "conv_k": _dense_init(ks[2], (L, cfg.conv_width, lru), scale=0.1),
        "conv_b": jnp.zeros((L, lru), jnp.float32),
        "gate_a_w": jnp.ones((L, lru), jnp.float32),
        "gate_a_b": jnp.zeros((L, lru), jnp.float32),
        "gate_x_w": jnp.ones((L, lru), jnp.float32),
        "gate_x_b": jnp.zeros((L, lru), jnp.float32),
        "lambda_param": jnp.full((L, lru), 0.5, jnp.float32),
        "w_out": _dense_init(ks[3], (L, lru, d)),
    }


def _mlstm_params(key, cfg: ModelConfig, shape_prefix) -> Dict[str, jax.Array]:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 6)
    return {
        "ln": jnp.zeros(shape_prefix + (d,), jnp.float32),
        "w_q": _dense_init(ks[0], shape_prefix + (d, H * hd)),
        "w_k": _dense_init(ks[1], shape_prefix + (d, H * hd)),
        "w_v": _dense_init(ks[2], shape_prefix + (d, H * hd)),
        "w_i": _dense_init(ks[3], shape_prefix + (d, H)),
        "w_f": _dense_init(ks[3], shape_prefix + (d, H)),
        "w_o": _dense_init(ks[4], shape_prefix + (d, H * hd)),
        "w_out": _dense_init(ks[5], shape_prefix + (H * hd, d)),
    }


def _slstm_params(key, cfg: ModelConfig, L: int) -> Dict[str, jax.Array]:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 9)
    p = {"ln": jnp.zeros((L, d), jnp.float32),
         "w_out": _dense_init(ks[8], (L, H * hd, d))}
    for t, g in enumerate(("z", "i", "f", "o")):
        p[f"w_{g}"] = _dense_init(ks[t], (L, d, H * hd))
        p[f"r_{g}"] = _dense_init(ks[4 + t], (L, H, hd, hd), scale=hd ** -0.5)
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": _dense_init(ks[0], (cfg.vocab_size, cfg.d_model), scale=0.02),
        "final_norm": _norm_init((cfg.d_model,)),
    }
    if cfg.family == "hybrid":
        unit = cfg.block_pattern
        G = cfg.n_layers // len(unit)
        tail = cfg.n_layers - G * len(unit)
        blocks: Dict[str, Any] = {}
        for i, kind in enumerate(unit):
            sub = {"ln_mix": _norm_init((G, cfg.d_model)),
                   "ln_mlp": _norm_init((G, cfg.d_model))}
            if kind == "A":
                sub.update(_attn_params(ks[1 + i], cfg, G))
            else:
                sub.update(_rec_params(ks[1 + i], cfg, G))
            sub.update({f"mlp_{k}": v for k, v in
                        _ffn_params(jax.random.fold_in(ks[1 + i], 7), cfg, G).items()})
            blocks[f"pos{i}"] = sub
        params["groups"] = blocks
        if tail:
            sub = {"ln_mix": _norm_init((tail, cfg.d_model)),
                   "ln_mlp": _norm_init((tail, cfg.d_model))}
            sub.update(_rec_params(ks[6], cfg, tail))
            sub.update({f"mlp_{k}": v for k, v in
                        _ffn_params(ks[7], cfg, tail).items()})
            params["tail"] = sub
    elif cfg.family == "ssm":
        m = cfg.mlstm_per_group
        G = cfg.n_layers // (m + 1)
        params["mlstm"] = _mlstm_params(ks[1], cfg, (G, m))
        params["slstm"] = _slstm_params(ks[2], cfg, G)
    else:
        L = cfg.n_layers
        blocks = {"ln1": _norm_init((L, cfg.d_model)),
                  "ln2": _norm_init((L, cfg.d_model))}
        blocks.update(_attn_params(ks[1], cfg, L))
        blocks.update(_ffn_params(ks[2], cfg, L))
        params["blocks"] = blocks
    return params


# ===========================================================================
# parameter partition specs
# ===========================================================================

def param_specs(cfg: ModelConfig, rules: Rules):
    """PartitionSpec pytree matching init_params (FSDP embed dim + TP)."""
    P = rules.spec
    kv_ax = "kv_heads" if kv_eff(cfg) % cfg.tp == 0 else None

    def attn(prefix=""):
        s = {
            prefix + "wq": P(None, "embed", "heads"),
            prefix + "wk": P(None, "embed", kv_ax),
            prefix + "wv": P(None, "embed", kv_ax),
            prefix + "wo": P(None, "heads", "embed"),
        }
        if cfg.qk_norm:
            s[prefix + "q_norm"] = P(None, None)
            s[prefix + "k_norm"] = P(None, None)
        return s

    def ffn(prefix=""):
        if cfg.is_moe:
            return {
                prefix + "router": P(None, "embed", None),
                prefix + "w_gate": P(None, "expert", "embed", None),
                prefix + "w_up": P(None, "expert", "embed", None),
                prefix + "w_down": P(None, "expert", None, "embed"),
            }
        return {
            prefix + "w_gate": P(None, "embed", "ff"),
            prefix + "w_up": P(None, "embed", "ff"),
            prefix + "w_down": P(None, "ff", "embed"),
        }

    def rec(prefix="", extra_dims=1):
        n = (None,) * extra_dims
        return {
            prefix + "w_gate": P(*n, "embed", "ff"),
            prefix + "w_rec": P(*n, "embed", "ff"),
            prefix + "conv_k": P(*n, None, "ff"),
            prefix + "conv_b": P(*n, "ff"),
            prefix + "gate_a_w": P(*n, "ff"),
            prefix + "gate_a_b": P(*n, "ff"),
            prefix + "gate_x_w": P(*n, "ff"),
            prefix + "gate_x_b": P(*n, "ff"),
            prefix + "lambda_param": P(*n, "ff"),
            prefix + "w_out": P(*n, "ff", "embed"),
        }

    specs: Dict[str, Any] = {
        "embed": P("vocab", "embed"),
        "final_norm": P(None),
    }
    if cfg.family == "hybrid":
        groups = {}
        unit = cfg.block_pattern
        for i, kind in enumerate(unit):
            sub = {"ln_mix": P(None, None), "ln_mlp": P(None, None)}
            sub.update(attn() if kind == "A" else rec())
            sub.update({f"mlp_{k}": v for k, v in ffn().items()})
            groups[f"pos{i}"] = sub
        specs["groups"] = groups
        if cfg.n_layers % len(unit):
            sub = {"ln_mix": P(None, None), "ln_mlp": P(None, None)}
            sub.update(rec())
            sub.update({f"mlp_{k}": v for k, v in ffn().items()})
            specs["tail"] = sub
    elif cfg.family == "ssm":
        n2 = (None, None)
        specs["mlstm"] = {
            "ln": P(*n2, None),
            "w_q": P(*n2, "embed", None),
            "w_k": P(*n2, "embed", None),
            "w_v": P(*n2, "embed", "ff"),
            "w_i": P(*n2, "embed", None),
            "w_f": P(*n2, "embed", None),
            "w_o": P(*n2, "embed", "ff"),
            "w_out": P(*n2, "ff", "embed"),
        }
        sl = {"ln": P(None, None), "w_out": P(None, None, "embed")}
        for g in ("z", "i", "f", "o"):
            sl[f"w_{g}"] = P(None, "embed", None)
            sl[f"r_{g}"] = P(None, None, None, None)
        specs["slstm"] = sl
    else:
        blocks = {"ln1": P(None, None), "ln2": P(None, None)}
        blocks.update(attn())
        blocks.update(ffn())
        specs["blocks"] = blocks
    return specs


# ===========================================================================
# block bodies
# ===========================================================================

def _attention_mix(x, p, cfg: ModelConfig, rules: Rules, positions,
                   cache_kv=None, pos=None, window: int = 0):
    """Pre-norm attention.  cache_kv=(k,v) for serving; returns (y, new_kv).

    Sharding strategy (DESIGN.md §5):
      * train/prefill compute: KV heads are repeated transiently to
        ``cfg.kv_flash`` (a multiple of tp) so the flash tiles shard tp-ways
        even for KV=8/4/1 archs;
      * caches store TRUE KV heads and shard the TIME axis over the model
        axis ("kv_time") — decode attention contracts over time, so each
        device computes a partial (layer!) of the output and the softmax
        normalizer: the paper's layer partition applied to the sequence
        contraction (flash-decoding).  Aggregation = the small all-reduces
        GSPMD emits for the T-reductions.
    """
    B, S, d = x.shape
    hd, Hp, KVp = cfg.hd, cfg.h_padded, cfg.kv_param
    h = rms_norm(x, p["ln1"] if "ln1" in p else p["ln_mix"], cfg.norm_eps)
    q = jnp.einsum("bsd,dk->bsk", h, p["wq"].astype(h.dtype)).reshape(B, S, Hp, hd)
    k = jnp.einsum("bsd,dk->bsk", h, p["wk"].astype(h.dtype)).reshape(B, S, KVp, hd)
    v = jnp.einsum("bsd,dk->bsk", h, p["wv"].astype(h.dtype)).reshape(B, S, KVp, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, rules, "batch", None, "heads", None)

    def _flash(q, k, v):
        KVf = cfg.kv_flash
        r = KVf // KVp
        if r > 1:
            if rules.seq is not None:
                # under sequence parallelism, gather the seq dim BEFORE the
                # head repeat: repeating a seq-sharded tensor into a
                # head-sharded layout makes GSPMD fall back to involuntary
                # full replication (§Perf iteration).
                k = shard(k, rules, "batch", None, None, None)
                v = shard(v, rules, "batch", None, None, None)
            k = jnp.repeat(k, r, axis=2)
            v = jnp.repeat(v, r, axis=2)
        k = shard(k, rules, "batch", None, "kv_heads", None)
        v = shard(v, rules, "batch", None, "kv_heads", None)
        qg = q.reshape(B, S, KVf, Hp // KVf, hd)
        o = flash_attention_xla(qg, k, v, True, window)
        return o.reshape(B, S, Hp, hd)

    new_kv = None
    if cache_kv is not None:
        ck, cv = cache_kv   # (B, Tc, KVp, hd), time sharded over "kv_time"
        Tc = ck.shape[1]
        # windowed archs keep a ring buffer of size window: slot s holds the
        # most recent absolute position congruent to s (k/v carry RoPE, so
        # attention is slot-order invariant).
        ring = window > 0 and Tc <= window
        if S == 1:  # decode: insert, then LBP-over-time attention
            wpos = pos % Tc if ring else pos
            ck = jax.vmap(lambda c, kk, pp: jax.lax.dynamic_update_slice_in_dim(
                c, kk, pp, 0))(ck, k[:, 0:1].astype(ck.dtype), wpos)
            cv = jax.vmap(lambda c, vv, pp: jax.lax.dynamic_update_slice_in_dim(
                c, vv, pp, 0))(cv, v[:, 0:1].astype(cv.dtype), wpos)
            qg = q.reshape(B, S, KVp, Hp // KVp, hd)
            # ring: every slot is inside the window by construction -> only
            # the "not written yet" mask (t <= pos) applies.
            o = decode_attention(qg, ck, cv, pos,
                                 window=0 if ring else window)
            o = o.reshape(B, S, Hp, hd)
        else:       # prefill: write true-KV cache, attend with repeats
            from .tuning import TUNING
            kc, vc = k, v
            if TUNING.cache_write_constraint:
                # match the cache's (batch, kv_time) layout before the
                # insert: without this GSPMD falls back to involuntary full
                # replication when resharding into the time-sharded cache.
                kc = shard(kc, rules, "batch", "kv_time", None, None)
                vc = shard(vc, rules, "batch", "kv_time", None, None)
            if S >= Tc:   # windowed cache keeps the trailing Tc positions,
                # rolled so slot == absolute_position % Tc (ring invariant
                # for decode continuation; no-op when Tc divides S).
                ck = jnp.roll(kc[:, S - Tc:], S % Tc, axis=1).astype(ck.dtype)
                cv = jnp.roll(vc[:, S - Tc:], S % Tc, axis=1).astype(cv.dtype)
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    ck, kc.astype(ck.dtype), 0, 1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cv, vc.astype(cv.dtype), 0, 1)
            o = _flash(q, k, v)
        new_kv = (ck, cv)
    else:
        o = _flash(q, k, v)
    o = shard(o, rules, "batch", None, "heads", None)
    # LBP row-parallel out-projection: contraction over model-sharded heads.
    from . import lbp_linear
    from .tuning import reduce_pref_dtype
    if lbp_linear.applicable(rules):
        y = lbp_linear.lbp_row_parallel(
            o.reshape(B, S, Hp * hd).astype(x.dtype),
            p["wo"].astype(x.dtype), rules)
        return y, new_kv
    y = jnp.einsum("bshk,hkD->bsD", o.astype(x.dtype),
                   p["wo"].reshape(Hp, hd, d).astype(x.dtype),
                   preferred_element_type=reduce_pref_dtype(x.dtype))
    return shard(y.astype(x.dtype), rules, "batch", "seq", None), new_kv


def _ffn_mix(x, p, cfg: ModelConfig, rules: Rules, prefix=""):
    """Pre-norm FFN (dense SwiGLU or MoE). Returns (y, aux)."""
    ln = p["ln2"] if "ln2" in p else p["ln_mlp"]
    h = rms_norm(x, ln, cfg.norm_eps)
    if cfg.is_moe:
        return moe_ffn(h, p[prefix + "router"], p[prefix + "w_gate"],
                       p[prefix + "w_up"], p[prefix + "w_down"], rules,
                       experts_per_token=cfg.experts_per_token,
                       capacity_factor=cfg.capacity_factor)
    y = swiglu_ffn(h, p[prefix + "w_gate"], p[prefix + "w_up"],
                   p[prefix + "w_down"], rules)
    return y, jnp.zeros((), jnp.float32)


# ===========================================================================
# forward (training / no-cache): returns final hidden + aux
# ===========================================================================

def forward_hidden(params, cfg: ModelConfig, rules: Rules, tokens,
                   prefix_embeds=None, remat: bool = True):
    B = tokens.shape[0]
    x = embed_tokens(tokens, params["embed"], rules)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    x = shard(x, rules, "batch", "seq", None)

    if cfg.family == "hybrid":
        x, aux = _hybrid_stack(x, params, cfg, rules, positions, remat)
    elif cfg.family == "ssm":
        x, aux = _ssm_stack(x, params, cfg, rules, remat)
    else:
        x, aux = _uniform_stack(x, params, cfg, rules, positions, remat)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def _uniform_stack(x, params, cfg, rules, positions, remat):
    def body(carry, layer_p):
        x, aux = carry
        a, _ = _attention_mix(x, layer_p, cfg, rules, positions)
        x = x + a
        f, al = _ffn_mix(x, layer_p, cfg, rules)
        return (x + f, aux + al), None

    fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    return x, aux


def _hybrid_stack(x, params, cfg, rules, positions, remat):
    unit = cfg.block_pattern

    def group_body(carry, group_p):
        x, aux = carry
        for i, kind in enumerate(unit):
            p = group_p[f"pos{i}"]
            if kind == "A":
                a, _ = _attention_mix(x, p, cfg, rules, positions,
                                      window=cfg.window)
            else:
                a, _ = recurrent_block(
                    rms_norm(x, p["ln_mix"], cfg.norm_eps), p, rules)
            x = x + a
            f, al = _ffn_mix(x, p, cfg, rules, prefix="mlp_")
            x = x + f
            aux = aux + al
        return (x, aux), None

    fn = jax.checkpoint(group_body) if remat else group_body
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                               params["groups"])
    if "tail" in params:
        def tail_body(carry, p):
            x, aux = carry
            a, _ = recurrent_block(
                rms_norm(x, p["ln_mix"], cfg.norm_eps), p, rules)
            x = x + a
            f, al = _ffn_mix(x, p, cfg, rules, prefix="mlp_")
            return (x + f, aux + al), None
        fn = jax.checkpoint(tail_body) if remat else tail_body
        (x, aux), _ = jax.lax.scan(fn, (x, aux), params["tail"])
    return x, aux


def _ssm_stack(x, params, cfg, rules, remat):
    H, hd = cfg.n_heads, cfg.hd

    def group_body(carry, group_p):
        x, aux = carry
        mp, sp = group_p

        def m_body(xc, lp):
            h = rms_norm(xc, lp["ln"], cfg.norm_eps)
            y, _ = mlstm_block(h, lp, rules, n_heads=H, head_dim=hd,
                               chunk=cfg.mlstm_chunk)
            return xc + y, None

        x, _ = jax.lax.scan(m_body, x, mp)
        h = rms_norm(x, sp["ln"], cfg.norm_eps)
        y, _ = slstm_block(h, sp, rules, n_heads=H, head_dim=hd)
        return (x + y, aux), None

    fn = jax.checkpoint(group_body) if remat else group_body
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                               (params["mlstm"], params["slstm"]))
    return x, aux


# ===========================================================================
# loss
# ===========================================================================

def loss_fn(params, cfg: ModelConfig, rules: Rules, batch,
            remat: bool = True):
    """Next-token CE over the token region (prefix positions excluded)."""
    tokens = batch["tokens"]
    prefix = batch.get("prefix_embeds")
    hidden, aux = forward_hidden(params, cfg, rules, tokens, prefix, remat)
    Pfx = 0 if prefix is None else prefix.shape[1]
    h_tok = hidden[:, Pfx:, :]
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:], jnp.float32),
         jnp.zeros_like(tokens[:, :1], jnp.float32)], axis=1)
    ce = chunked_cross_entropy(h_tok, params["embed"], labels, rules,
                               mask=mask)
    return ce + AUX_COEF * aux


# ===========================================================================
# serving: cache init, prefill, decode
# ===========================================================================

def init_cache(cfg: ModelConfig, B: int, T: int, dtype=jnp.bfloat16):
    hd, KVe = cfg.hd, kv_eff(cfg)
    if cfg.family == "hybrid":
        unit = cfg.block_pattern
        G = cfg.n_layers // len(unit)
        tail = cfg.n_layers - G * len(unit)
        cache: Dict[str, Any] = {}
        Tw = min(T, cfg.window) if cfg.window else T
        for i, kind in enumerate(unit):
            if kind == "A":
                cache[f"pos{i}"] = (
                    jnp.zeros((G, B, Tw, KVe, hd), dtype),
                    jnp.zeros((G, B, Tw, KVe, hd), dtype))
            else:
                cache[f"pos{i}"] = RGLRUState(
                    conv=jnp.zeros((G, B, cfg.conv_width - 1, cfg.lru),
                                   jnp.float32),
                    h=jnp.zeros((G, B, cfg.lru), jnp.float32))
        if tail:
            cache["tail"] = RGLRUState(
                conv=jnp.zeros((tail, B, cfg.conv_width - 1, cfg.lru),
                               jnp.float32),
                h=jnp.zeros((tail, B, cfg.lru), jnp.float32))
        return cache
    if cfg.family == "ssm":
        m = cfg.mlstm_per_group
        G = cfg.n_layers // (m + 1)
        H, hd = cfg.n_heads, cfg.hd
        return {
            "mlstm": MLSTMState(
                C=jnp.zeros((G, m, B, H, hd, hd), jnp.float32),
                n=jnp.zeros((G, m, B, H, hd), jnp.float32),
                lf_acc=jnp.zeros((G, m, B, H), jnp.float32)),
            "slstm": SLSTMState(
                c=jnp.zeros((G, B, H, hd), jnp.float32),
                n=jnp.zeros((G, B, H, hd), jnp.float32),
                h=jnp.zeros((G, B, H, hd), jnp.float32)),
        }
    L = cfg.n_layers
    return {"k": jnp.zeros((L, B, T, KVe, hd), dtype),
            "v": jnp.zeros((L, B, T, KVe, hd), dtype)}


def cache_specs(cfg: ModelConfig, rules: Rules):
    """PartitionSpec pytree matching init_cache.

    KV caches shard their TIME axis over the model dim ("kv_time"): the
    decode attention contracts over time, so this is the paper's layer
    partition on the sequence axis (each device owns k_i cache slices and
    contributes one partial layer of the attention output)."""
    P = rules.spec
    kv = P(None, "batch", "kv_time", None, None)
    if cfg.family == "hybrid":
        unit = cfg.block_pattern
        specs: Dict[str, Any] = {}
        rec = RGLRUState(conv=P(None, "batch", None, "ff"),
                         h=P(None, "batch", "ff"))
        for i, kind in enumerate(unit):
            specs[f"pos{i}"] = (kv, kv) if kind == "A" else rec
        if cfg.n_layers % len(unit):
            specs["tail"] = rec
        return specs
    if cfg.family == "ssm":
        return {
            "mlstm": MLSTMState(C=P(None, None, "batch", None, None, "ff"),
                                n=P(None, None, "batch", None, None),
                                lf_acc=P(None, None, "batch", None)),
            "slstm": SLSTMState(c=P(None, "batch", None, None),
                                n=P(None, "batch", None, None),
                                h=P(None, "batch", None, None)),
        }
    return {"k": kv, "v": kv}


def prefill(params, cfg: ModelConfig, rules: Rules, tokens, cache,
            prefix_embeds=None, last_index=None):
    """Run the full prompt, filling ``cache``; returns (cache, last_logits).

    ``last_index`` (B,) optionally picks a per-row position for the
    returned logits instead of the common last one — the serving engine
    right-pads mixed-length prompts to one batch and reads each row's
    logits at its own true last token (indices count from the start of
    ``prefix_embeds`` when given).  Causality keeps the pad positions out
    of every real position's attention, so row r's logits match an
    unpadded length-``last_index[r]+1`` prefill.
    """
    B = tokens.shape[0]
    x = embed_tokens(tokens, params["embed"], rules)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    x = shard(x, rules, "batch", "seq", None)
    x, cache = _stack_with_cache(x, params, cfg, rules, positions, cache,
                                 pos=None)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if last_index is None:
        last = x[:, -1]
    else:
        last = x[jnp.arange(B), jnp.asarray(last_index, jnp.int32)]
    logits = jnp.einsum("bd,vd->bv", last.astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    return cache, shard(logits, rules, "batch", "vocab")


def decode_step(params, cfg: ModelConfig, rules: Rules, token, pos, cache):
    """One token: token (B, 1) int32, pos (B,) int32 -> (logits, cache)."""
    B = token.shape[0]
    x = embed_tokens(token, params["embed"], rules)
    positions = pos[:, None]
    x = shard(x, rules, "batch", None, None)
    x, cache = _stack_with_cache(x, params, cfg, rules, positions, cache,
                                 pos=pos)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    return shard(logits, rules, "batch", None, "vocab"), cache


def _stack_with_cache(x, params, cfg, rules, positions, cache, pos):
    """Layer stack threading serving state (scan xs=params+cache, ys=cache)."""
    decode = pos is not None
    B = x.shape[0]
    if pos is None:
        pos_arr = jnp.zeros((B,), jnp.int32)
    else:
        pos_arr = pos

    if cfg.family == "hybrid":
        unit = cfg.block_pattern

        def group_body(x, inp):
            group_p, group_c = inp
            new_c = {}
            for i, kind in enumerate(unit):
                p, c = group_p[f"pos{i}"], group_c[f"pos{i}"]
                if kind == "A":
                    a, nkv = _attention_mix(x, p, cfg, rules, positions,
                                            cache_kv=c, pos=pos_arr,
                                            window=cfg.window)
                    new_c[f"pos{i}"] = nkv
                else:
                    a, ns = recurrent_block(
                        rms_norm(x, p["ln_mix"], cfg.norm_eps), p, rules,
                        state=RGLRUState(*c))
                    new_c[f"pos{i}"] = ns
                x = x + a
                f, _ = _ffn_mix(x, p, cfg, rules, prefix="mlp_")
                x = x + f
            return x, new_c

        group_cache = {k: v for k, v in cache.items() if k != "tail"}
        x, new_cache = jax.lax.scan(group_body, x,
                                    (params["groups"], group_cache))
        if "tail" in params:
            def tail_body(x, inp):
                p, c = inp
                a, ns = recurrent_block(
                    rms_norm(x, p["ln_mix"], cfg.norm_eps), p, rules,
                    state=RGLRUState(*c))
                x = x + a
                f, _ = _ffn_mix(x, p, cfg, rules, prefix="mlp_")
                return x + f, ns
            x, tail_cache = jax.lax.scan(tail_body, x,
                                         (params["tail"], cache["tail"]))
            new_cache["tail"] = tail_cache
        return x, new_cache

    if cfg.family == "ssm":
        H, hd = cfg.n_heads, cfg.hd

        def group_body(x, inp):
            (mp, sp), (mc, sc) = inp

            def m_body(xc, lp_lc):
                lp, lc = lp_lc
                h = rms_norm(xc, lp["ln"], cfg.norm_eps)
                y, ns = mlstm_block(h, lp, rules, n_heads=H, head_dim=hd,
                                    chunk=cfg.mlstm_chunk,
                                    state=MLSTMState(*lc))
                return xc + y, ns

            x, new_mc = jax.lax.scan(m_body, x, (mp, mc))
            h = rms_norm(x, sp["ln"], cfg.norm_eps)
            y, new_sc = slstm_block(h, sp, rules, n_heads=H, head_dim=hd,
                                    state=SLSTMState(*sc))
            return x + y, (new_mc, new_sc)

        x, (new_m, new_s) = jax.lax.scan(
            group_body, x,
            ((params["mlstm"], params["slstm"]),
             (cache["mlstm"], cache["slstm"])))
        return x, {"mlstm": new_m, "slstm": new_s}

    def body(x, inp):
        layer_p, (ck, cv) = inp
        a, nkv = _attention_mix(x, layer_p, cfg, rules, positions,
                                cache_kv=(ck, cv), pos=pos_arr)
        x = x + a
        f, _ = _ffn_mix(x, layer_p, cfg, rules)
        return x + f, nkv

    x, (nk, nv) = jax.lax.scan(body, x, (params["blocks"],
                                         (cache["k"], cache["v"])))
    return x, {"k": nk, "v": nv}
