"""Performance tuning flags (the §Perf hillclimb knobs).

Each flag corresponds to one hypothesis->change->measure iteration recorded
in EXPERIMENTS.md §Perf; the dry-run lowers baseline and optimized variants
by flipping these (launch.dryrun --opt/--no-opt, tags in the artifacts).

  moe_capacity_sharded  shard the MoE (E, C, d) expert batches over the
                        batch axes as well as the expert axis.  OFF means
                        the paper-faithful-naive layout where only experts
                        shard — every data-row replicates all expert compute
                        (found via the roofline: 16x per-device FLOP
                        inflation on qwen3-moe).
  cache_write_constraint constrain prefill k/v to the cache's (batch,
                        kv_time) layout BEFORE the cache insert, avoiding
                        GSPMD's involuntary full-replication resharding.
  reduce_bf16           perform the LBP layer aggregation (the contraction-
                        sharded matmul partial sums: attention out-proj,
                        FFN down-proj, MoE down-proj) in bfloat16 instead of
                        f32 — halves the dominant all-reduce bytes at the
                        cost of bf16 summation across p partial layers.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Tuning:
    moe_capacity_sharded: bool = True
    cache_write_constraint: bool = True
    reduce_bf16: bool = False   # paper-faithful default: exact f32 layer sum
    # explicit shard_map LBP for the row-parallel matmuls, aggregated via
    # the core.collectives registry ("scatter" under the train_sp /
    # prefill_sp profiles, "allreduce" otherwise)
    explicit_lbp_scatter: bool = False
    # overlapped layer-streaming execution plane (core/overlap.py): the
    # FSDP weight gather becomes a ppermute ring matmul'd one column block
    # per hop, and the layer aggregation uses the stream_* modes
    # ("stream_scatter" under the sp profiles, "stream_gather" otherwise)
    # so distribution of layer j+1 overlaps multiplication of layer j.
    # Only takes effect on the explicit path (explicit_lbp_scatter=True);
    # requires the streamed dims to divide by the ring sizes.
    overlap_streaming: bool = False
    # bidirectional rings for the streamed aggregation (core/overlap.py
    # stream_scatter_bidir / stream_gather_bidir): the permute chain is
    # split into two half-rings circulating in opposite directions, so
    # the sequential hop depth halves (ceil((p-1)/2) per direction) at
    # identical total bytes — wins when both link directions are free
    # (full-duplex ICI) and latency, not bandwidth, bounds the ring.
    # Only takes effect with overlap_streaming=True.
    overlap_bidir: bool = False
    # per-data-row MoE dispatch (no cross-row token gather).  Measured
    # REFUTED with GSPMD (it cannot prove the combine scatter-add local and
    # inserts full activation all-reduces) — kept for the record + the
    # future shard_map dispatch; see EXPERIMENTS §Perf.
    moe_row_local: bool = False
    # the shard_map version of the same idea: fully-manual EP dispatch —
    # local token selection per (data-row x expert-shard), expert-weight
    # FSDP gather inside, one bf16 psum over the model axis to combine.
    # Default ON after §Perf Cell A iter 4: −59% step bound on qwen3-moe
    # train (parity- and grad-tested on a real mesh).
    moe_ep_shard_map: bool = True


TUNING = Tuning()


def set_tuning(**kw) -> Tuning:
    for k, v in kw.items():
        assert hasattr(TUNING, k), k
        setattr(TUNING, k, v)
    return TUNING


def reduce_pref_dtype(x_dtype):
    """preferred_element_type for the row-parallel (layer-sum) matmuls."""
    import jax.numpy as jnp
    return jnp.bfloat16 if TUNING.reduce_bf16 else None
