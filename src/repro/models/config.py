"""ModelConfig: one dataclass describes every assigned architecture.

``tp`` is the tensor-parallel quantum: q-head counts are padded up to a
multiple of it at parameter-shape time (DESIGN.md §5; the padding waste is
visible in the roofline's MODEL_FLOPS / HLO_FLOPs ratio).  KV heads are
never padded — when ``n_kv_heads % tp != 0`` the KV tensors replicate over
the model axis instead (make_rules drops their sharding).

``reduced()`` produces the small same-family variant used by the CPU smoke
tests (few layers, narrow, tiny vocab, few experts).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # hybrid (recurrentgemma): block pattern unit, e.g. ("R","R","A")
    block_pattern: Tuple[str, ...] = ()
    window: int = 0             # local-attention window
    lru_width: int = 0          # RG-LRU width (0 -> d_model)
    conv_width: int = 4
    # ssm (xlstm): blocks per macro-group, mLSTM:sLSTM ratio
    mlstm_per_group: int = 0    # e.g. 7 (with 1 sLSTM per group)
    mlstm_chunk: int = 64
    # frontend stub
    frontend: str = "none"      # none | audio_frames | vision_patches
    prefix_len: int = 0         # frontend prefix tokens inside seq_len
    # distribution
    tp: int = 16                # head-padding quantum (1 for reduced configs)
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def h_padded(self) -> int:
        """q heads padded to a multiple of tp (parameter shapes use this)."""
        return math.ceil(self.n_heads / self.tp) * self.tp

    @property
    def kv_param(self) -> int:
        """KV heads in parameters/caches: MHA pads with q; GQA keeps true KV."""
        return self.h_padded if self.n_kv_heads == self.n_heads else self.n_kv_heads

    @property
    def kv_flash(self) -> int:
        """KV heads inside flash attention: repeated transiently to the
        smallest multiple of both kv_param and tp, so head compute shards
        tp-ways even when true KV < tp (llama 8, qwen3-moe 4, MQA 1)."""
        kv = self.kv_param
        return kv * (self.tp // math.gcd(kv, self.tp))

    @property
    def kv_sharded(self) -> bool:
        return self.kv_param % self.tp == 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def lru(self) -> int:
        return self.lru_width or self.d_model

    def n_params(self) -> int:
        """Total parameter count (true heads, no TP padding)."""
        d, hd = self.d_model, self.hd
        n = self.vocab_size * d  # embed (tied)
        if not self.tie_embeddings:
            n += self.vocab_size * d
        if self.family == "ssm":
            per = 3 * d * d + d * self.n_heads * 2 + d * d + d * d  # qkv,i/f,o,out
            n += self.n_layers * per
            return n
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        if self.is_moe:
            ffn = d * self.n_experts + 3 * self.n_experts * d * self.d_ff
        else:
            ffn = 3 * d * self.d_ff
        if self.block_pattern:
            unit = self.block_pattern
            n_attn = sum(1 for b in unit if b == "A")
            n_rec = sum(1 for b in unit if b == "R")
            groups = self.n_layers // len(unit)
            rec = 2 * d * self.lru + self.conv_width * self.lru \
                + 2 * self.lru + self.lru * d
            n += groups * (n_attn * attn + n_rec * rec) \
                + self.n_layers * ffn  # every layer has an MLP
            tail = self.n_layers - groups * len(unit)
            n += tail * rec
        else:
            n += self.n_layers * (attn + ffn)
        return n

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        dense = self.n_params() - 3 * self.n_layers * self.n_experts * d * self.d_ff
        return dense + 3 * self.n_layers * self.experts_per_token * d * self.d_ff

    # ------------------------------------------------------------------
    def reduced(self, **over) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests."""
        base = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4 if not self.block_pattern else 5),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            tp=1,
        )
        if self.is_moe:
            # capacity 4.0: no token drops at smoke sizes, so serving
            # (prefill+decode) is exactly consistent with the full forward
            # (capacity-dropping depends on batch size by construction).
            base.update(n_experts=8, experts_per_token=2, d_ff=32,
                        capacity_factor=4.0)
        if self.block_pattern:
            base.update(block_pattern=self.block_pattern, window=16,
                        lru_width=64, n_layers=5)
        if self.family == "ssm":
            base.update(mlstm_per_group=self.mlstm_per_group, n_layers=8,
                        n_heads=2, head_dim=32, mlstm_chunk=8, d_ff=0)
        if self.frontend != "none":
            base.update(frontend=self.frontend, prefix_len=4)
        base.update(over)
        return dataclasses.replace(self, **base)
