"""RG-LRU recurrent temporal-mixing block (Griffin / recurrentgemma).

Structure (Griffin, arXiv:2402.19427):
    x -> [linear -> GeLU]          (gate branch)
      -> [linear -> causal depthwise conv(4) -> RG-LRU]   (recurrent branch)
    y = gate * recurrent -> linear -> residual

RG-LRU per channel:  a_t = exp(-c_coef * softplus(Lambda) * r_t),
                     h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with r_t, i_t per-channel sigmoid gates (diagonal gate weights — the
block-diagonal gates of the original are simplified to diagonal; DESIGN.md
§assumption-changes).  The recurrence is elementwise over channels, so the
paper's layer partition has no contraction dim here (DESIGN §Arch-
applicability); channels shard over the model axis instead.

Train path uses ``jax.lax.associative_scan`` (log-depth — TPU-friendly);
the Pallas kernel (kernels/rglru_kernel.py) is the sequential-VMEM TPU
alternative validated against the same math.  Decode carries (conv window,
h) state.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.rules import Rules, shard

C_COEF = 8.0


class RGLRUState(NamedTuple):
    conv: jax.Array   # (B, conv_width - 1, lru) trailing inputs
    h: jax.Array      # (B, lru)


def _gates(xr: jax.Array, p) -> Tuple[jax.Array, jax.Array]:
    """Per-channel recurrence/input gates on the conv output."""
    r = jax.nn.sigmoid(xr * p["gate_a_w"] + p["gate_a_b"])
    i = jax.nn.sigmoid(xr * p["gate_x_w"] + p["gate_x_b"])
    log_a = -C_COEF * jax.nn.softplus(p["lambda_param"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * xr)
    return a, b


def _conv1d_causal(x: jax.Array, kernel: jax.Array, bias: jax.Array,
                   history: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. x: (B,S,D), kernel: (W,D).  ``history`` is the
    (B, W-1, D) trailing context (decode), else zero-padding."""
    W = kernel.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([history, x], axis=1)
    out = sum(xp[:, t:t + x.shape[1]] * kernel[t] for t in range(W))
    return out + bias


def recurrent_block(
    x: jax.Array,              # (B, S, d)
    p,                         # param dict for this block
    rules: Rules,
    state: Optional[RGLRUState] = None,
) -> Tuple[jax.Array, Optional[RGLRUState]]:
    xf = x.astype(jnp.float32)
    gate = jax.nn.gelu(jnp.einsum("bsd,dl->bsl", xf, p["w_gate"].astype(jnp.float32)))
    xr = jnp.einsum("bsd,dl->bsl", xf, p["w_rec"].astype(jnp.float32))
    gate = shard(gate, rules, "batch", None, "ff")
    xr = shard(xr, rules, "batch", None, "ff")

    hist = state.conv if state is not None else None
    xr = _conv1d_causal(xr, p["conv_k"].astype(jnp.float32),
                        p["conv_b"].astype(jnp.float32), hist)
    a, b = _gates(xr, {k: v.astype(jnp.float32) for k, v in p.items()
                       if k in ("gate_a_w", "gate_a_b", "gate_x_w",
                                "gate_x_b", "lambda_param")})

    h0 = state.h if state is not None else None
    if x.shape[1] == 1 and state is not None:
        # decode: single-step update
        h = a[:, 0] * h0.astype(jnp.float32) + b[:, 0]
        hs = h[:, None]
    else:
        if h0 is not None:
            b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
        # associative scan over (a, b): (a2, b2) o (a1, b1) = (a1 a2, a2 b1 + b2)
        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a1 * a2, a2 * b1 + b2
        _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = hs[:, -1]
    hs = shard(hs, rules, "batch", None, "ff")

    y = jnp.einsum("bsl,ld->bsd", hs * gate, p["w_out"].astype(jnp.float32))
    y = shard(y.astype(x.dtype), rules, "batch", "seq", None)

    new_state = None
    if state is not None:
        W = p["conv_k"].shape[0]
        # xr here is post-conv; we must keep raw pre-conv inputs for history.
        # recompute the raw projection tail:
        raw = jnp.einsum("bsd,dl->bsl", xf, p["w_rec"].astype(jnp.float32))
        tail = jnp.concatenate([state.conv, raw], axis=1)[:, -(W - 1):]
        new_state = RGLRUState(conv=tail.astype(state.conv.dtype),
                               h=h.astype(state.h.dtype))
    return y, new_state
