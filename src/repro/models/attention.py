"""GQA attention: custom-VJP chunked online-softmax (flash at the XLA level).

Why custom VJP: plain AD through a scanned online-softmax stores per-tile
residuals — the full (S, T) score matrix again — which is exactly what flash
attention exists to avoid.  The forward saves only (q, k, v, out, lse); the
backward recomputes tiles (the classical flash backward), so train-time
activation memory for 32k sequences stays O(S·d) per layer.

This is the portable XLA implementation used by the models everywhere (and
the only executable path on this CPU container).  The Pallas kernel
(kernels/flash_attention_kernel.py) is the TPU hot-path with the same
blocking scheme; tests assert all three (ref / XLA-flash / Pallas-interpret)
agree.

Layout: q (B, S, KV, G, hd) — GQA groups explicit; k, v (B, T, KV, hd).
``window > 0`` restricts attention to the trailing window (recurrentgemma
local attention): tiles outside the band are skipped by loop bounds, so
compute is O(S * (window + chunk)), sub-quadratic in S.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1e30


def _pick_chunk(size: int, want: int) -> int:
    want = min(want, size)
    while size % want:
        want -= 1
    return want


def _mask(i, j, qc, kvc, causal, window):
    rows = i * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kvc), 0)
    cols = j * kvc + jax.lax.broadcasted_iota(jnp.int32, (qc, kvc), 1)
    ok = jnp.ones((qc, kvc), bool)
    if causal:
        ok &= cols <= rows
    if window > 0:
        ok &= cols > rows - window
    return ok


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_xla(q, k, v, causal: bool = True, window: int = 0,
                        q_chunk: int = 512, kv_chunk: int = 1024):
    out, _ = _fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk)
    return out


def _bounds(i, qc, kvc, T, causal, window):
    """KV-chunk loop bounds for q chunk i (traced)."""
    n_kv = T // kvc
    if causal:
        hi = jnp.minimum((i * qc + qc + kvc - 1) // kvc, n_kv)
    else:
        hi = jnp.asarray(n_kv)
    if window > 0:
        # smallest visible col across the whole chunk belongs to its FIRST
        # row: col_min = i*qc - window + 1
        lo = jnp.maximum((i * qc - window + 1) // kvc, 0)
        lo = jnp.minimum(lo, hi)
    else:
        lo = jnp.asarray(0)
    return lo, hi


def _fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk):
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    qc = _pick_chunk(S, q_chunk)
    kvc = _pick_chunk(T, kv_chunk)
    scale = float(hd) ** -0.5
    nq = S // qc

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def q_step(i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * qc, qc, 1).astype(jnp.float32)
        lo, hi = _bounds(i, qc, kvc, T, causal, window)

        def kv_step(j, carry):
            m, l, acc = carry
            kj = jax.lax.dynamic_slice_in_dim(kf, j * kvc, kvc, 1)
            vj = jax.lax.dynamic_slice_in_dim(vf, j * kvc, kvc, 1)
            s = jnp.einsum("bqkgd,btkd->bkgqt", qi, kj) * scale
            ok = _mask(i, j, qc, kvc, causal, window)
            s = jnp.where(ok[None, None, None], s, NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # explicit zero on masked entries: on an all-masked row
            # (m_new == NEG) exp(s - m_new) would be exp(0) = 1.
            p = jnp.where(ok[None, None, None],
                          jnp.exp(s - m_new[..., None]), 0.0)
            alpha = jnp.exp(jnp.minimum(m - m_new, 0.0))
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bkgqt,btkd->bkgqd", p, vj)
            return m_new, l, acc

        m0 = jnp.full((B, KV, G, qc), NEG, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc, hd), jnp.float32)
        m, l, acc = jax.lax.fori_loop(lo, hi, kv_step, (m0, l0, a0))
        l_safe = jnp.maximum(l, 1e-30)
        oi = (acc / l_safe[..., None])                     # (B,KV,G,qc,hd)
        lse = m + jnp.log(l_safe)                          # (B,KV,G,qc)
        return oi.transpose(0, 3, 1, 2, 4).astype(q.dtype), lse

    ois, lses = jax.lax.map(q_step, jnp.arange(nq))
    out = jnp.moveaxis(ois, 0, 1).reshape(B, S, KV, G, hd)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, KV, G, S)
    return out, lse


def _fwd(q, k, v, causal, window, q_chunk, kv_chunk):
    out, lse = _fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk)
    return out, (q, k, v, out, lse)


def _bwd(causal, window, q_chunk, kv_chunk, res, dout):
    q, k, v, out, lse = res
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    qc = _pick_chunk(S, q_chunk)
    kvc = _pick_chunk(T, kv_chunk)
    scale = float(hd) ** -0.5
    nq, nkv = S // qc, T // kvc

    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    dof = dout.astype(jnp.float32)
    # D_i = rowsum(dout * out): (B, KV, G, S)
    Dfull = jnp.einsum("bskgd,bskgd->bkgs", dof, out.astype(jnp.float32))

    def tile(qi, kj, vj, lse_i, D_i, doi, i, j):
        """Recompute p and ds for tile (i, j); returns (p, ds)."""
        s = jnp.einsum("bqkgd,btkd->bkgqt", qi, kj) * scale
        ok = _mask(i, j, qc, kvc, causal, window)
        s = jnp.where(ok[None, None, None], s, NEG)
        p = jnp.exp(s - lse_i[..., None])                  # (B,KV,G,qc,kvc)
        dp = jnp.einsum("bqkgd,btkd->bkgqt", doi, vj)
        ds = p * (dp - D_i[..., None]) * scale
        return p, ds

    # ---- dq: map over q chunks, loop over the kv band ----
    def dq_step(i):
        qi = jax.lax.dynamic_slice_in_dim(qf, i * qc, qc, 1)
        doi = jax.lax.dynamic_slice_in_dim(dof, i * qc, qc, 1)
        lse_i = jax.lax.dynamic_slice_in_dim(lse, i * qc, qc, 3)
        D_i = jax.lax.dynamic_slice_in_dim(Dfull, i * qc, qc, 3)
        lo, hi = _bounds(i, qc, kvc, T, causal, window)

        def kv_step(j, dqi):
            kj = jax.lax.dynamic_slice_in_dim(kf, j * kvc, kvc, 1)
            vj = jax.lax.dynamic_slice_in_dim(vf, j * kvc, kvc, 1)
            _, ds = tile(qi, kj, vj, lse_i, D_i, doi, i, j)
            return dqi + jnp.einsum("bkgqt,btkd->bqkgd", ds, kj)

        dqi = jax.lax.fori_loop(lo, hi, kv_step,
                                jnp.zeros((B, qc, KV, G, hd), jnp.float32))
        return dqi

    dq = jnp.moveaxis(jax.lax.map(dq_step, jnp.arange(nq)), 0, 1)
    dq = dq.reshape(B, S, KV, G, hd)

    # ---- dk/dv: map over kv chunks, loop over the q band ----
    def dkv_step(j):
        kj = jax.lax.dynamic_slice_in_dim(kf, j * kvc, kvc, 1)
        vj = jax.lax.dynamic_slice_in_dim(vf, j * kvc, kvc, 1)
        if causal:
            ilo = (j * kvc) // qc
        else:
            ilo = jnp.asarray(0)
        if window > 0:
            ihi = jnp.minimum((j * kvc + kvc - 1 + window) // qc + 1, nq)
        else:
            ihi = jnp.asarray(nq)

        def q_step(i, carry):
            dkj, dvj = carry
            qi = jax.lax.dynamic_slice_in_dim(qf, i * qc, qc, 1)
            doi = jax.lax.dynamic_slice_in_dim(dof, i * qc, qc, 1)
            lse_i = jax.lax.dynamic_slice_in_dim(lse, i * qc, qc, 3)
            D_i = jax.lax.dynamic_slice_in_dim(Dfull, i * qc, qc, 3)
            p, ds = tile(qi, kj, vj, lse_i, D_i, doi, i, j)
            dkj = dkj + jnp.einsum("bkgqt,bqkgd->btkd", ds, qi)
            dvj = dvj + jnp.einsum("bkgqt,bqkgd->btkd", p, doi)
            return dkj, dvj

        z = jnp.zeros((B, kvc, KV, hd), jnp.float32)
        return jax.lax.fori_loop(ilo, ihi, q_step, (z, z))

    dks, dvs = jax.lax.map(dkv_step, jnp.arange(nkv))
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, T, KV, hd)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, T, KV, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_xla.defvjp(_fwd, _bwd)


# ---------------------------------------------------------------------------
# decode (single new token against a cache) — no grad needed
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0):
    """q: (B, 1, KV, G, hd); caches: (B, T, KV, hd); pos: (B,) current index.

    Attends to cache positions <= pos (and > pos - window if windowed).

    The cache stays in its storage dtype (bf16) inside the einsums with f32
    accumulation — an explicit .astype(f32) would materialize a full f32
    COPY of the cache (2x HBM read + a write), which the §Perf pass found
    to halve decode's useful-bandwidth ratio.
    """
    B, _, KVh, G, hd = q.shape
    T = k_cache.shape[1]
    scale = float(hd) ** -0.5
    s = jnp.einsum("bqkgd,btkd->bkgqt", q.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32) * scale
    t_idx = jnp.arange(T)[None, :]                     # (1, T)
    ok = t_idx <= pos[:, None]
    if window > 0:
        ok &= t_idx > (pos[:, None] - window)
    s = jnp.where(ok[:, None, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def reference_attention(q, k, v, causal=True, window: int = 0):
    """Naive oracle in the same (B,S,KV,G,hd) layout (tests only)."""
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    scale = float(hd) ** -0.5
    s = jnp.einsum("bqkgd,btkd->bkgqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= cols <= rows
    if window > 0:
        ok &= cols > rows - window
    s = jnp.where(ok[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
