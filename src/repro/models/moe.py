"""Mixture-of-Experts FFN: top-k router + sort-based capacity dispatch.

Dispatch never materializes the (tokens, E, capacity) one-hot tensor (at
1M train tokens that is astronomically large); instead the top-k
(token, expert) pairs are sorted by expert id, positions within each
expert's group are computed from the sorted order, and tokens beyond an
expert's capacity are dropped (classic capacity-factor semantics).

Sharding: the (E, C, d) expert batches carry ``expert -> model`` constraints
— expert parallelism; the gather/scatter between token-sharded x and
expert-sharded batches is where GSPMD emits the EP all-to-all.  Inside each
expert the down-projection contracts over d_ff — per-expert LBP layers.

Load-balance auxiliary loss follows Switch (mean fraction * mean prob * E).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..sharding.rules import Rules, shard


def _dispatch_local(xt, top_i, top_w, e_lo, E_loc: int, C: int):
    """Sort-based dispatch of local tokens to experts [e_lo, e_lo + E_loc).

    xt: (T, d); top_i/top_w: (T, K); e_lo may be traced (axis_index);
    E_loc/C are static.  Returns (xe (E_loc, C, d), slot_token (E_loc*C,),
    slot_w, slot_valid) — all index into LOCAL tokens only (the locality
    GSPMD could not prove; here it is manual).
    """
    T, d = xt.shape
    K = top_i.shape[1]

    flat_e = top_i.reshape(-1)
    flat_t = jnp.arange(T * K, dtype=jnp.int32) // K
    flat_w = top_w.reshape(-1).astype(jnp.float32)
    mine = (flat_e >= e_lo) & (flat_e < e_lo + E_loc)
    flat_e = jnp.where(mine, flat_e - e_lo, E_loc)          # foreign -> sentinel

    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    seg_start = jnp.searchsorted(se, jnp.arange(E_loc), side="left")
    pos = jnp.arange(T * K, dtype=jnp.int32) - seg_start[
        jnp.minimum(se, E_loc - 1)]
    keep = (se < E_loc) & (pos < C)
    slot = jnp.where(keep, se * C + pos, E_loc * C)

    slot_token = jnp.zeros(E_loc * C + 1, jnp.int32).at[slot].set(st, mode="drop")
    slot_w = jnp.zeros(E_loc * C + 1, jnp.float32).at[slot].set(sw, mode="drop")
    slot_valid = jnp.zeros(E_loc * C + 1, jnp.float32).at[slot].set(
        jnp.ones_like(sw), mode="drop")
    slot_token = slot_token[:E_loc * C]
    slot_w = slot_w[:E_loc * C]
    slot_valid = slot_valid[:E_loc * C]

    xe = jnp.take(xt, slot_token, axis=0) * slot_valid[:, None].astype(xt.dtype)
    return xe.reshape(E_loc, C, d), slot_token, slot_w, slot_valid


def moe_ffn_shard_map(x, router_w, w_gate, w_up, w_down, rules,
                      *, experts_per_token: int, capacity_factor: float):
    """Explicit-EP MoE: shard_map over the whole mesh.

    Each device (data row r, model col m) dispatches ITS batch shard's
    tokens to ITS expert shard locally (token replicas across the model
    axis make this communication-free), runs the expert FFNs, combines
    locally, and psums partial outputs over the model axis.  Collectives
    per layer: expert-weight FSDP all-gather (data axis) + one bf16
    activation psum (model axis) — vs GSPMD's full token all-gather
    (§Perf Cell A iter 3 post-mortem).
    """
    from jax.sharding import PartitionSpec as P

    from ..core import collectives

    B, S, d = x.shape
    E = router_w.shape[1]
    K = experts_per_token
    T = B * S
    mesh = rules.mesh
    model_ax = rules.expert
    data_ax = rules.embed if isinstance(rules.embed, str) else None
    n_model = mesh.shape[model_ax]
    E_loc = E // n_model
    batch_axes = ((rules.batch,) if isinstance(rules.batch, str)
                  else tuple(rules.batch or ()))
    n_rows = 1
    for a in batch_axes:
        n_rows *= mesh.shape[a]
    T_loc = T // n_rows
    C = max(1, int(math.ceil(T_loc * K / E * capacity_factor)))

    # routing on the global (replicated-over-model) activations
    xt = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    frac = jnp.mean(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(frac * probs.mean(axis=0))

    def local(xt_l, ti_l, tw_l, wg_l, wu_l, wd_l):
        if data_ax is not None:   # FSDP gather of this shard's expert weights
            wg_l = jax.lax.all_gather(wg_l, data_ax, axis=1, tiled=True)
            wu_l = jax.lax.all_gather(wu_l, data_ax, axis=1, tiled=True)
            wd_l = jax.lax.all_gather(wd_l, data_ax, axis=2, tiled=True)
        m = jax.lax.axis_index(model_ax)
        e_lo = m * E_loc
        xe, slot_token, slot_w, slot_valid = _dispatch_local(
            xt_l, ti_l, tw_l, e_lo, E_loc, C)
        # NOTE: e_lo is traced, so the mask/shift runs on device — the
        # dispatch stays fully local.
        h = jnp.einsum("ecd,edf->ecf", xe, wg_l.astype(xe.dtype))
        u = jnp.einsum("ecd,edf->ecf", xe, wu_l.astype(xe.dtype))
        ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                        wd_l.astype(xe.dtype)).reshape(E_loc * C, -1)
        contrib = ye.astype(jnp.float32) * (slot_w * slot_valid)[:, None]
        y_l = jnp.zeros((xt_l.shape[0], xt_l.shape[1]), jnp.float32
                        ).at[slot_token].add(contrib)
        return collectives.aggregate(y_l.astype(x.dtype), "allreduce",
                                     model_ax)

    fn = rules.shard_map(
        local,
        in_specs=(P(rules.batch, None), P(rules.batch, None),
                  P(rules.batch, None), P(model_ax, data_ax, None),
                  P(model_ax, data_ax, None), P(model_ax, None, data_ax)),
        out_specs=P(rules.batch, None))
    yt = fn(xt, top_i, top_w, w_gate, w_up, w_down)
    return yt.reshape(B, S, d), aux


def moe_ffn(
    x: jax.Array,          # (B, S, d)
    router_w: jax.Array,   # (d, E)
    w_gate: jax.Array,     # (E, d, ffe)
    w_up: jax.Array,       # (E, d, ffe)
    w_down: jax.Array,     # (E, ffe, d)
    rules: Rules,
    *,
    experts_per_token: int,
    capacity_factor: float = 1.25,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,d), aux_loss scalar)."""
    from .tuning import TUNING as _T
    if (_T.moe_ep_shard_map and rules.mesh is not None
            and isinstance(rules.expert, str)):
        return moe_ffn_shard_map(
            x, router_w, w_gate, w_up, w_down, rules,
            experts_per_token=experts_per_token,
            capacity_factor=capacity_factor)
    B, S, d = x.shape
    E = router_w.shape[1]
    K = experts_per_token
    T = B * S
    xt = x.reshape(T, d)

    from .tuning import TUNING, reduce_pref_dtype

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                 # (T, E)
    top_w, top_i = jax.lax.top_k(probs, K)                  # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance loss (global statistics).
    frac = jnp.mean(
        jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(frac * probs.mean(axis=0))

    # Row-local dispatch (§Perf iteration on qwen3-moe): tokens are grouped
    # per data-row (R rows = the batch shards) and each row fills its own
    # capacity chunk of every expert.  All gather/scatter indices then stay
    # within a row, so the dispatch needs NO cross-row communication —
    # GSPMD's alternative is all-gathering every token to every row.
    # Per-row capacity (drops decided within a row) is standard practice.
    R = 1
    if (TUNING.moe_capacity_sharded and TUNING.moe_row_local
            and rules.mesh is not None):
        ax = rules.batch
        axes = (ax,) if isinstance(ax, str) else tuple(ax or ())
        for a in axes:
            R *= rules.mesh.shape[a]
        while T % R:   # tiny smoke batches may not divide evenly
            R //= 2
    Tr = T // R
    C = max(1, int(math.ceil(Tr * K / E * capacity_factor)))

    # ---- per-row sort-based dispatch (leading R dim everywhere) ----
    flat_e = top_i.reshape(R, Tr * K)
    flat_t = jnp.broadcast_to(
        (jnp.arange(Tr * K, dtype=jnp.int32) // K)[None], (R, Tr * K))
    flat_w = top_w.reshape(R, Tr * K).astype(jnp.float32)

    order = jnp.argsort(flat_e, axis=1)                     # stable, per row
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = jnp.take_along_axis(flat_t, order, axis=1)
    sw = jnp.take_along_axis(flat_w, order, axis=1)
    seg_start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E), side="left"))(se)
    pos = jnp.arange(Tr * K, dtype=jnp.int32)[None] - \
        jnp.take_along_axis(seg_start, se, axis=1)
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)             # dropped -> sentinel

    rix = jnp.arange(R, dtype=jnp.int32)[:, None]
    slot_token = jnp.zeros((R, E * C + 1), jnp.int32).at[rix, slot].set(
        st, mode="drop")
    slot_w = jnp.zeros((R, E * C + 1), jnp.float32).at[rix, slot].set(
        sw, mode="drop")
    slot_valid = jnp.zeros((R, E * C + 1), jnp.float32).at[rix, slot].set(
        jnp.ones_like(sw), mode="drop")
    slot_token = slot_token[:, :E * C]
    slot_w = slot_w[:, :E * C]
    slot_valid = slot_valid[:, :E * C]

    cap_ax = "batch" if TUNING.moe_capacity_sharded else None
    xr = shard(xt.reshape(R, Tr, d), rules, "batch", None, None)
    xe = jnp.take_along_axis(xr, slot_token[:, :, None], axis=1) \
        * slot_valid[:, :, None].astype(xt.dtype)           # (R, E*C, d)
    # (R, E, C, d) -> (E, R*C, d): expert-major with row-chunked capacity
    xe = xe.reshape(R, E, C, d).transpose(1, 0, 2, 3).reshape(E, R * C, d)
    xe = shard(xe, rules, "expert", cap_ax, None)

    # ---- expert FFN (SwiGLU) ----
    h = jnp.einsum("ecd,edf->ecf", xe, w_gate.astype(xe.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, w_up.astype(xe.dtype))
    h = shard(jax.nn.silu(h) * u, rules, "expert", cap_ax, None)
    ye = jnp.einsum("ecf,efd->ecd", h, w_down.astype(xe.dtype),
                    preferred_element_type=reduce_pref_dtype(xe.dtype))
    ye = shard(ye.astype(xe.dtype), rules, "expert", cap_ax, None)

    # ---- weighted combine (row-local scatter-add back to tokens) ----
    ye = ye.reshape(E, R, C, d).transpose(1, 0, 2, 3).reshape(R, E * C, d)
    contrib = ye.astype(jnp.float32) * (slot_w * slot_valid)[:, :, None]
    yt = jnp.zeros((R, Tr, d), jnp.float32).at[rix, slot_token].add(contrib)
    out = shard(yt.reshape(B, S, d).astype(x.dtype), rules,
                "batch", "seq", None)
    return out, aux
