"""Model zoo substrate: decoder-only LM families (dense / MoE / hybrid / ssm).

config.py       ModelConfig (+ reduced smoke variants)
layers.py       RMSNorm, RoPE, SwiGLU, embeddings, chunked sharded xent
attention.py    GQA with custom-VJP chunked online-softmax (flash at XLA
                level), local-window variant, KV-cache decode
moe.py          top-k router + sort-based capacity dispatch (EP)
rglru.py        RG-LRU recurrent block (recurrentgemma)
xlstm.py        chunkwise mLSTM + sLSTM blocks
transformer.py  block assembly (scan over layers, remat), init, train/serve
"""
