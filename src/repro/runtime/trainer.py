"""Trainer: the fault-tolerant training loop.

Composes the substrate: synthetic pipeline -> jitted train_step ->
async checkpoints -> resume-from-latest -> (simulated) failure handling.
The loop is exactly what launch/train.py drives; tests run it on reduced
configs and assert bit-identical resume and loss descent.

Failure story (single-process container -> simulated, but the control flow
is the production one):
  * ``inject_failure_at``: at step k the loop raises DeviceFailure (stands
    in for a hardware fault surfacing as a failed step);
  * recovery: reload latest checkpoint, rebuild data iterator at the
    restored step (random-access pipeline), re-solve the LBP schedule for
    the surviving fleet (runtime.rebalance), continue;
  * the test asserts the post-recovery loss trajectory equals an
    uninterrupted run's (determinism end-to-end).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint.store import AsyncCheckpointer, latest_step, load_checkpoint
from ..data.pipeline import SyntheticTokens
from ..models import transformer as T
from ..models.config import ModelConfig
from ..obs import clock as obs_clock
from ..obs.metrics import MetricsRegistry
from ..obs.trace import NullTracer
from ..optim.adamw import AdamWConfig
from ..sharding.rules import Rules
from ..train.step import init_train_state, make_train_step


class DeviceFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 20
    checkpoint_every: int = 5
    checkpoint_dir: str = "/tmp/repro_ckpt"
    grad_accum: int = 1
    seed: int = 0
    log_every: int = 1
    inject_failure_at: Optional[int] = None   # simulate a node fault
    max_recoveries: int = 2


class Trainer:
    def __init__(self, cfg: ModelConfig, rules: Rules,
                 tcfg: TrainerConfig, opt_cfg: Optional[AdamWConfig] = None,
                 batch_size: int = 8, seq_len: int = 64,
                 tracer=None, metrics=None):
        self.cfg = cfg
        self.rules = rules
        self.tcfg = tcfg
        # observability plane: the trainer's timeline is its step counter
        self.tracer = tracer if tracer is not None else NullTracer()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._tick = 0
        if self.tracer.enabled and self.tracer.clock is None:
            self.tracer.use_clock(lambda: float(self._tick))
        self.opt_cfg = opt_cfg or AdamWConfig(
            warmup_steps=5, total_steps=tcfg.total_steps)
        self.data = SyntheticTokens(
            vocab_size=cfg.vocab_size, global_batch=batch_size,
            seq_len=seq_len, seed=tcfg.seed, prefix_len=cfg.prefix_len,
            d_model=cfg.d_model)
        self.step_fn = jax.jit(make_train_step(
            cfg, rules, self.opt_cfg, grad_accum=tcfg.grad_accum))
        self.ckpt = AsyncCheckpointer(tcfg.checkpoint_dir)
        self.history: List[Dict[str, float]] = []
        self.recoveries = 0

    # ------------------------------------------------------------------
    def _fresh_state(self):
        return init_train_state(self.cfg, jax.random.PRNGKey(self.tcfg.seed))

    def _restore_or_init(self):
        s = latest_step(self.tcfg.checkpoint_dir)
        if s is None:
            return 0, self._fresh_state()
        target = jax.eval_shape(self._fresh_state)
        step, state = load_checkpoint(self.tcfg.checkpoint_dir, s, target)
        return step, state

    # ------------------------------------------------------------------
    def run(self) -> List[Dict[str, float]]:
        step, state = self._restore_or_init()
        injected = {self.tcfg.inject_failure_at} if \
            self.tcfg.inject_failure_at is not None else set()

        while step < self.tcfg.total_steps:
            try:
                if step in injected:
                    injected.discard(step)
                    raise DeviceFailure(f"simulated device fault at step {step}")
                batch = self.data.batch_at(step)
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                if "prefix_embeds" in batch:
                    batch["prefix_embeds"] = batch["prefix_embeds"].astype(
                        jax.numpy.bfloat16)
                self._tick = step
                t0 = obs_clock.wall_time()
                with self.tracer.span("train_step", track="trainer",
                                      lane="steps", step=step):
                    state, metrics = self.step_fn(state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                metrics["step"] = step
                metrics["dt"] = obs_clock.wall_time() - t0
                self.history.append(metrics)
                self.metrics.counter("train_steps").inc()
                self.metrics.gauge("loss").set(metrics["loss"])
                step += 1
                if step % self.tcfg.checkpoint_every == 0:
                    self.ckpt.save(step, state)
            except DeviceFailure:
                self.recoveries += 1
                self.metrics.counter("recoveries").inc()
                self.tracer.event("device_failure", track="trainer",
                                  lane="faults", step=step)
                if self.recoveries > self.tcfg.max_recoveries:
                    raise
                # production: drop dead devices from the network graph,
                # re-solve the LBP schedule (runtime.rebalance), rebuild the
                # mesh; here the surviving fleet is the same single process.
                self.ckpt.wait()
                step, state = self._restore_or_init()
        self.ckpt.wait()
        self.ckpt.save(step, state)
        self.ckpt.wait()
        return self.history
