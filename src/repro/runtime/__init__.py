from .rebalance import (drop_devices, join_devices,  # noqa: F401
                        measure_speeds, plan_rebalance)
from .trainer import Trainer, TrainerConfig  # noqa: F401
