from .rebalance import plan_rebalance, measure_speeds  # noqa: F401
from .trainer import Trainer, TrainerConfig  # noqa: F401
