from .correct import (CorrectionPolicy, StealEvent,  # noqa: F401
                      WorkStealingCorrector, corrected_plan,
                      simulate_correction, steal_unit)
from .rebalance import (correct_shares, drop_devices,  # noqa: F401
                        join_devices, measure_speeds, plan_rebalance)
from .trainer import Trainer, TrainerConfig  # noqa: F401
