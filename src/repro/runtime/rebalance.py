"""Straggler mitigation + elastic rescale: the paper's solvers as the
scheduling brain of the runtime.

On real fleets devices are heterogeneous in practice (thermal throttling,
SDC-quarantined hosts, DCN sharing).  The runtime:

  1. measures per-device effective rates (here: injected or timed),
  2. converts them to the paper's star-network model (w_i = 1/rate;
     z_i = link class: ICI near-zero, DCN per-pod),
  3. solves the §4 equality-based split (PCSS for compute-bound, PCCS when
     link costs matter) + §4.5 integer adjustment with quantum=128
     (MXU-aligned shards),
  4. re-packs the LBP matmul's ragged shards (core.lbp_matmul.pad_ragged).

Elastic rescale (node loss/join) is the same path with a different device
set, plus checkpoint restore-with-reshard (checkpoint.store).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..core.network import SpeedProfile, StarNetwork
from ..core.partition import LayerAssignment


@dataclasses.dataclass
class RebalancePlan:
    assignment: LayerAssignment
    speeds: np.ndarray
    predicted_speedup: float     # vs even split, compute-bound model


def measure_speeds(step_times: Sequence[float]) -> np.ndarray:
    """Per-device relative rate from measured per-device step times."""
    t = np.asarray(step_times, dtype=np.float64)
    assert np.all(t > 0)
    rate = 1.0 / t
    return rate / rate.mean()


def plan_rebalance(K: int, speeds: Sequence[float], *, quantum: int = 128,
                   mode: str = "PCSS",
                   net: Optional[StarNetwork] = None) -> RebalancePlan:
    """Split contraction dim K over devices proportional to measured rates.

    Falls back to quantum=1 if K is too small to quantize by 128 (reduced
    smoke configs)."""
    speeds = np.asarray(speeds, dtype=np.float64)
    p = len(speeds)
    if K % (quantum) != 0 or K < quantum * p:
        quantum = 1
    assign = LayerAssignment.from_speeds(K, speeds, quantum=quantum,
                                         mode=mode, net=net)
    # compute-bound finish time model: t = max_i k_i / speed_i
    even = np.full(p, K / p)
    t_even = float(np.max(even / speeds))
    t_new = float(np.max(np.where(assign.k > 0, assign.k / speeds, 0.0)))
    return RebalancePlan(assignment=assign, speeds=speeds,
                         predicted_speedup=t_even / max(t_new, 1e-12))


def drop_devices(assign: LayerAssignment, dead: Sequence[int],
                 speeds: Sequence[float], quantum: int = 128
                 ) -> RebalancePlan:
    """Node failure: re-solve the split over the surviving device set."""
    alive = [i for i in range(assign.p) if i not in set(dead)]
    s = np.asarray(speeds, dtype=np.float64)[alive]
    return plan_rebalance(assign.K, s, quantum=quantum)
