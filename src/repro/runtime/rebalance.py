"""Straggler mitigation + elastic rescale: ``repro.plan`` as the
scheduling brain of the runtime.

On real fleets devices are heterogeneous in practice (thermal throttling,
SDC-quarantined hosts, DCN sharing).  The runtime:

  1. measures per-device effective rates (here: injected or timed),
  2. describes the platform as a ``repro.plan`` Topology — a flat ICI star
     from the measured speeds by default, or any caller-provided topology
     (e.g. the two-level multi-pod ``HierarchicalTopology``),
  3. calls ``repro.plan.plan()`` (§4 equality solve / two-level recursion
     + §4.5 integer adjustment, quantum=128 for MXU-aligned shards),
  4. re-packs the LBP matmul's ragged shards (core.lbp_matmul.pad_ragged).

Elastic rescale (node loss/join) is the same path with the topology
restricted to the surviving device set, plus checkpoint
restore-with-reshard (checkpoint.store).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..core.network import StarNetwork
from ..core.partition import LayerAssignment
from ..plan import PartitionPlan, StarTopology, Topology, plan as plan_split


@dataclasses.dataclass
class RebalancePlan:
    assignment: LayerAssignment
    speeds: np.ndarray
    predicted_speedup: float     # vs even split, compute-bound model
    plan: Optional[PartitionPlan] = None   # full IR (finish times, comm, provenance)


def measure_speeds(step_times: Sequence[float]) -> np.ndarray:
    """Per-device relative rate from measured per-device step times."""
    t = np.asarray(step_times, dtype=np.float64)
    assert np.all(t > 0)
    rate = 1.0 / t
    return rate / rate.mean()


def _as_topology(speeds, net: Optional[StarNetwork],
                 topology: Optional[Topology]) -> Topology:
    """Precedence: explicit topology > legacy StarNetwork > measured speeds."""
    if topology is not None:
        return topology
    if net is not None:
        return StarTopology.from_network(net)
    if speeds is None:
        raise ValueError("pass speeds=, net= or topology= — there is "
                         "nothing to describe the fleet from")
    return StarTopology.from_speeds(np.asarray(speeds, dtype=np.float64))


def plan_rebalance(K: int, speeds: Optional[Sequence[float]] = None, *,
                   quantum: int = 128, mode: str = "PCSS",
                   net: Optional[StarNetwork] = None,
                   topology: Optional[Topology] = None) -> RebalancePlan:
    """Split contraction dim K over devices proportional to measured rates.

    Routes through ``repro.plan.plan()``; the returned ``RebalancePlan``
    carries the full ``PartitionPlan`` IR.  Falls back to quantum=1 if K
    is too small to quantize by 128 (reduced smoke configs)."""
    topo = _as_topology(speeds, net, topology)
    if speeds is None and not hasattr(topo, "w"):
        raise ValueError(
            f"pass speeds= alongside a {topo.kind!r} topology (it has no "
            f"per-device speed view to derive them from)")
    speeds = (np.asarray(speeds, dtype=np.float64) if speeds is not None
              else 1.0 / topo.w)
    p = topo.p
    assert speeds.shape == (p,)
    if K % quantum != 0 or K < quantum * p:
        quantum = 1
    pp = plan_split(topo, K, quantum=quantum, objective=mode)
    assign = LayerAssignment(pp.k, quantum)
    # compute-bound finish time model: t = max_i k_i / speed_i
    even = np.full(p, K / p)
    t_even = float(np.max(even / speeds))
    t_new = float(np.max(np.where(assign.k > 0, assign.k / speeds, 0.0)))
    return RebalancePlan(assignment=assign, speeds=speeds,
                         predicted_speedup=t_even / max(t_new, 1e-12),
                         plan=pp)


def drop_devices(assign: LayerAssignment, dead: Sequence[int],
                 speeds: Sequence[float], quantum: int = 128, *,
                 mode: str = "PCSS",
                 net: Optional[StarNetwork] = None,
                 topology: Optional[Topology] = None) -> RebalancePlan:
    """Node failure: re-solve the split over the surviving device set,
    under the SAME mode and link model the caller planned with (the
    topology/network is shrunk to the alive devices)."""
    alive = [i for i in range(assign.p) if i not in set(dead)]
    s = np.asarray(speeds, dtype=np.float64)[alive]
    topo = _as_topology(speeds, net, topology)
    if not hasattr(topo, "restrict"):
        raise ValueError(
            f"cannot shrink a {topo.kind!r} topology to the survivors; "
            f"rebuild it for the new fleet and call plan_rebalance")
    assert topo.p == assign.p, "topology must describe the pre-failure fleet"
    return plan_rebalance(assign.K, s, quantum=quantum, mode=mode,
                          topology=topo.restrict(alive))
