"""Straggler mitigation + elastic rescale: ``repro.plan`` as the
scheduling brain of the runtime.

On real fleets devices are heterogeneous in practice (thermal throttling,
SDC-quarantined hosts, DCN sharing).  The runtime:

  1. measures per-device effective rates (here: injected or timed),
  2. describes the platform as a ``repro.plan`` Topology — a flat ICI star
     from the measured speeds by default, or any caller-provided topology
     (e.g. the two-level multi-pod ``HierarchicalTopology``),
  3. calls ``repro.plan.plan()`` (§4 equality solve / two-level recursion
     + §4.5 integer adjustment, quantum=128 for MXU-aligned shards),
  4. re-packs the LBP matmul's ragged shards (core.lbp_matmul.pad_ragged).

Elastic rescale (node loss/join) is the same path with the topology
restricted to the surviving device set, plus checkpoint
restore-with-reshard (checkpoint.store).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..core.network import StarNetwork
from ..core.partition import LayerAssignment
from ..plan import PartitionPlan, StarTopology, Topology, plan as plan_split


@dataclasses.dataclass
class RebalancePlan:
    assignment: LayerAssignment
    speeds: np.ndarray
    predicted_speedup: float     # vs even split, compute-bound model
    plan: Optional[PartitionPlan] = None   # full IR (finish times, comm, provenance)


def measure_speeds(step_times: Sequence[float]) -> np.ndarray:
    """Per-device relative rate from measured per-device step times.

    A zero/negative step time is NOT a measurement — it is a device with
    no history (a replica that just joined the fleet reports 0.0 until
    its first step lands).  Those devices get the *median* rate of the
    measured ones (a neutral prior: the solver neither starves nor
    floods a newcomer), and an all-unmeasured fleet degrades to the even
    split.  The old behaviour divided by zero.
    """
    t = np.asarray(step_times, dtype=np.float64)
    if t.ndim != 1 or t.shape[0] < 1:
        raise ValueError(f"step_times must be a non-empty 1-D sequence, "
                         f"got shape {t.shape}")
    measured = t > 0
    rate = np.empty_like(t)
    if not np.any(measured):
        rate[:] = 1.0                       # no history anywhere: even split
    else:
        rate[measured] = 1.0 / t[measured]
        rate[~measured] = float(np.median(rate[measured]))
    return rate / rate.mean()


def _as_topology(speeds, net: Optional[StarNetwork],
                 topology: Optional[Topology]) -> Topology:
    """Precedence: explicit topology > legacy StarNetwork > measured speeds."""
    if topology is not None:
        return topology
    if net is not None:
        return StarTopology.from_network(net)
    if speeds is None:
        raise ValueError("pass speeds=, net= or topology= — there is "
                         "nothing to describe the fleet from")
    return StarTopology.from_speeds(np.asarray(speeds, dtype=np.float64))


def plan_rebalance(K: int, speeds: Optional[Sequence[float]] = None, *,
                   quantum: int = 128, mode: str = "PCSS",
                   net: Optional[StarNetwork] = None,
                   topology: Optional[Topology] = None) -> RebalancePlan:
    """Split contraction dim K over devices proportional to measured rates.

    Routes through ``repro.plan.plan()``; the returned ``RebalancePlan``
    carries the full ``PartitionPlan`` IR.  Falls back to quantum=1 if K
    is too small to quantize by 128 (reduced smoke configs)."""
    topo = _as_topology(speeds, net, topology)
    if speeds is None and not hasattr(topo, "w"):
        raise ValueError(
            f"pass speeds= alongside a {topo.kind!r} topology (it has no "
            f"per-device speed view to derive them from)")
    speeds = (np.asarray(speeds, dtype=np.float64) if speeds is not None
              else 1.0 / topo.w)
    p = topo.p
    assert speeds.shape == (p,)
    if K % quantum != 0 or K < quantum * p:
        quantum = 1
    pp = plan_split(topo, K, quantum=quantum, objective=mode)
    assign = LayerAssignment(pp.k, quantum)
    # compute-bound finish time model: t = max_i k_i / speed_i
    even = np.full(p, K / p)
    t_even = float(np.max(even / speeds))
    t_new = float(np.max(np.where(assign.k > 0, assign.k / speeds, 0.0)))
    return RebalancePlan(assignment=assign, speeds=speeds,
                         predicted_speedup=t_even / max(t_new, 1e-12),
                         plan=pp)


def correct_shares(rb: RebalancePlan, src: int, dst: int,
                   amount: int) -> RebalancePlan:
    """Apply ONE work-stealing correction (``runtime.correct``) to a
    rebalance plan: move ``amount`` contraction units from device ``src``
    to ``dst`` WITHOUT re-solving — the per-step share correction the
    dynamic corrector performs on the virtual-load assignment.  The
    amount must keep the quantum alignment (the corrector's steal units
    guarantee it); the carried ``PartitionPlan`` is re-scaled the same
    way the corrector re-scales its own plan."""
    from .correct import corrected_plan
    k = rb.assignment.k.copy()
    p = k.shape[0]
    if not (0 <= src < p and 0 <= dst < p) or src == dst:
        raise ValueError(f"bad correction {src}->{dst} for {p} devices")
    amount = int(amount)
    if not 0 < amount <= int(k[src]):
        raise ValueError(
            f"cannot move {amount} units from device {src} holding {k[src]}")
    k[src] -= amount
    k[dst] += amount
    assign = LayerAssignment(k, rb.assignment.quantum)
    even = np.full(p, assign.K / p)
    t_even = float(np.max(even / rb.speeds))
    t_new = float(np.max(np.where(k > 0, k / rb.speeds, 0.0)))
    return RebalancePlan(
        assignment=assign, speeds=rb.speeds,
        predicted_speedup=t_even / max(t_new, 1e-12),
        plan=corrected_plan(rb.plan, k) if rb.plan is not None else None)


def drop_devices(assign: LayerAssignment, dead: Sequence[int],
                 speeds: Sequence[float], quantum: int = 128, *,
                 mode: str = "PCSS",
                 net: Optional[StarNetwork] = None,
                 topology: Optional[Topology] = None) -> RebalancePlan:
    """Node failure: re-solve the split over the surviving device set,
    under the SAME mode and link model the caller planned with (the
    topology/network is shrunk to the alive devices)."""
    alive = [i for i in range(assign.p) if i not in set(dead)]
    s = np.asarray(speeds, dtype=np.float64)[alive]
    topo = _as_topology(speeds, net, topology)
    if not hasattr(topo, "restrict"):
        raise ValueError(
            f"cannot shrink a {topo.kind!r} topology to the survivors; "
            f"rebuild it for the new fleet and call plan_rebalance")
    assert topo.p == assign.p, "topology must describe the pre-failure fleet"
    return plan_rebalance(assign.K, s, quantum=quantum, mode=mode,
                          topology=topo.restrict(alive))


def join_devices(assign: LayerAssignment, joining: Sequence[float],
                 speeds: Sequence[float], quantum: int = 128, *,
                 mode: str = "PCSS",
                 link_class: Optional[float] = None,
                 net: Optional[StarNetwork] = None,
                 topology: Optional[Topology] = None) -> RebalancePlan:
    """Elastic join — ``drop_devices``' counterpart: re-solve the split
    over the union of the incumbent fleet and newly joined devices.

    ``joining`` gives the newcomers' measured (or presumed) rates;
    ``speeds`` describes the incumbents, matching ``assign``.  A star
    topology/network is extended with the joiners as ICI-class children
    (or ``link_class``); multi-level topologies cannot be grown in place
    — rebuild them for the new fleet and call ``plan_rebalance``.
    """
    joining = np.atleast_1d(np.asarray(joining, dtype=np.float64))
    if joining.shape[0] < 1 or not np.all(joining > 0):
        raise ValueError(
            f"joining devices need positive rates (got {joining!r}); "
            f"rate-less newcomers go through measure_speeds, which "
            f"assigns them the fleet's median")
    s_old = np.asarray(speeds, dtype=np.float64)
    assert s_old.shape == (assign.p,), \
        "speeds must describe the incumbent fleet (one per assign device)"
    s = np.concatenate([s_old, joining])
    topo = None
    if topology is not None or net is not None:
        base = _as_topology(speeds, net, topology)
        assert base.p == assign.p, \
            "topology must describe the incumbent fleet"
        if not isinstance(base, StarTopology):
            raise ValueError(
                f"cannot grow a {base.kind!r} topology in place; rebuild "
                f"it for the new fleet and call plan_rebalance")
        from ..plan import ICI_LINK
        z_new = np.full(joining.shape[0],
                        ICI_LINK if link_class is None else link_class)
        topo = StarTopology(w=np.concatenate([base.w, 1.0 / joining]),
                            z=np.concatenate([base.z, z_new]),
                            t_cp=base.t_cp, t_cm=base.t_cm)
    return plan_rebalance(assign.K, s, quantum=quantum, mode=mode,
                          topology=topo)
