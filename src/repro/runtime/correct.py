"""Dynamic-correction scheduling: drift-triggered work stealing over a
static LBP plan (ROADMAP open item 5).

The §4/§5 plans are static: they assume the measured speeds hold for the
whole run.  On contended hardware they do not — the tail device becomes
the makespan.  Beaumont et al. ("Analysis of Dynamic Scheduling
Strategies for Matrix Multiplication on Heterogeneous Platforms") show
the winning strategy is a HYBRID: keep the static seed plan, add a
bounded runtime corrector, and steal at the granularity the partition
already uses ("Revisiting Matrix Product on Master-Worker Platforms"
motivates layer-block steals).  This module is that corrector:

  * detection is NEVER invented here — the corrector consumes
    ``obs.DriftMonitor`` skew (``observe_finish`` / ``observe_shares``
    + ``should_replan``), the exact signal PR 7 landed;
  * a correction moves ONE steal unit of load from the straggler (the
    node with the highest predicted relative finish under the current
    shares) to whichever node minimizes the post-steal makespan — list
    scheduling at steal-unit granularity;
  * a hysteresis bound (trip threshold = ``hysteresis x`` the plan's own
    quantization tolerance) guarantees an UNDISTURBED run performs zero
    steals and stays bit-identical to the static path;
  * a cooldown + global budget bound the number of corrections, and an
    improvement guard (the predicted makespan must strictly drop)
    prevents oscillation.

Two observation surfaces, matching the two drift signals:

  observe_times(busy)  the TRAIN/OVERLAP plane: per-node busy seconds of
                       one synchronous step.  Work shares cannot drift
                       there (every node processes exactly its assigned
                       rows), so skew lives in finish-time space —
                       scored against ``plan.finish_times`` with the
                       finish-space ``tolerance()``.  A uniform platform
                       slowdown scores zero drift (nothing to rebalance).
  observe(work)        the SERVE plane: per-replica work (decode tokens)
                       since the current plan — share-fraction space,
                       scored with ``share_tolerance()``.

Steal units per execution plane (``steal_unit``):

  train    one quantum layer block (the §4.5 alignment unit — shares
           stay MXU-aligned through any number of corrections)
  overlap  one whole ring tile (quantum x ring size) so the streamed
           matmul's per-device tiling stays divisible by the ring
  serve    one queued request (the fleet controller sheds it through
           the exactly-once requeue path)

``simulate_correction`` is the deterministic per-step loop used by the
contention benchmark and the tier-1 acceptance tests.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from ..obs.drift import DriftMonitor
from ..plan.ir import PartitionPlan

__all__ = ["CorrectionPolicy", "StealEvent", "WorkStealingCorrector",
           "corrected_plan", "simulate_correction", "steal_unit"]


@dataclasses.dataclass(frozen=True)
class StealEvent:
    """One correction: ``amount`` load units moved src -> dst at the
    observation step where drift ``drift`` tripped the threshold."""

    step: int
    src: int
    dst: int
    amount: int
    drift: float


@dataclasses.dataclass(frozen=True)
class CorrectionPolicy:
    """Bounds on the corrector (all three are load-bearing for the
    zero-steals-when-undisturbed and bounded-convergence guarantees)."""

    hysteresis: float = 1.25  # trip at hysteresis x plan tolerance (>= 1)
    cooldown: int = 1         # observations between corrections
    max_corrections: int = 8  # global steal budget for the plan's lifetime
    min_window: float = 0.0   # minimum observed mass before scoring
    persistence: int = 1      # consecutive over-threshold obs before a steal

    def __post_init__(self):
        assert self.hysteresis >= 1.0, \
            "hysteresis < 1 would steal on quantization noise alone"
        assert self.cooldown >= 1 and self.max_corrections >= 0
        assert self.persistence >= 1


def steal_unit(plan: PartitionPlan, plane: str, *, ring: int = 1) -> int:
    """Load units one correction moves, per execution plane (see module
    docstring).  Always a multiple of ``plan.quantum`` for the partition
    planes, so corrected shares stay quantum-aligned."""
    if plane == "train":
        return int(plan.quantum)
    if plane == "overlap":
        return int(plan.quantum) * max(1, int(ring))
    if plane == "serve":
        return 1
    raise ValueError(f"unknown execution plane {plane!r} "
                     f"(expected train | overlap | serve)")


def corrected_plan(plan: PartitionPlan, new_k: np.ndarray) -> PartitionPlan:
    """The plan with shares ``new_k`` and finish times re-scaled by the
    share ratio (per-unit service times are recovered from the plan
    itself, the same trick ``DriftMonitor.tolerance`` uses).  ``k_real``
    keeps the solver's original optimum — provenance of the seed."""
    k = np.asarray(new_k, dtype=np.int64)
    assert int(k.sum()) == int(plan.load) and np.all(k >= 0)
    old = plan.k.astype(np.float64)
    loaded = plan.k > 0
    per_unit = (float(np.median(plan.finish_times[loaded] / old[loaded]))
                if loaded.any() else 0.0)

    def rescale(ft):
        ratio = np.where(old > 0, k / np.maximum(old, 1.0), 0.0)
        return np.where(old > 0, np.asarray(ft) * ratio, k * per_unit)

    fo = (rescale(plan.finish_times_overlap)
          if plan.finish_times_overlap is not None else None)
    meta = dict(plan.meta)
    meta["corrections"] = int(meta.get("corrections", 0)) + 1
    return dataclasses.replace(plan, k=k,
                               finish_times=rescale(plan.finish_times),
                               finish_times_overlap=fo, meta=meta)


class WorkStealingCorrector:
    """Seeds from a static plan, consumes DriftMonitor skew, re-assigns
    marginal blocks straggler -> fastest-absorber under a hysteresis
    bound.  ``self.plan`` always carries the shares to execute; the
    caller resets its observation accumulator whenever an event is
    returned (the monitor is reseeded on the corrected plan)."""

    def __init__(self, plan: PartitionPlan, *, plane: str = "train",
                 ring: int = 1, overlap: bool = False,
                 policy: Optional[CorrectionPolicy] = None,
                 metrics=None, tracer=None, track: str = "controller",
                 gauge_name: str = "plan_drift"):
        self.seed_plan = plan
        self.plan = plan
        self.plane = plane
        self.unit = steal_unit(plan, plane, ring=ring)
        self.policy = policy or CorrectionPolicy()
        self.metrics = metrics
        self.tracer = tracer
        self.track = track
        self._overlap = overlap
        self._gauge_name = gauge_name
        self.monitor = DriftMonitor(plan, overlap=overlap, metrics=metrics,
                                    gauge_name=gauge_name)
        self.events: List[StealEvent] = []
        self.steps = 0
        self._last_correction = -10 ** 9
        self._over = 0   # consecutive over-threshold observations

    # -- observation surfaces -------------------------------------------
    def observe_times(self, busy: Sequence[float]) -> Optional[StealEvent]:
        """Train/overlap plane: per-node busy seconds of one synchronous
        step.  Observed times are scaled so their loaded-node total
        matches the plan's (a uniformly slower platform is NOT drift),
        then scored against ``finish_times`` with the finish-space
        tolerance."""
        self.steps += 1
        busy = np.asarray(busy, dtype=np.float64)
        loaded = self.plan.k > 0
        obs_mass = float(busy[loaded].sum())
        if obs_mass <= 0:
            return None
        scale = float(self.monitor.predicted[loaded].sum()) / obs_mass
        drift = self.monitor.observe_finish(busy * scale)
        if not self._tripped(
                self.policy.hysteresis * self.monitor.tolerance()):
            return None
        # per-unit service time estimate straight from the measurement
        with np.errstate(divide="ignore", invalid="ignore"):
            w_hat = np.where(loaded, busy / np.maximum(self.plan.k, 1),
                             np.inf)
        return self._correct(w_hat, drift)

    def observe(self, work: Sequence[float]) -> Optional[StealEvent]:
        """Serve plane: per-node work (tokens, requests) since the
        current plan — share-fraction space, share-space tolerance."""
        self.steps += 1
        work = np.asarray(work, dtype=np.float64)
        drift = self.monitor.observe_shares(work)
        if float(work.sum()) < max(self.policy.min_window, 1e-12):
            return None           # not enough mass to score yet
        if not self._tripped(
                self.policy.hysteresis * self.monitor.share_tolerance()):
            return None
        # observed work per unit time fraction -> per-unit service time
        with np.errstate(divide="ignore", invalid="ignore"):
            w_hat = np.where(work > 0, 1.0 / work, np.inf)
        return self._correct(w_hat, drift)

    def _tripped(self, threshold: float) -> bool:
        """Hysteresis + persistence: the monitor must sit over the trip
        threshold for ``persistence`` CONSECUTIVE observations — one
        noisy window never moves load."""
        if not self.monitor.should_replan(threshold):
            self._over = 0
            return False
        self._over += 1
        return self._over >= self.policy.persistence

    # -- the correction -------------------------------------------------
    def _correct(self, w_hat: np.ndarray, drift: float
                 ) -> Optional[StealEvent]:
        if len(self.events) >= self.policy.max_corrections:
            return None
        if self.steps - self._last_correction < self.policy.cooldown:
            return None
        k = self.plan.k.astype(np.float64)
        t = np.where(k > 0, k * w_hat, 0.0)       # predicted rel. finish
        t = np.where(np.isnan(t), np.inf, t)
        src = int(np.argmax(t))
        if not np.isfinite(t[src]):
            return None                           # straggler unmeasured
        amount = min(self.unit, int(self.plan.k[src]))
        amount -= amount % max(1, int(self.plan.quantum))
        if amount <= 0:
            return None
        t_recv = (k + amount) * w_hat             # finish if j absorbs it
        t_recv[src] = np.inf
        dst = int(np.argmin(t_recv))
        if not np.isfinite(t_recv[dst]):
            return None
        # improvement guard: predicted makespan must strictly drop, else
        # a too-coarse unit would oscillate around the optimum
        t_new = t.copy()
        t_new[src] = (k[src] - amount) * w_hat[src]
        t_new[dst] = t_recv[dst]
        if float(np.max(t_new)) >= float(np.max(t)):
            return None
        new_k = self.plan.k.copy()
        new_k[src] -= amount
        new_k[dst] += amount
        self.plan = corrected_plan(self.plan, new_k)
        self.monitor = DriftMonitor(self.plan, overlap=self._overlap,
                                    metrics=self.metrics,
                                    gauge_name=self._gauge_name)
        ev = StealEvent(step=self.steps, src=src, dst=dst, amount=amount,
                        drift=drift)
        self.events.append(ev)
        self._last_correction = self.steps
        self._over = 0
        if self.metrics is not None:
            self.metrics.counter("steals").inc()
            self.metrics.gauge(self._gauge_name).set(0.0)
        if self.tracer is not None:
            self.tracer.event("steal", track=self.track, lane="correction",
                              src=int(src), dst=int(dst), amount=int(amount),
                              drift=round(float(drift), 6))
        return ev


def simulate_correction(plan: PartitionPlan, *,
                        slow_node: Optional[int] = None,
                        slow_at_frac: float = 0.3, slow_factor: float = 2.0,
                        n_steps: int = 32, plane: str = "train",
                        ring: int = 1, steal: bool = True,
                        policy: Optional[CorrectionPolicy] = None) -> dict:
    """Deterministic contention simulation (the bench/test harness).

    Runs ``n_steps`` synchronous steps: node i is busy ``k_i * w_i`` per
    step, with per-unit times ``w`` recovered from the plan itself, so
    an UNDISTURBED run observes exactly the predicted finish times —
    zero drift, provably zero steals, shares bit-identical to the seed.
    With ``slow_node`` set, that node's ``w`` is multiplied by
    ``slow_factor`` from step ``slow_at_frac * n_steps`` on; the
    corrector sees each step's busy times and converges the realized
    per-step finish spread back inside the plan's quantization
    tolerance within its steal budget.
    """
    corr = WorkStealingCorrector(plan, plane=plane, ring=ring, policy=policy)
    loaded = plan.k > 0
    w = np.where(loaded, plan.finish_times / np.maximum(plan.k, 1), 0.0)
    slow_at = int(round(slow_at_frac * n_steps))
    total_time = total_static = 0.0
    spread = 0.0
    convergence_step = None
    for step in range(1, n_steps + 1):
        w_eff = w.copy()
        if slow_node is not None and step > slow_at:
            w_eff[slow_node] *= slow_factor
        k = corr.plan.k
        busy = k * w_eff
        total_time += float(busy.max())
        total_static += float((plan.k * w_eff).max())
        live = k > 0
        spread = float((busy[live].max() - busy[live].min())
                       / max(busy[live].max(), 1e-12)) if live.any() else 0.0
        if steal:
            ev = corr.observe_times(busy)
            if ev is not None:
                convergence_step = step
    tol = float(corr.monitor.tolerance())
    # the corrector re-assigns in whole steal units, so the spread it can
    # converge to is the one-UNIT shift, not the one-quantum shift: the
    # plan tolerance scaled by unit/quantum (identical on the train
    # plane, x ring on the overlap plane)
    unit_tol = tol * corr.unit / max(1, int(plan.quantum))
    return {
        "n_steps": int(n_steps),
        "slow_at": int(slow_at) if slow_node is not None else None,
        "makespan": round(total_time, 6),
        "makespan_static": round(total_static, 6),
        "spread_final": round(spread, 6),
        "steals": len(corr.events),
        "steal_bound": int(corr.policy.max_corrections),
        "convergence_step": convergence_step,
        "tolerance": round(tol, 6),
        "unit_tolerance": round(unit_tol, 6),
        "unit": int(corr.unit),
        "final_k": [int(x) for x in corr.plan.k],
        "seed_k": [int(x) for x in plan.k],
        "events": [dataclasses.asdict(e) for e in corr.events],
    }
