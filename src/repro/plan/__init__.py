"""Topology-aware planning subsystem: one PartitionPlan IR for everybody.

The paper's point is a *single* scheduling model; this package is its
architectural seam.  Describe the platform once as a ``Topology``
(flat star from measured speeds, §5 mesh, or the two-level pod
hierarchy of the production multi-pod mesh), then

    pp = plan(topology, load, quantum=..., objective=...)

returns a ``PartitionPlan``: quantum-aligned integer shares, the solver's
real-valued optimum, predicted per-node finish times, per-link-class comm
volume, and solver provenance.  Every consumer routes through here —
``core.partition.LayerAssignment.from_speeds`` (training splits),
``runtime.rebalance`` (straggler mitigation / elastic rescale) and the
serving ``CapacityPlanner`` — so the cost model lives in ONE place.

Solvers are a registry keyed by topology kind (``register_planner``);
the matching execution-plane aggregation for two-level plans is the
"hierarchical" mode in ``core.collectives``.
"""

from .ir import CommVolume, PartitionPlan  # noqa: F401
from .solvers import (POD_MODE, available_planners,  # noqa: F401
                      compare_flat_hierarchical, comm_for_split,
                      evaluate_split, plan, register_planner)
from .topology import (DCN_CLASS_Z, DCN_LINK, ICI_LINK,  # noqa: F401
                       HierarchicalTopology, MeshTopology, StarTopology,
                       Topology, production_shape, production_topology)
