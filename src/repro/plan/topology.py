"""Platform topologies: describe the hardware once, lower it to solver models.

The paper's solvers consume *network models* (``core.network.StarNetwork``,
``core.network.MeshNetwork``); production code should never hand-build
those.  A ``Topology`` is the planning subsystem's description of the
platform — measured speeds plus link structure — and each concrete kind
knows how to lower itself to the model(s) its solvers need:

  StarTopology          flat single-level star (§4): every device hangs off
                        the source on its own link.  The in-pod TPU case is
                        z ~ 0 (ICI_LINK): the solver balances compute only.
  MeshTopology          §5 X x Y grid, wraps ``core.network.MeshNetwork``.
  HierarchicalTopology  two-level pod hierarchy: a DCN trunk per pod
                        (shared by the pod's devices) and near-zero ICI
                        within — the production multi-pod shape of
                        ``launch/mesh.py`` ((pod=2, data=16, model=16)),
                        whose "pod" axis crosses DCN.

The flat star model of a multi-pod platform is *wrong* in a specific way:
it gives every remote device a private DCN channel, when physically the
pod shares one trunk.  ``HierarchicalTopology.flatten()`` returns exactly
that naive view so planners/benchmarks can quantify the error (Beaumont &
Marchal, arXiv:1404.3913: the platform model, not the splitter, decides
schedule quality).
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.network import MeshNetwork, StarNetwork, W_TCP_RANGE

# Link classes of the runtime plane (inverse link speeds, paper's z).
ICI_LINK = 1e-9    # near-zero: in-pod interconnect, solver balances compute
DCN_LINK = 1e-3    # cross-pod data-center network trunk

# Any z at or above this counts as DCN-class for comm-volume accounting
# (geometric midpoint of the two classes).
DCN_CLASS_Z = 1e-6

# Production mesh shapes — the single source of truth; ``launch/mesh.py``
# builds its jax meshes from these same tuples.
_PRODUCTION_SHAPES = {False: (16, 16), True: (2, 16, 16)}


def production_shape(multi_pod: bool = False) -> Tuple[int, ...]:
    """(data, model) single pod / (pod, data, model) multi-pod chip grid."""
    return _PRODUCTION_SHAPES[bool(multi_pod)]


@dataclasses.dataclass(frozen=True)
class StarTopology:
    """Flat star: p devices, each on its own link from the source."""

    kind: ClassVar[str] = "star"

    w: np.ndarray          # (p,) inverse compute speed per device
    z: np.ndarray          # (p,) inverse link speed source->device
    t_cp: float = 1.0
    t_cm: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "w", np.asarray(self.w, dtype=np.float64))
        object.__setattr__(self, "z", np.asarray(self.z, dtype=np.float64))
        assert self.w.shape == self.z.shape and self.w.ndim == 1
        assert np.all(self.w > 0) and np.all(self.z > 0)

    @property
    def p(self) -> int:
        return int(self.w.shape[0])

    def to_network(self) -> StarNetwork:
        return StarNetwork(w=self.w, z=self.z, t_cp=self.t_cp, t_cm=self.t_cm)

    def dcn_mask(self) -> np.ndarray:
        """(p,) True where the device's link is DCN-class."""
        return self.z >= DCN_CLASS_Z

    def restrict(self, alive: Sequence[int]) -> "StarTopology":
        """The topology of a surviving subset (elastic rescale / node loss)."""
        idx = np.asarray(list(alive), dtype=np.int64)
        return StarTopology(w=self.w[idx], z=self.z[idx],
                            t_cp=self.t_cp, t_cm=self.t_cm)

    def with_rates(self, rates: Sequence[float]) -> "StarTopology":
        """Same links, fresh speed measurements (drift re-planning)."""
        rates = np.asarray(rates, dtype=np.float64)
        assert rates.shape == self.w.shape and np.all(rates > 0)
        return StarTopology(w=1.0 / rates, z=self.z,
                            t_cp=self.t_cp, t_cm=self.t_cm)

    @staticmethod
    def from_speeds(speeds: Sequence[float],
                    link_cost: float = ICI_LINK) -> "StarTopology":
        """Relative compute rates (1.0 = nominal) inside one pod: w = 1/rate,
        near-zero z so the solvers balance compute (the PCSS limit)."""
        w = 1.0 / np.asarray(speeds, dtype=np.float64)
        return StarTopology(w=w, z=np.full_like(w, link_cost))

    @staticmethod
    def from_rates(rates: Sequence[float],
                   link: Optional[Sequence[float]] = None) -> "StarTopology":
        """Measured absolute rates (e.g. tokens/sec per serving replica):
        w = 1/rate, per-device link class (default: all ICI)."""
        rates = np.asarray(rates, dtype=np.float64)
        assert np.all(rates > 0)
        w = 1.0 / rates
        z = (np.full_like(w, ICI_LINK) if link is None
             else np.asarray(link, dtype=np.float64))
        return StarTopology(w=w, z=z)

    @staticmethod
    def from_network(net: StarNetwork) -> "StarTopology":
        return StarTopology(w=net.w, z=net.z, t_cp=net.t_cp, t_cm=net.t_cm)


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """§5 multi-neighbor grid; wraps the paper's MeshNetwork model."""

    kind: ClassVar[str] = "mesh"

    network: MeshNetwork

    @property
    def p(self) -> int:
        return self.network.p

    def to_network(self) -> MeshNetwork:
        return self.network

    @staticmethod
    def from_network(net: MeshNetwork) -> "MeshTopology":
        return MeshTopology(network=net)


@dataclasses.dataclass(frozen=True)
class HierarchicalTopology:
    """Two-level pod hierarchy: one shared trunk per pod, ICI within.

    The source sits in pod 0 by convention (its trunk is ICI-class); every
    other pod is reached over its DCN trunk, *shared* by the pod's devices
    — the physical constraint the flat star misses.  Lowerings:

      top_star()   pods as super-children: the within-pod PCSS split makes
                   pod j behave exactly like one processor with
                   w_pod = 1/sum(1/w_i) (k_i w_i is constant inside the
                   pod), so the §4 machinery applies unchanged at the top.
      pod_star(j)  the within-pod star over ICI links.
      flatten()    the naive single-level view (per-device private trunk
                   links) — for quantifying the flat model's error.
    """

    kind: ClassVar[str] = "hierarchical"

    pod_w: Tuple[np.ndarray, ...]   # per-pod (m_j,) inverse device speeds
    trunk_z: np.ndarray             # (n_pods,) inverse trunk link speed
    ici_z: float = ICI_LINK
    t_cp: float = 1.0
    t_cm: float = 1.0

    def __post_init__(self):
        pods = tuple(np.asarray(w, dtype=np.float64) for w in self.pod_w)
        object.__setattr__(self, "pod_w", pods)
        object.__setattr__(self, "trunk_z",
                           np.asarray(self.trunk_z, dtype=np.float64))
        assert len(pods) == self.trunk_z.shape[0] and len(pods) >= 1
        assert all(w.ndim == 1 and w.size > 0 and np.all(w > 0) for w in pods)
        assert np.all(self.trunk_z > 0) and self.ici_z > 0

    # -- structure ---------------------------------------------------------
    @property
    def n_pods(self) -> int:
        return len(self.pod_w)

    @property
    def pod_sizes(self) -> Tuple[int, ...]:
        return tuple(int(w.shape[0]) for w in self.pod_w)

    @property
    def p(self) -> int:
        return int(sum(self.pod_sizes))

    @property
    def w(self) -> np.ndarray:
        """(p,) flattened per-device inverse speeds (pod-major order)."""
        return np.concatenate(self.pod_w)

    def pod_slices(self) -> Tuple[slice, ...]:
        offs = np.concatenate([[0], np.cumsum(self.pod_sizes)])
        return tuple(slice(int(offs[j]), int(offs[j + 1]))
                     for j in range(self.n_pods))

    def device_pod(self) -> np.ndarray:
        """(p,) pod index of each flattened device."""
        return np.repeat(np.arange(self.n_pods), self.pod_sizes)

    def dcn_trunks(self) -> np.ndarray:
        """(n_pods,) True where the pod's trunk is DCN-class."""
        return self.trunk_z >= DCN_CLASS_Z

    # -- lowerings ---------------------------------------------------------
    def pod_rate(self) -> np.ndarray:
        """(n_pods,) aggregate compute rate of each pod = sum(1/w_i)."""
        return np.array([float(np.sum(1.0 / w)) for w in self.pod_w])

    def top_star(self) -> StarNetwork:
        """Pods as super-children: w_pod = 1/sum(1/w_i), z = trunk."""
        return StarNetwork(w=1.0 / self.pod_rate(), z=self.trunk_z,
                           t_cp=self.t_cp, t_cm=self.t_cm)

    def pod_star(self, j: int) -> StarNetwork:
        w = self.pod_w[j]
        return StarNetwork(w=w, z=np.full_like(w, self.ici_z),
                           t_cp=self.t_cp, t_cm=self.t_cm)

    def flatten(self) -> StarTopology:
        """The naive single-level model: every device gets a *private* link
        of its pod's trunk class (over-provisioning DCN bandwidth m-fold)."""
        z = np.concatenate([np.full(m, self.trunk_z[j])
                            for j, m in enumerate(self.pod_sizes)])
        return StarTopology(w=self.w, z=z, t_cp=self.t_cp, t_cm=self.t_cm)

    # -- elasticity --------------------------------------------------------
    def restrict(self, alive: Sequence[int]) -> "HierarchicalTopology":
        """Drop dead devices (flattened indices); empty pods disappear."""
        alive = set(int(i) for i in alive)
        pods, trunks = [], []
        for j, sl in enumerate(self.pod_slices()):
            keep = [i - sl.start for i in range(sl.start, sl.stop)
                    if i in alive]
            if keep:
                pods.append(self.pod_w[j][keep])
                trunks.append(self.trunk_z[j])
        assert pods, "cannot restrict to an empty device set"
        return HierarchicalTopology(pod_w=tuple(pods),
                                    trunk_z=np.asarray(trunks),
                                    ici_z=self.ici_z,
                                    t_cp=self.t_cp, t_cm=self.t_cm)

    def with_rates(self, rates: Sequence[float]) -> "HierarchicalTopology":
        rates = np.asarray(rates, dtype=np.float64)
        assert rates.shape == (self.p,) and np.all(rates > 0)
        pods = tuple(1.0 / rates[sl] for sl in self.pod_slices())
        return HierarchicalTopology(pod_w=pods, trunk_z=self.trunk_z,
                                    ici_z=self.ici_z,
                                    t_cp=self.t_cp, t_cm=self.t_cm)

    @staticmethod
    def from_pod_speeds(speeds_by_pod: Sequence[Sequence[float]], *,
                        ici: float = ICI_LINK,
                        dcn: float = DCN_LINK,
                        trunk_z: Optional[Sequence[float]] = None,
                        ) -> "HierarchicalTopology":
        """Relative device rates grouped by pod.  Pod 0 hosts the source
        (ICI trunk); the rest cross DCN — override with ``trunk_z``."""
        pods = tuple(1.0 / np.asarray(s, dtype=np.float64)
                     for s in speeds_by_pod)
        if trunk_z is None:
            trunk_z = np.full(len(pods), dcn)
            trunk_z[0] = ici
        return HierarchicalTopology(pod_w=pods,
                                    trunk_z=np.asarray(trunk_z),
                                    ici_z=ici)


Topology = Union[StarTopology, MeshTopology, HierarchicalTopology]


def production_topology(*, multi_pod: bool = True,
                        seed: int = 0,
                        relative_speed: Optional[Sequence[float]] = None,
                        ) -> Topology:
    """Scheduler-plane topology of the production mesh (``launch/mesh.py``).

    Multi-pod: (pod=2, data=16, model=16) — 2 pods of 256 devices behind
    DCN trunks (pod 0 local).  Single pod: a 256-device ICI star.  Device
    heterogeneity defaults to the paper's §6.1 w*Tcp range, seeded;
    pass ``relative_speed`` (p,) to use measured rates instead.
    """
    shape = production_shape(multi_pod)
    if multi_pod:
        n_pods, per_pod = shape[0], int(np.prod(shape[1:]))
    else:
        n_pods, per_pod = 1, int(np.prod(shape))
    p = n_pods * per_pod
    if relative_speed is not None:
        w = np.mean(W_TCP_RANGE) / np.asarray(relative_speed,
                                              dtype=np.float64)
        assert w.shape == (p,)
    else:
        rng = np.random.default_rng(seed)
        w = rng.uniform(*W_TCP_RANGE, size=p)
    if not multi_pod:
        return StarTopology(w=w, z=np.full(p, ICI_LINK))
    trunk = np.full(n_pods, DCN_LINK)
    trunk[0] = ICI_LINK
    return HierarchicalTopology(
        pod_w=tuple(w[j * per_pod:(j + 1) * per_pod] for j in range(n_pods)),
        trunk_z=trunk)
