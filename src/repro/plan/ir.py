"""PartitionPlan: the one IR every planning consumer receives.

A plan is the complete answer to "how should ``load`` divisible units be
split across this platform": the solver's real-valued optimum, the
quantum-aligned integer shares actually executed, the predicted per-node
finish times of those integer shares, comm-volume accounting per link
class, and solver provenance — so training rebalance, serving capacity
split and the benchmarks all read the same structure instead of each
re-deriving pieces from raw solver outputs.

Comm-volume semantics match ``mesh_lp.LPResult.comm_volume``: entries are
counted once per link traversal, so a hierarchical plan's total includes
both the trunk hop and the intra-pod hop (the DCN/ICI split is what the
multi-pod comparisons care about).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class CommVolume:
    """Entries moved during input distribution, split by link class."""

    total: float    # sum over links of traffic (multi-hop counted per hop)
    dcn: float      # subset crossing DCN-class links (the scarce resource)
    ici: float      # subset crossing ICI-class links

    def __post_init__(self):
        assert self.total >= 0 and self.dcn >= 0 and self.ici >= 0
        assert abs(self.total - (self.dcn + self.ici)) <= 1e-6 * max(
            self.total, 1.0)


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Integer split of ``load`` units over ``p`` nodes + predictions."""

    k: np.ndarray             # (p,) int64 shares, quantum-aligned, sum==load
    k_real: np.ndarray        # (p,) the solver's real-valued optimum
    load: int
    quantum: int
    finish_times: np.ndarray  # (p,) predicted T_f(i) of the integer shares
    comm: CommVolume
    solver: str               # provenance: "star:PCCS", "hierarchical:PCCS+PCSS", "mesh:heuristic", ...
    topology_kind: str        # "star" | "mesh" | "hierarchical"
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # (p,) T_f(i) of the SAME integer shares on the overlapped
    # layer-streaming plane (finish = max(comm_i, comp_i), the paper's
    # simultaneous-start bound) — None when the topology's solver family
    # has no overlap model (mesh).  Carried alongside the serial
    # prediction so consumers can price the overlap win of any plan.
    finish_times_overlap: Any = None

    def __post_init__(self):
        k = np.asarray(self.k, dtype=np.int64)
        object.__setattr__(self, "k", k)
        object.__setattr__(self, "k_real",
                           np.asarray(self.k_real, dtype=np.float64))
        object.__setattr__(self, "finish_times",
                           np.asarray(self.finish_times, dtype=np.float64))
        assert k.shape == self.k_real.shape == self.finish_times.shape
        if self.finish_times_overlap is not None:
            fo = np.asarray(self.finish_times_overlap, dtype=np.float64)
            object.__setattr__(self, "finish_times_overlap", fo)
            assert fo.shape == k.shape
        assert np.all(k >= 0) and int(k.sum()) == int(self.load)
        if self.quantum > 1:
            assert np.all(k % self.quantum == 0), \
                "plan shares must be quantum-aligned"

    @property
    def p(self) -> int:
        return int(self.k.shape[0])

    @property
    def finish_time(self) -> float:
        """Predicted makespan: slowest node that actually holds load."""
        loaded = self.k > 0
        if not loaded.any():
            return 0.0
        return float(self.finish_times[loaded].max())

    @property
    def finish_time_overlap(self):
        """Predicted makespan on the overlapped streaming plane (None when
        no overlap model exists for this topology kind)."""
        if self.finish_times_overlap is None:
            return None
        loaded = self.k > 0
        if not loaded.any():
            return 0.0
        return float(self.finish_times_overlap[loaded].max())

    def fractions(self) -> np.ndarray:
        return self.k / max(int(self.load), 1)

    def summary(self) -> Dict[str, Any]:
        """JSON-friendly digest for benchmarks and reports."""
        return {
            "solver": self.solver,
            "topology": self.topology_kind,
            "p": self.p,
            "load": int(self.load),
            "quantum": int(self.quantum),
            "finish_time": self.finish_time,
            "finish_time_overlap": self.finish_time_overlap,
            "comm_total": self.comm.total,
            "comm_dcn": self.comm.dcn,
            "comm_ici": self.comm.ici,
            "nonzero_shares": int(np.count_nonzero(self.k)),
        }
