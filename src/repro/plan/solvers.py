"""plan(): one entry point from any Topology to a PartitionPlan.

A *planner* is registered per topology kind and owns the full lowering:
solve the real-valued split with the paper's machinery, integer-adjust to
the quantum, predict per-node finish times, and account comm volume per
link class.  Built-ins:

  star          §4 equality solvers (objective = "SCSS"|"SCCS"|"PCCS"|"PCSS"
                |"overlap", default PCCS) + §4.5 integer adjustment.  The
                beyond-paper "overlap" objective targets the layer-streaming
                execution plane (``core/overlap.py``): finish is the paper's
                simultaneous-start bound max(comm_i, k_i w_i) instead of the
                serial comm+compute sum.
  mesh          §5 MIP family (objective = "heuristic"|"pmft"|"lp", default
                heuristic): the simulation-only solvers promoted to
                first-class planning backends.
  hierarchical  NEW two-level solver: split across pods at trunk (DCN)
                cost with the §4 solver of ``objective`` (pods behave as
                super-processors, w_pod = 1/sum(1/w_i)), then recurse
                within each pod with PCSS over ICI — the same §4 machinery
                at both levels, integer-adjusted at both levels.

Why within-pod PCSS: with k_i proportional to 1/w_i the per-device compute
time k_i*w_i is constant inside the pod, i.e. the pod finishes exactly
like one processor of rate sum(1/w_i) — the super-processor abstraction
the top level assumes is *exact*, not an approximation.  (ICI z is ~0, so
compute balance is also the within-pod optimum.)
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import numpy as np

from ..core.integer_adjust import adjust_integer
from ..core.star import SOLVERS, per_processor_finish
from .ir import CommVolume, PartitionPlan
from .topology import HierarchicalTopology, MeshTopology, StarTopology, Topology

Planner = Callable[[Topology, int, int, Optional[str]], PartitionPlan]

_PLANNERS: Dict[str, Planner] = {}

# Within-pod balance mode of the hierarchical planner (see module docstring).
POD_MODE = "PCSS"


def register_planner(kind: str, fn: Planner, *, overwrite: bool = False) -> None:
    if kind in _PLANNERS and not overwrite:
        raise ValueError(f"planner for topology kind {kind!r} already registered")
    _PLANNERS[kind] = fn


def available_planners() -> Tuple[str, ...]:
    return tuple(sorted(_PLANNERS))


def plan(topology: Topology, load: int, *, quantum: int = 1,
         objective: Optional[str] = None) -> PartitionPlan:
    """Split ``load`` divisible units over ``topology``.

    ``quantum``: shares are multiples of it (128 = MXU-aligned shards,
    serving micro-batches; 1 = the paper).  ``objective`` selects the
    solver within the topology's family (see module docstring); None picks
    the kind's default.
    """
    load, quantum = int(load), int(quantum)
    assert load >= 1 and quantum >= 1
    if quantum > 1 and load % quantum != 0:
        raise ValueError(
            f"load={load} must be a multiple of quantum={quantum} "
            f"(pad the load upstream)")
    try:
        planner = _PLANNERS[topology.kind]
    except KeyError:
        raise ValueError(
            f"no planner for topology kind {topology.kind!r}; "
            f"registered: {available_planners()}") from None
    return planner(topology, load, quantum, objective)


# ---------------------------------------------------------------------------
# split evaluation (shared by planners, tests and benchmarks)
# ---------------------------------------------------------------------------

def evaluate_split(topology: Topology, k: np.ndarray, load: int,
                   objective: Optional[str] = None) -> np.ndarray:
    """Predicted per-node finish times of an *arbitrary* split under the
    topology's true cost model — e.g. to price a flat-star plan on the
    two-level platform it ignored."""
    k = np.asarray(k, dtype=np.float64)
    if isinstance(topology, StarTopology):
        return per_processor_finish(topology.to_network(), load, k,
                                    objective or "PCCS")
    if isinstance(topology, HierarchicalTopology):
        return _hier_finish_times(topology, k, load, objective or "PCCS")
    if isinstance(topology, MeshTopology):
        from ..core.mesh_lp import solve_fixed_k_normalized
        return solve_fixed_k_normalized(topology.network, load,
                                        k).t_finish_nodes
    raise ValueError(f"cannot evaluate splits on {topology.kind!r}")


def comm_for_split(topology: Topology, k: np.ndarray, load: int) -> CommVolume:
    """Input-distribution volume of a split, per link class (entries are
    counted once per link traversal, like ``LPResult.comm_volume``)."""
    k = np.asarray(k, dtype=np.float64)
    if isinstance(topology, StarTopology):
        vol = 2.0 * load * k
        dcn = topology.dcn_mask()
        return CommVolume(total=float(vol.sum()),
                          dcn=float(vol[dcn].sum()),
                          ici=float(vol[~dcn].sum()))
    if isinstance(topology, HierarchicalTopology):
        shares = np.array([float(k[sl].sum()) for sl in topology.pod_slices()])
        trunk_vol = 2.0 * load * shares
        dcn_trunk = topology.dcn_trunks()
        intra = 2.0 * load * float(k.sum())   # second hop, always ICI
        dcn = float(trunk_vol[dcn_trunk].sum())
        ici = float(trunk_vol[~dcn_trunk].sum()) + intra
        return CommVolume(total=dcn + ici, dcn=dcn, ici=ici)
    raise ValueError(f"no closed-form comm accounting for {topology.kind!r}")


def _hier_finish_times(topo: HierarchicalTopology, k: np.ndarray, load: int,
                       mode: str) -> np.ndarray:
    """Two-level timing: the §4 mode semantics applied at trunk granularity
    (each pod's share serializes through its shared trunk), then ICI
    distribution + compute within the pod."""
    shares = np.array([float(k[sl].sum()) for sl in topo.pod_slices()])
    trunk_comm = 2.0 * load * shares * topo.trunk_z * topo.t_cm
    w = topo.w
    comp = k * float(load) ** 2 * w * topo.t_cp
    if mode == "PCSS":          # simultaneous start: full comm/comp overlap
        return comp
    ici_comm = 2.0 * load * k * topo.ici_z * topo.t_cm
    if mode == "overlap":
        # streamed pipeline trunk -> ICI -> compute: the finish bound is
        # the slowest stage on the device's path, not the stage sum
        return np.maximum(np.maximum(trunk_comm[topo.device_pod()],
                                     ici_comm), comp)
    if mode == "PCCS":          # parallel trunks, consecutive start
        start = trunk_comm
    elif mode == "SCSS":        # sequential trunks, compute while receiving
        start = np.concatenate([[0.0], np.cumsum(trunk_comm)[:-1]])
    elif mode == "SCCS":        # sequential trunks, start after own transfer
        start = np.cumsum(trunk_comm)
    else:
        raise ValueError(mode)
    return start[topo.device_pod()] + ici_comm + comp


# ---------------------------------------------------------------------------
# built-in planners
# ---------------------------------------------------------------------------

def _plan_star(topo: StarTopology, load: int, quantum: int,
               objective: Optional[str]) -> PartitionPlan:
    mode = objective or "PCCS"
    net = topo.to_network()
    sched = SOLVERS[mode](net, load)
    k = adjust_integer(net, load, sched.k, mode, quantum=quantum)
    return PartitionPlan(
        k=k, k_real=sched.k, load=load, quantum=quantum,
        finish_times=per_processor_finish(net, load, k, mode),
        comm=comm_for_split(topo, k, load),
        solver=f"star:{mode}", topology_kind="star",
        meta={"schedule_finish": sched.finish_time},
        finish_times_overlap=per_processor_finish(net, load, k, "overlap"))


def _plan_hierarchical(topo: HierarchicalTopology, load: int, quantum: int,
                       objective: Optional[str]) -> PartitionPlan:
    mode = objective or "PCCS"
    top = topo.top_star()
    sched = SOLVERS[mode](top, load)
    shares = adjust_integer(top, load, sched.k, mode, quantum=quantum)

    k = np.zeros(topo.p, dtype=np.int64)
    k_real = np.zeros(topo.p, dtype=np.float64)
    for j, sl in enumerate(topo.pod_slices()):
        inv = 1.0 / topo.pod_w[j]
        k_real[sl] = sched.k[j] * inv / inv.sum()   # within-pod PCSS optimum
        share = int(shares[j])
        if share == 0:
            continue
        pod_net = topo.pod_star(j)
        psched = SOLVERS[POD_MODE](pod_net, share)
        k[sl] = adjust_integer(pod_net, share, psched.k, POD_MODE,
                               quantum=quantum)
    kf = k.astype(np.float64)
    return PartitionPlan(
        k=k, k_real=k_real, load=load, quantum=quantum,
        finish_times=_hier_finish_times(topo, kf, load, mode),
        comm=comm_for_split(topo, k, load),
        solver=f"hierarchical:{mode}+{POD_MODE}",
        topology_kind="hierarchical",
        meta={"pod_shares": shares.tolist(),
              "top_finish": sched.finish_time},
        finish_times_overlap=_hier_finish_times(topo, kf, load, "overlap"))


def _plan_mesh(topo: MeshTopology, load: int, quantum: int,
               objective: Optional[str]) -> PartitionPlan:
    from ..core.heuristic import mft_lbp_heuristic
    from ..core.mesh_lp import solve_relaxed
    from ..core.pmft import fifs, pmft_lbp

    mode = objective or "heuristic"
    net = topo.network
    if mode == "heuristic":
        ms = mft_lbp_heuristic(net, load, quantum=quantum)
        k, res, k_real = ms.k, ms.result, ms.k_relaxed
        meta = {"lp_solves": ms.lp_solves, "simplex_iters": ms.simplex_iters}
    elif mode == "pmft":
        ms = pmft_lbp(net, load, quantum=quantum)
        k, res, k_real = ms.k, ms.result, ms.k_relaxed
        meta = {"lp_solves": ms.lp_solves, "simplex_iters": ms.simplex_iters}
    elif mode == "lp":
        relaxed = solve_relaxed(net, load)
        k, res, solves, iters = fifs(net, load, relaxed, quantum=quantum)
        meta = {"lp_solves": 1 + solves, "simplex_iters": relaxed.nit + iters}
        k_real = relaxed.k
    else:
        raise ValueError(
            f"unknown mesh objective {mode!r} (use heuristic|pmft|lp)")
    vol = res.comm_volume
    return PartitionPlan(
        k=k, k_real=k_real, load=load, quantum=quantum,
        finish_times=res.t_finish_nodes,
        comm=CommVolume(total=vol, dcn=0.0, ici=vol),  # grid links: one class
        solver=f"mesh:{mode}", topology_kind="mesh", meta=meta)


register_planner("star", _plan_star)
register_planner("mesh", _plan_mesh)
register_planner("hierarchical", _plan_hierarchical)


# ---------------------------------------------------------------------------
# flat-vs-hierarchical comparison (tests, benchmarks, reports)
# ---------------------------------------------------------------------------

def compare_flat_hierarchical(topo: HierarchicalTopology, load: int, *,
                              quantum: int = 1,
                              objective: str = "PCCS") -> Dict[str, object]:
    """Price the naive flat-star plan against the two-level plan *on the
    true topology* (the flat model's private-DCN-link assumption is priced
    at what the shared trunk actually costs)."""
    hier = plan(topo, load, quantum=quantum, objective=objective)
    flat = plan(topo.flatten(), load, quantum=quantum, objective=objective)
    ft = evaluate_split(topo, flat.k, load, objective=objective)
    loaded = flat.k > 0
    flat_finish = float(ft[loaded].max()) if loaded.any() else 0.0
    flat_comm = comm_for_split(topo, flat.k, load)
    eps = 1e-12
    return {
        "hierarchical": hier,
        "flat": flat,
        "flat_finish_on_topology": flat_finish,
        "flat_comm_on_topology": flat_comm,
        "finish_speedup": flat_finish / max(hier.finish_time, eps),
        "dcn_reduction": 1.0 - hier.comm.dcn / max(flat_comm.dcn, eps),
    }
