"""Parse collective ops (+ loop trip counts) out of compiled HLO text.

``cost_analysis()`` has no collective view, so §Roofline's collective term
comes from here: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute in the post-SPMD module, with

  * result-shape bytes (per-partition, since the module is SPMD),
  * the collective group size (replica_groups, both explicit {{...}} and
    iota [G,N]<= forms),
  * a WHILE-LOOP MULTIPLIER: scan-over-layers puts one collective in the
    loop body but executes it n_layers (x grad_accum) times — each while's
    trip count is recovered from the loop-condition constant and pushed
    down the call graph.

Per-op link traffic uses the ring model (bytes actually crossing ICI per
device):  AG: (g-1)/g * out;  AR: 2 (g-1)/g * out;  RS: (g-1) * out
(out is the scattered shape);  A2A: (g-1)/g * out;  permute: out.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one shape like 'f32[8,128]{1,0}' (scalar: 'f32[]')."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    b = _DTYPE_BYTES.get(dt, 4)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def _result_bytes(result: str, op: str) -> int:
    """Result bytes; for tuple results (async -start ops) take the last
    element (first elements alias the operands)."""
    result = result.strip()
    if result.startswith("("):
        parts = _split_tuple(result)
        if not parts:
            return 0
        if op.endswith("-start"):
            return _shape_bytes(parts[-1])
        return sum(_shape_bytes(p) for p in parts)
    return _shape_bytes(result)


def _split_tuple(s: str) -> List[str]:
    s = s.strip()
    assert s.startswith("(")
    depth = 0
    parts, cur = [], []
    for ch in s[1:]:
        if ch == "(":
            depth += 1
        if ch == ")":
            if depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


_GROUPS_EXPLICIT = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")
_PAIR_RE = re.compile(r"\{(\d+),(\d+)\}")


def permute_direction_counts(hlo: str, p: int) -> Dict[str, int]:
    """Classify every collective-permute in ``hlo`` by ring direction.

    A permute whose source_target_pairs all step +1 mod ``p`` is a
    "forward" ring hop, all -1 mod ``p`` is "backward", anything else
    (or a mix) is "other".  The bidirectional streaming modes
    (``core/overlap.py`` *_bidir) are gated on exactly ceil((p-1)/2)
    forward and floor((p-1)/2) backward hops per ring — this is the
    structural check's parser.  Counts are static occurrences in the
    module text (no while-loop multiplier): the gates compare ring
    SHAPE, not executed volume.
    """
    counts = {"forward": 0, "backward": 0, "other": 0}
    for m in _PAIRS_RE.finditer(hlo):
        pairs = [(int(a), int(b)) for a, b in _PAIR_RE.findall(m.group(1))]
        if not pairs:
            continue
        if all(t == (s + 1) % p for s, t in pairs):
            counts["forward"] += 1
        elif all(t == (s - 1) % p for s, t in pairs):
            counts["backward"] += 1
        else:
            counts["other"] += 1
    return counts


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT.search(line)
    if m:
        first = m.group(1)
        return len([x for x in first.split(",") if x.strip() != ""])
    return default


_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)|body=%?([\w\.\-]+).*?condition=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _parse_computations(hlo: str) -> Dict[str, List[str]]:
    """Split HLO text into computations.

    A computation header is any line ending with '{' that contains '->'
    (e.g. '%body.1 (arg: (s32[], ...)) -> (s32[], ...) {' or
    'ENTRY %main.42 (...) -> ... {'); the body runs until a lone '}'.
    """
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped:
                name = stripped
                if name.startswith("ENTRY"):
                    name = name[len("ENTRY"):].strip()
                name = name.split("(")[0].strip().lstrip("%").strip()
                if name:
                    cur = name
                    comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        comps[cur].append(stripped)
    return comps


def collective_summary(hlo: str, n_devices_default: int = 1) -> Dict:
    comps = _parse_computations(hlo)

    # trip count per while-body: max s32 constant in its condition computation
    body_trips: Dict[str, int] = {}
    calls: Dict[str, List[str]] = defaultdict(list)
    for name, lines in comps.items():
        for ln in lines:
            if " while(" in ln and "condition=" in ln and "body=" in ln:
                m = _WHILE_RE.search(ln)
                if m:
                    g = m.groups()
                    cond, body = (g[0], g[1]) if g[0] else (g[3], g[2])
                    trip = 1
                    for cl in comps.get(cond, []):
                        for c in _CONST_RE.findall(cl):
                            trip = max(trip, int(c))
                    body_trips[body] = trip
                    calls[name].append(body)
                    calls[name].append(cond)
            else:
                for target in _CALL_RE.findall(ln):
                    if target in comps:
                        calls[name].append(target)

    # propagate multipliers from the entry
    entry = None
    for cand in comps:
        if cand.endswith(".0") or "main" in cand or entry is None:
            pass
    # entry computation = the one never called
    called = {t for ts in calls.values() for t in ts}
    roots = [c for c in comps if c not in called]
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        if m <= mult.get(name, 0):
            return
        mult[name] = m
        for t in calls.get(name, []):
            visit(t, m * body_trips.get(t, 1))

    for r in roots:
        visit(r, 1.0)

    per_op: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "bytes": 0.0, "link_bytes": 0.0})
    op_re = re.compile(
        r"=\s*(\([^=]*?\)|\S+)\s+(" + "|".join(_COLL_OPS) + r")(-start)?\(")

    for name, lines in comps.items():
        m_comp = mult.get(name, 1.0)
        for ln in lines:
            mm = op_re.search(ln)
            if not mm:
                continue
            result, op, start = mm.group(1), mm.group(2), mm.group(3)
            full_op = op + (start or "")
            if "-done(" in ln:
                continue
            nbytes = _result_bytes(result, full_op)
            g = _group_size(ln, n_devices_default)
            if g <= 1:
                link = 0.0
            elif op == "all-gather":
                link = nbytes * (g - 1) / g
            elif op == "all-reduce":
                link = nbytes * 2 * (g - 1) / g
            elif op == "reduce-scatter":
                link = nbytes * (g - 1)
            elif op == "all-to-all":
                link = nbytes * (g - 1) / g
            else:  # collective-permute
                link = float(nbytes)
            d = per_op[op]
            d["count"] += m_comp
            d["bytes"] += nbytes * m_comp
            d["link_bytes"] += link * m_comp

    total = sum(d["bytes"] for d in per_op.values())
    total_link = sum(d["link_bytes"] for d in per_op.values())
    return {
        "per_op": {k: {kk: round(vv, 1) for kk, vv in v.items()}
                   for k, v in sorted(per_op.items())},
        "total_bytes": round(total, 1),
        "total_link_bytes": round(total_link, 1),
        "n_while_loops": len(body_trips),
        "trip_counts": sorted(body_trips.values(), reverse=True)[:8],
    }
