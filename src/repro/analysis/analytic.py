"""Analytic FLOP/byte models per (arch x shape) cell — the napkin math.

Two uses:
  * MODEL_FLOPS for §Roofline (6*N*D train / 2*N*D serve, N = active
    params), plus an attention-aware "expected" FLOP count that the
    HLO-parsed number is checked against (the parser cannot see dynamic
    trip counts inside the causal flash loops, so for attention-heavy
    cells the analytic number is the trustworthy one);
  * ideal HBM bytes (weights once + activations once) for the memory term
    sanity check.
"""

from __future__ import annotations

from typing import Dict

from ..configs import get_config
from ..configs.shapes import SHAPES
from ..models.config import ModelConfig


def attention_flops_fwd(cfg: ModelConfig, S: int, B: int) -> float:
    """Score + PV flops for one full forward over B x S tokens (causal ->
    half the S^2 rectangle; windowed -> S*window band)."""
    if cfg.family == "ssm":
        # chunkwise mLSTM: per chunk c: scores c^2*hd + out c^2*hd + state 2*c*hd^2
        c = cfg.mlstm_chunk
        H, hd = cfg.n_heads, cfg.hd
        n_m = cfg.n_layers * cfg.mlstm_per_group // (cfg.mlstm_per_group + 1)
        per_tok = H * (2 * c * hd + 4 * hd * hd)
        return 2.0 * B * S * per_tok * n_m
    Hp, hd = cfg.h_padded, cfg.hd
    if cfg.block_pattern:
        n_attn = (cfg.n_layers // len(cfg.block_pattern)) * sum(
            1 for b in cfg.block_pattern if b == "A")
    else:
        n_attn = cfg.n_layers
    eff = min(S, cfg.window) if cfg.window else S
    # causal: average context length ~ eff/2 (full window band for local)
    ctx = eff if cfg.window else eff / 2.0
    return 4.0 * B * S * ctx * Hp * hd * n_attn


def cell_flops(arch: str, shape: str) -> Dict[str, float]:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    B, S = cell.global_batch, cell.seq_len
    N = cfg.n_active_params()

    if cell.kind == "train":
        D = B * S
        model = 6.0 * N * D
        # remat adds one forward recompute: 8*N*D; attention counted
        # separately (fwd + recompute + bwd~2x = 4x fwd)
        expected = 8.0 * N * D + 4.0 * attention_flops_fwd(cfg, S, B)
    elif cell.kind == "prefill":
        D = B * S
        model = 2.0 * N * D
        expected = 2.0 * N * D + attention_flops_fwd(cfg, S, B)
    else:  # decode: one token per row, context S
        D = B * 1
        model = 2.0 * N * D
        eff = min(S, cfg.window) if cfg.window else S
        if cfg.family == "ssm":
            H, hd = cfg.n_heads, cfg.hd
            n_m = cfg.n_layers * cfg.mlstm_per_group // (cfg.mlstm_per_group + 1)
            attn = 2.0 * B * H * (2 * hd * hd) * n_m
        elif cfg.block_pattern:
            n_attn = (cfg.n_layers // len(cfg.block_pattern)) * sum(
                1 for b in cfg.block_pattern if b == "A")
            attn = 4.0 * B * eff * cfg.h_padded * cfg.hd * n_attn
        else:
            attn = 4.0 * B * eff * cfg.h_padded * cfg.hd * cfg.n_layers
        expected = 2.0 * N * D + attn
    return {"model_flops": model, "expected_flops": expected,
            "tokens": float(D)}


def cell_ideal_bytes(arch: str, shape: str) -> float:
    """Ideal HBM traffic per device: weights read once per (micro)batch
    pass + KV cache read once (serve).  bf16."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    B, S = cell.global_batch, cell.seq_len
    N = cfg.n_active_params()
    n_dev = 256.0
    if cell.kind == "train":
        from ..train.step import default_grad_accum
        ga = default_grad_accum(cfg)
        # params + grads + opt read/write, sharded; weights re-gathered per
        # microbatch and for fwd/bwd/remat (x3)
        w = cfg.n_params() * 2.0 / n_dev * ga * 3.0
        opt = cfg.n_params() * (4 + 4 + 4 + 4) * 2.0 / n_dev
        act = B * S * cfg.d_model * 2.0 * cfg.n_layers * 4 / n_dev
        return w + opt + act
    if cell.kind == "prefill":
        w = cfg.n_params() * 2.0 / n_dev
        act = B * S * cfg.d_model * 2.0 * cfg.n_layers * 2 / n_dev
        return w + act
    # decode: weights once + cache once
    w = N * 2.0 / n_dev
    eff = min(S, cfg.window) if cfg.window else S
    if cfg.family in ("hybrid",):
        n_attn = (cfg.n_layers // len(cfg.block_pattern)) * sum(
            1 for b in cfg.block_pattern if b == "A")
    elif cfg.family == "ssm":
        n_attn = 0
    else:
        n_attn = cfg.n_layers
    cache = 2.0 * B * eff * cfg.kv_param * cfg.hd * 2.0 * n_attn / n_dev
    return w + cache
