"""Roofline analysis: HLO collective parsing + three-term roofline."""
