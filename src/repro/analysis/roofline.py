"""Three-term roofline from the dry-run artifacts (TPU v5e constants).

    compute term    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory term     = HLO_bytes_per_device / HBM_BW
    collective term = ring_link_bytes_per_device / ICI_BW

The dominant term is the step-time lower bound; the reported roofline
fraction is  (MODEL_FLOPS_per_device / PEAK_FLOPS) / dominant — i.e. what
share of the theoretically-attainable step time goes to *useful* model
math.  MODEL_FLOPS / HLO_FLOPs separately exposes remat/padding/redundancy
waste.

Multi-pod extension: collective bytes split by link class.  ICI carries
the in-pod hops at ICI_BW per link; each pod's shared DCN trunk carries
the cross-pod shard traffic at DCN_BW.  ``serial_vs_overlap`` prices a
step on both execution planes — the blocking plane pays the SUM of the
terms on the critical path, the layer-streaming plane (``core/overlap``)
pays their MAX per the paper's simultaneous-start analysis — which is the
ICI-vs-DCN narrative ``benchmarks/overlap.py`` reports.

Usage:
  PYTHONPATH=src python -m repro.analysis.roofline [--mesh 16x16] [--csv]
"""

from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12     # bf16 / chip (v5e)
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link
DCN_BW = 12.5e9         # bytes/s / pod trunk (100 Gb/s shared DCN uplink)

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def serial_vs_overlap(compute_s: float, ici_s: float, dcn_s: float = 0.0,
                      memory_s: float = 0.0) -> Dict[str, float]:
    """Step-time bounds of the two execution planes.

    serial:  blocking collectives — compute, ICI hops and the DCN trunk
             serialize on the critical path (memory is folded into the
             compute term as their max: HBM traffic already overlaps MXU
             issue on TPU).
    overlap: layer streaming — distribution of layer j+1 overlaps
             multiplication of layer j, so the bound is the slowest single
             term (the paper's simultaneous-start max(comm, compute)).
    """
    comp = max(compute_s, memory_s)
    serial = comp + ici_s + dcn_s
    overlapped = max(comp, ici_s, dcn_s)
    bound = max(("compute", comp), ("ici", ici_s), ("dcn", dcn_s),
                key=lambda kv: kv[1])[0]
    return {
        "compute_s": comp, "ici_s": ici_s, "dcn_s": dcn_s,
        "serial_s": serial, "overlap_s": overlapped,
        "overlap_speedup": serial / overlapped if overlapped > 0 else 1.0,
        "overlap_bound": bound,
    }


def collective_split_seconds(ici_bytes: float, dcn_bytes_per_pod: float
                             ) -> Dict[str, float]:
    """Seconds each link class needs for the given per-device ICI bytes and
    per-pod trunk bytes (the `hierarchical_byte_breakdown` quantities)."""
    return {"ici_s": ici_bytes / ICI_BW,
            "dcn_s": dcn_bytes_per_pod / DCN_BW}


def roofline_row(art: Dict) -> Dict:
    from .analytic import cell_flops

    n_dev = art["n_devices"]
    flops_dev = art["hlo_flops"]
    bytes_dev = art["hlo_bytes"]
    link_dev = art["collectives"]["total_link_bytes"]

    ana = cell_flops(art["arch"], art["shape"])
    model_dev = ana["model_flops"] / n_dev
    expected_dev = ana["expected_flops"] / n_dev
    # the HLO parser cannot expand dynamic-bound (causal flash) loops;
    # take the max of parsed and analytic as the compute estimate.
    flops_est = max(flops_dev, expected_dev)

    t_comp = flops_est / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = link_dev / ICI_BW
    dom = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
              key=lambda kv: kv[1])
    t_model = model_dev / PEAK_FLOPS
    frac = t_model / dom[1] if dom[1] > 0 else 0.0
    # both execution planes' bounds (dry-run artifacts are single-pod:
    # all collective traffic is ICI-class)
    planes = serial_vs_overlap(t_comp, t_coll, 0.0, memory_s=t_mem)
    return {
        "serial_bound_s": planes["serial_s"],
        "overlap_bound_s": planes["overlap_s"],
        "overlap_speedup": planes["overlap_speedup"],
        "arch": art["arch"], "shape": art["shape"], "mesh": art["mesh"],
        "tag": art.get("tag", ""),
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dom[0],
        "model_flops_dev": model_dev,
        "hlo_flops_dev": flops_dev,
        "expected_flops_dev": expected_dev,
        "useful_ratio": model_dev / flops_est if flops_est else 0.0,
        "roofline_fraction": frac,
        "peak_gib": art["bytes_per_device"]["peak"] / 2**30,
        "arg_gib": art["bytes_per_device"]["argument"] / 2**30,
        "temp_gib": art["bytes_per_device"]["temp"] / 2**30,
    }


def load_rows(mesh: str = "16x16", tag: Optional[str] = None) -> List[Dict]:
    rows = []
    for f in sorted((ARTIFACTS / mesh).glob("*.json")):
        art = json.loads(f.read_text())
        if tag is not None and art.get("tag", "") != tag:
            continue
        if tag is None and art.get("tag", ""):
            continue
        rows.append(roofline_row(art))
    return rows


def fmt_table(rows: List[Dict]) -> str:
    hdr = (f"| {'arch':22s} | {'shape':11s} | {'comp s':>9s} | {'mem s':>9s} "
           f"| {'coll s':>9s} | {'bound':10s} | {'useful':>6s} | {'roofl%':>6s} "
           f"| {'peak GiB':>8s} |")
    sep = "|" + "-" * (len(hdr) - 2) + "|"
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']:22s} | {r['shape']:11s} | {r['compute_s']:9.4f} "
            f"| {r['memory_s']:9.4f} | {r['collective_s']:9.4f} "
            f"| {r['dominant']:10s} | {r['useful_ratio']*100:5.1f}% "
            f"| {r['roofline_fraction']*100:5.1f}% "
            f"| {max(r['peak_gib'], r['arg_gib']+r['temp_gib']):8.2f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = load_rows(args.mesh, args.tag)
    if args.csv:
        keys = list(rows[0].keys())
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    else:
        print(fmt_table(rows))


if __name__ == "__main__":
    main()
