"""Three-term roofline from the dry-run artifacts (TPU v5e constants).

    compute term    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory term     = HLO_bytes_per_device / HBM_BW
    collective term = ring_link_bytes_per_device / ICI_BW

The dominant term is the step-time lower bound; the reported roofline
fraction is  (MODEL_FLOPS_per_device / PEAK_FLOPS) / dominant — i.e. what
share of the theoretically-attainable step time goes to *useful* model
math.  MODEL_FLOPS / HLO_FLOPs separately exposes remat/padding/redundancy
waste.

Usage:
  PYTHONPATH=src python -m repro.analysis.roofline [--mesh 16x16] [--csv]
"""

from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12     # bf16 / chip (v5e)
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def roofline_row(art: Dict) -> Dict:
    from .analytic import cell_flops

    n_dev = art["n_devices"]
    flops_dev = art["hlo_flops"]
    bytes_dev = art["hlo_bytes"]
    link_dev = art["collectives"]["total_link_bytes"]

    ana = cell_flops(art["arch"], art["shape"])
    model_dev = ana["model_flops"] / n_dev
    expected_dev = ana["expected_flops"] / n_dev
    # the HLO parser cannot expand dynamic-bound (causal flash) loops;
    # take the max of parsed and analytic as the compute estimate.
    flops_est = max(flops_dev, expected_dev)

    t_comp = flops_est / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = link_dev / ICI_BW
    dom = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
              key=lambda kv: kv[1])
    t_model = model_dev / PEAK_FLOPS
    frac = t_model / dom[1] if dom[1] > 0 else 0.0
    return {
        "arch": art["arch"], "shape": art["shape"], "mesh": art["mesh"],
        "tag": art.get("tag", ""),
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dom[0],
        "model_flops_dev": model_dev,
        "hlo_flops_dev": flops_dev,
        "expected_flops_dev": expected_dev,
        "useful_ratio": model_dev / flops_est if flops_est else 0.0,
        "roofline_fraction": frac,
        "peak_gib": art["bytes_per_device"]["peak"] / 2**30,
        "arg_gib": art["bytes_per_device"]["argument"] / 2**30,
        "temp_gib": art["bytes_per_device"]["temp"] / 2**30,
    }


def load_rows(mesh: str = "16x16", tag: Optional[str] = None) -> List[Dict]:
    rows = []
    for f in sorted((ARTIFACTS / mesh).glob("*.json")):
        art = json.loads(f.read_text())
        if tag is not None and art.get("tag", "") != tag:
            continue
        if tag is None and art.get("tag", ""):
            continue
        rows.append(roofline_row(art))
    return rows


def fmt_table(rows: List[Dict]) -> str:
    hdr = (f"| {'arch':22s} | {'shape':11s} | {'comp s':>9s} | {'mem s':>9s} "
           f"| {'coll s':>9s} | {'bound':10s} | {'useful':>6s} | {'roofl%':>6s} "
           f"| {'peak GiB':>8s} |")
    sep = "|" + "-" * (len(hdr) - 2) + "|"
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']:22s} | {r['shape']:11s} | {r['compute_s']:9.4f} "
            f"| {r['memory_s']:9.4f} | {r['collective_s']:9.4f} "
            f"| {r['dominant']:10s} | {r['useful_ratio']*100:5.1f}% "
            f"| {r['roofline_fraction']*100:5.1f}% "
            f"| {max(r['peak_gib'], r['arg_gib']+r['temp_gib']):8.2f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = load_rows(args.mesh, args.tag)
    if args.csv:
        keys = list(rows[0].keys())
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    else:
        print(fmt_table(rows))


if __name__ == "__main__":
    main()
