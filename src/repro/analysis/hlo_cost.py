"""Loop-aware instruction-level cost model parsed from compiled HLO text.

Why: ``compiled.cost_analysis()`` reports a single aggregate WITHOUT
multiplying while-loop trip counts — scan-over-layers (and grad-accum
scans) under-count FLOPs/bytes by the layer count.  This parser rebuilds
the three roofline inputs per device from the scheduled SPMD module:

  flops       2 * prod(result_dims) * prod(contracting_dims) per dot,
              times the enclosing loops' trip counts
  hbm_bytes   sum of (operands + result) bytes over every non-free
              instruction at fusion granularity (fusion bodies excluded —
              their traffic happens in registers/VMEM), times trip counts
  collectives per-op counts/bytes/ring-link-bytes, times trip counts

Computation multipliers: entry = 1; while bodies/conds multiply by the trip
count recovered from the loop-condition constant; fusion bodies (calls=)
and reduce subcomputations (to_apply=) are skipped — their cost is
attributed at the call site.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "iota", "partition-id", "replica-id",
             "opt-barrier"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)\(")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)|body=%?([\w\.\-]+).*?condition=%?([\w\.\-]+)")
_GROUPS_EXPLICIT = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_dims(shape_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


def _shape_bytes(shape_str: str) -> int:
    shape_str = shape_str.strip()
    if shape_str.startswith("("):
        return sum(_shape_bytes(p) for p in _split_tuple(shape_str))
    sd = _shape_dims(shape_str)
    if sd is None:
        return 0
    dt, dims = sd
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 4)


def _split_tuple(s: str) -> List[str]:
    s = s.strip()
    depth = 0
    parts, cur = [], []
    for ch in s[1:]:
        if ch == "(":
            depth += 1
        if ch == ")":
            if depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


def _first_paren_group(line: str) -> str:
    """Contents of the first (...) after the op name (operand list)."""
    start = line.find("(")
    if start < 0:
        return ""
    depth = 0
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                return line[start + 1:i]
    return line[start + 1:]


def _parse_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped:
                name = stripped
                if name.startswith("ENTRY"):
                    name = name[len("ENTRY"):].strip()
                name = name.split("(")[0].strip().lstrip("%").strip()
                if name:
                    cur = name
                    comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        comps[cur].append(stripped)
    return comps


def analyze_hlo(hlo: str) -> Dict:
    comps = _parse_computations(hlo)

    # --- call graph + loop trip counts ---
    body_trips: Dict[str, int] = {}
    loop_calls: Dict[str, List[str]] = defaultdict(list)   # body=/condition=
    fusion_targets = set()                                  # calls=/to_apply=
    for name, lines in comps.items():
        for ln in lines:
            if " while(" in ln and "condition=" in ln and "body=" in ln:
                m = _WHILE_RE.search(ln)
                if m:
                    g = m.groups()
                    cond, body = (g[0], g[1]) if g[0] else (g[3], g[2])
                    trip = 1
                    for cl in comps.get(cond, []):
                        for c in _CONST_RE.findall(cl):
                            trip = max(trip, int(c))
                    body_trips[body] = trip
                    loop_calls[name] += [body, cond]
            im = _INSTR_RE.match(ln)
            op_of_line = im.group(3) if im else None
            for t in re.findall(r"(?:calls|to_apply)=%?([\w\.\-]+)", ln):
                if op_of_line == "call":
                    # a plain call is an inlined sub-computation whose
                    # memory traffic is real (the CPU backend wraps
                    # parallelized fusions this way) — charge it with the
                    # caller's multiplier instead of skipping it like a
                    # fusion body / reduce subcomputation.
                    loop_calls[name].append(t)
                else:
                    fusion_targets.add(t)
            for t in re.findall(r"branch_computations=\{([^}]*)\}", ln):
                for b in t.split(","):
                    loop_calls[name].append(b.strip().lstrip("%"))

    called = {t for ts in loop_calls.values() for t in ts} | fusion_targets
    roots = [c for c in comps if c not in called]

    # Execution-count multipliers over the (acyclic) call graph.  Each
    # call edge contributes its caller's multiplier — a computation
    # reached from two call sites (or from the entry AND a loop body)
    # executes the SUM, not the max.  Processed in topological order so
    # every caller's multiplier is final before it is propagated.
    parents: Dict[str, set] = defaultdict(set)
    for n, ts in loop_calls.items():
        for t in ts:
            parents[t].add(n)
    mult: Dict[str, float] = {r: 1.0 for r in roots}
    remaining = {t: len(ps) for t, ps in parents.items()}
    queue = list(roots)
    while queue:
        n = queue.pop()
        m = mult.get(n, 0.0)
        for t in loop_calls.get(n, []):           # one entry per call site
            mult[t] = mult.get(t, 0.0) + m * body_trips.get(t, 1)
        for t in set(loop_calls.get(n, [])):
            remaining[t] -= 1
            if remaining[t] == 0:
                queue.append(t)

    # map each fusion computation's parameters to their slice behaviour so
    # fusion call sites can charge sliced windows instead of full operands
    # (scan bodies slice one layer of stacked params per trip).
    fusion_param_bytes: Dict[str, Dict[int, Optional[int]]] = {}
    for fname in fusion_targets:
        lines = comps.get(fname, [])
        shapes_f: Dict[str, str] = {}
        param_of: Dict[str, int] = {}
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            iname, result, op = im.groups()
            shapes_f[iname] = result
            if op == "parameter":
                pm = re.search(r"parameter\((\d+)\)", ln)
                if pm:
                    param_of[iname] = int(pm.group(1))
        overrides: Dict[int, Optional[int]] = {}
        # def-use inside the fusion; "passthrough" ops (bitcast/convert/...)
        # forward the analysis so `convert(param) -> dynamic-slice` is still
        # recognized as a windowed read (scan bodies do this constantly).
        _PASS = {"bitcast", "reshape", "copy", "convert", "transpose",
                 "broadcast"}
        uses: Dict[str, List[Tuple[str, int, str]]] = defaultdict(list)
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            iname, result, op = im.groups()
            if op == "parameter":
                continue
            for idx, o in enumerate(
                    re.findall(r"%([\w\.\-]+)", _first_paren_group(ln))):
                uses[o].append((op, idx, result, iname))

        def slice_bytes_of(name: str, depth: int = 0) -> Optional[int]:
            """Total windowed bytes if every (transitive) use of `name` is
            slice-like; None if any use reads it in full."""
            if depth > 6:
                return None
            total = 0
            for op, argidx, result, iname in uses.get(name, []):
                if op in ("dynamic-slice", "slice", "gather"):
                    total += _shape_bytes(result)
                elif op == "dynamic-update-slice" and argidx == 0:
                    pass  # in-place target; the update op is counted
                elif op in _PASS:
                    sub = slice_bytes_of(iname, depth + 1)
                    if sub is None:
                        return None
                    total += sub
                else:
                    return None
            return total

        for pname, pidx in param_of.items():
            sb = slice_bytes_of(pname)
            if sb is not None:
                overrides[pidx] = sb
        fusion_param_bytes[fname] = overrides

    # --- per-instruction pass (skip fusion bodies) ---
    flops = 0.0
    hbm_bytes = 0.0
    per_coll: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0.0, "bytes": 0.0, "link_bytes": 0.0})
    dot_flops_detail: Dict[str, float] = defaultdict(float)

    for name, lines in comps.items():
        if name in fusion_targets:
            continue
        m_comp = mult.get(name, 1.0)
        shapes: Dict[str, str] = {}
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            iname, result, op = im.groups()
            shapes[iname] = result

        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            iname, result, op = im.groups()
            if op in _FREE_OPS:
                continue
            operands = re.findall(r"%([\w\.\-]+)", _first_paren_group(ln))
            res_bytes = _shape_bytes(result)
            if op in ("dynamic-slice", "slice"):
                # only the sliced window moves, not the full operand
                touched = 2 * res_bytes
            elif op == "dynamic-update-slice":
                upd = _shape_bytes(shapes.get(operands[1], "")) if len(operands) > 1 else 0
                touched = 2 * upd        # read update + write window (in-place)
            elif op in ("while", "conditional", "call"):
                touched = 0              # cost attributed inside
            elif op == "fusion":
                target = None
                fm = re.search(r"calls=%?([\w\.\-]+)", ln)
                if fm:
                    target = fm.group(1)
                overrides = fusion_param_bytes.get(target, {})
                op_bytes = 0
                for idx, o in enumerate(operands):
                    if idx in overrides:
                        op_bytes += overrides[idx]
                    else:
                        op_bytes += _shape_bytes(shapes.get(o, ""))
                touched = op_bytes + res_bytes
            else:
                op_bytes = sum(_shape_bytes(shapes.get(o, "")) for o in operands)
                touched = op_bytes + res_bytes
            hbm_bytes += touched * m_comp

            if op == "dot":
                sd = _shape_dims(result)
                cm = _CDIMS_RE.search(ln)
                if sd and cm and operands:
                    _, rdims = sd
                    out_elems = 1
                    for d in rdims:
                        out_elems *= d
                    lhs = _shape_dims(shapes.get(operands[0], "")) or ("", [])
                    cdim_idx = [int(x) for x in cm.group(1).split(",") if x]
                    k = 1
                    for ci in cdim_idx:
                        if ci < len(lhs[1]):
                            k *= lhs[1][ci]
                    f = 2.0 * out_elems * k * m_comp
                    flops += f
                    dot_flops_detail[name] += f

            base = op[:-6] if op.endswith("-start") else op
            if base in _COLL_OPS and not op.endswith("-done"):
                if result.strip().startswith("("):
                    parts = _split_tuple(result)
                    nbytes = _shape_bytes(parts[-1]) if parts else 0
                else:
                    nbytes = _shape_bytes(result)
                g = 1
                gm = _GROUPS_IOTA.search(ln)
                if gm:
                    g = int(gm.group(2))
                else:
                    gm = _GROUPS_EXPLICIT.search(ln)
                    if gm:
                        g = len([x for x in gm.group(1).split(",") if x.strip()])
                if g <= 1:
                    link = 0.0
                elif base == "all-gather":
                    link = nbytes * (g - 1) / g
                elif base == "all-reduce":
                    link = nbytes * 2 * (g - 1) / g
                elif base == "reduce-scatter":
                    link = nbytes * (g - 1)
                elif base == "all-to-all":
                    link = nbytes * (g - 1) / g
                else:
                    link = float(nbytes)
                d = per_coll[base]
                d["count"] += m_comp
                d["bytes"] += nbytes * m_comp
                d["link_bytes"] += link * m_comp

    if hbm_bytes == 0.0:
        # Some backend/fusion layouts leave every charged instruction
        # behind call/fusion indirection the walk above cannot price;
        # fall back to the floor every program pays: entry parameters
        # read once + root results written once.
        for name in roots:
            for ln in comps.get(name, []):
                im = _INSTR_RE.match(ln)
                if not im:
                    continue
                _, result, op = im.groups()
                if op == "parameter" or ln.startswith("ROOT"):
                    hbm_bytes += _shape_bytes(result)

    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collectives": {
            "per_op": {k: {kk: round(vv, 1) for kk, vv in v.items()}
                       for k, v in sorted(per_coll.items())},
            "total_bytes": round(sum(d["bytes"] for d in per_coll.values()), 1),
            "total_link_bytes": round(
                sum(d["link_bytes"] for d in per_coll.values()), 1),
            "n_while_loops": len(body_trips),
            "trip_counts": sorted(body_trips.values(), reverse=True)[:8],
        },
    }
