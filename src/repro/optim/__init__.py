from .adamw import adamw_init, adamw_update, cosine_schedule, opt_state_specs  # noqa: F401
