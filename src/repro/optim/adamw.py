"""AdamW in pure JAX, sharded like the parameters (ZeRO-style).

Optimizer state lives in float32 with the same PartitionSpecs as the
parameters (FSDP embed dim + TP), so m/v never replicate.  The update is
elementwise — no resharding.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def cosine_schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.peak_lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_init(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_specs(p_specs):
    """PartitionSpec pytree for adamw_init(params) given param specs."""
    from jax.sharding import PartitionSpec as P
    return {"m": p_specs, "v": jax.tree.map(lambda s: s, p_specs),
            "step": P()}


def adamw_update(params, grads, state, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = cosine_schedule(step, cfg)

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        newp = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return newp, m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
