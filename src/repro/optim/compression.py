"""Int8 gradient compression with error feedback for the slow (DCN) axis.

At multi-pod scale the cross-pod gradient reduction crosses data-center
network links ~an order of magnitude slower than ICI.  ``compressed_psum``
performs the cross-pod all-reduce as: int8-quantize (per-tensor absmax
scale) -> all_gather(int8 + f32 scale) -> dequantize-sum.  Ring bytes drop
to ~1/4 of a bf16 all-reduce ((p-1)/p * 1B vs 2(p-1)/p * 2B).

Quantization error is returned so the caller can keep an error-feedback
buffer (add the residual into the next step's gradients) — standard EF-SGD;
tests verify convergence against the uncompressed path.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _psum_int8_local(x: jax.Array, axis: str) -> jax.Array:
    """Inside shard_map: mean over `axis` via int8 all_gather + local sum."""
    q, s = quantize_int8(x)
    qg = jax.lax.all_gather(q, axis)          # (p, ...) int8 on the wire
    sg = jax.lax.all_gather(s, axis)          # (p,) f32 scales
    deq = qg.astype(jnp.float32) * sg.reshape((-1,) + (1,) * x.ndim)
    return deq.sum(axis=0) / qg.shape[0]


def compressed_pmean(tree, mesh: Mesh, axis: str = "pod", specs=None):
    """Mean-reduce a pytree across `axis` with int8 wire format.

    ``specs``: PartitionSpec pytree describing each leaf's sharding over the
    OTHER mesh axes (e.g. the FSDP/TP param specs); the `axis` dim must not
    appear in them (values differ across `axis` — that is what gets
    reduced).  Returns (reduced_tree, error_tree) where error = input -
    quantized(input) for error feedback into the next step.
    """
    flat, tdef = jax.tree.flatten(tree)
    if specs is None:
        flat_specs = [P(*([None] * x.ndim)) for x in flat]
    else:
        flat_specs = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    outs = []
    for x, spec in zip(flat, flat_specs):
        fn = shard_map(functools.partial(_psum_int8_local, axis=axis),
                       mesh=mesh, in_specs=spec, out_specs=spec,
                       check_vma=False)
        reduced = fn(x)
        q, s = quantize_int8(x)
        err = x - dequantize_int8(q, s)
        outs.append((reduced, err))
    return tdef.unflatten([o[0] for o in outs]), tdef.unflatten([o[1] for o in outs])
