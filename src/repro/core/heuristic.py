"""MFT-LBP-heuristic (paper Algorithm 3 + §5.4 gradient-descent refinement).

Differences from PMFT-LBP:
  - the sum-repair in phase II uses the T_f(i) ordering from a SINGLE fixed-k
    LP solve, walking the sorted array circularly (no LP re-solve per move);
  - phase III checks only the single max->min neighbor per iteration and
    stops at the first non-improving move.

The paper advertises "solves LP twice"; evaluating the *final* integer
schedule requires one more fixed-k solve, which we perform and count
honestly in ``lp_solves`` / ``simplex_iters`` (this is still far below
PMFT-LBP's per-move re-solves, reproducing Fig. 9's gap).
"""

from __future__ import annotations

import numpy as np

from .mesh_lp import solve_fixed_k, solve_fixed_k_normalized, solve_relaxed
from .network import MeshNetwork
from .pmft import MeshSchedule, _eligible_receivers


def mft_lbp_heuristic(net: MeshNetwork, N: int, quantum: int = 1,
                      max_moves: int = 50, refine: bool = True) -> MeshSchedule:
    q = quantum
    relaxed = solve_relaxed(net, N)                       # LP solve #1
    solves, iters = 1, relaxed.nit

    k = np.rint(relaxed.k / q) * q
    k = np.maximum(k, 0.0)
    k[net.source] = 0.0

    # LP solve #2: T_f(i) at the rounded (possibly infeasible-sum) point.
    res = solve_fixed_k_normalized(net, N, k)
    solves += 1
    iters += res.nit

    diff = float(k.sum()) - float(N)
    if diff != 0.0:
        tf = res.t_finish_nodes.copy()
        nonsource = np.arange(net.p) != net.source
        order = np.argsort(tf)  # ascending finish time
        order = order[nonsource[order]]
        if diff < 0:
            # add +q starting from the fastest finisher, circularly
            idx = 0
            while diff < 0:
                i = int(order[idx % len(order)])
                if k[i] + q <= _storage_cap_arr(net, N)[i]:
                    k[i] += q
                    diff += q
                idx += 1
        else:
            # remove -q starting from the slowest finisher, circularly
            idx = len(order) - 1
            while diff > 0:
                i = int(order[idx % len(order)])
                if k[i] >= q:
                    k[i] -= q
                    diff -= q
                idx -= 1
        res = solve_fixed_k(net, N, k)                    # final evaluation
        solves += 1
        iters += res.nit

    if refine:
        # §5.4 phase III: single gradient-descent move per iteration.
        for _ in range(max_moves):
            tf = res.t_finish_nodes
            loaded = (k > 0)
            loaded[net.source] = False
            if not loaded.any():
                break
            a = int(np.argmax(np.where(loaded, tf, -np.inf)))
            ok = _eligible_receivers(net, N, k, q)
            ok[a] = False
            if not ok.any():
                break
            b = int(np.argmin(np.where(ok, tf, np.inf)))
            kk = k.copy()
            kk[a] -= q
            kk[b] += q
            r = solve_fixed_k(net, N, kk)
            solves += 1
            iters += r.nit
            if r.t_finish >= res.t_finish:
                break
            k, res = kk, r

    return MeshSchedule(k=k.astype(np.int64), result=res,
                        lp_solves=solves, simplex_iters=iters,
                        k_relaxed=relaxed.k)


def _storage_cap_arr(net: MeshNetwork, N: int) -> np.ndarray:
    if net.storage is None:
        return np.full(net.p, np.inf)
    return np.maximum(0.0, (net.storage - float(N) ** 2) / (2.0 * N))
