"""Integer adjustment of real-valued LBP splits (paper §4.5).

The star solvers return real-valued ``{k_i}``.  In practice k_i must be an
integer (a whole column of A / row of B).  The paper's heuristic:

  1. round each k_i to the nearest integer ("a processor gets the whole
     row/column if it takes more than half of the fractional part");
  2. if sum != N, sort processors by their actual finish time T_f(i):
       sum < N  -> repeatedly give +1 to the processor with the SMALLEST T_f(i)
       sum > N  -> repeatedly take -1 from the processor with the LARGEST T_f(i)
     recomputing finish times after every single-unit move.

TPU adaptation: the same machinery with ``quantum=128`` produces
MXU-lane-aligned shard sizes (see DESIGN.md §2); quantum=1 reproduces the
paper exactly.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from .network import StarNetwork
from .star import Mode, per_processor_finish


def adjust_integer(
    net: StarNetwork,
    N: int,
    k_real: np.ndarray,
    mode: Mode,
    quantum: int = 1,
) -> np.ndarray:
    """Round a real split to integers (multiples of ``quantum``) summing to N.

    N must be divisible by ``quantum`` when quantum > 1 (the TPU case pads N
    upstream); quantum=1 is the paper's setting.
    """
    if quantum != 1:
        assert N % quantum == 0, "pad N to a multiple of the quantum first"
    q = float(quantum)
    k = np.rint(np.asarray(k_real, dtype=np.float64) / q) * q
    k = np.maximum(k, 0.0)

    target = float(N)
    # Iteratively repair the sum, one quantum at a time (paper: "we conduct
    # the adjustment iteratively ... every iteration we only adjust one
    # row/column, then we update each processor's T_f").
    guard = 0
    while k.sum() != target and guard < 16 * net.p + int(2 * N / q) + 8:
        guard += 1
        tf = per_processor_finish(net, N, k, mode)
        if k.sum() < target:
            i = int(np.argmin(tf))
            k[i] += q
        else:
            # only remove from processors that still have load
            loaded = k > 0
            tf_masked = np.where(loaded, tf, -np.inf)
            i = int(np.argmax(tf_masked))
            k[i] -= q
    assert k.sum() == target, "integer adjustment failed to converge"
    assert np.all(k >= 0)
    return k.astype(np.int64)


def solve_integer(net: StarNetwork, N: int, mode: Mode = "PCCS", quantum: int = 1):
    """Convenience: real solve + §4.5 adjustment. Returns (k_int, T_f)."""
    from .star import SOLVERS, finish_time_for_split

    sched = SOLVERS[mode](net, N)
    k_int = adjust_integer(net, N, sched.k, mode, quantum=quantum)
    tf = finish_time_for_split(net, N, k_int, mode)
    return k_int, tf
