"""Distributed LBP matmul: the paper's technique as a composable JAX module.

The paper's layer-based partition assigns processor i the slice
``A[:, K_i]  /  B[K_i, :]`` of the contraction dimension; it computes one
full-shape *layer* ``L_i = A[:,K_i] @ B[K_i,:]`` and ``C = sum_i L_i``.

On a TPU mesh this is contraction-dimension (k) sharding.  Three aggregation
modes mirror the paper's assumption §1.2 and our beyond-paper optimization:

  "layers"     no aggregation — each device keeps its layer (the paper's
               'distributed storage of layers, lazy sync-up').  Output has a
               leading device axis.
  "allreduce"  eager aggregation via psum (paper-faithful when a replicated
               result is required; what a naive port would do).
  "scatter"    deferred aggregation via psum_scatter — each device owns a
               1/p slice of the *aggregated* sum along an output dim.  This
               is the paper's lazy aggregation made productive: collective
               bytes drop from 2(p-1)/p to (p-1)/p of the output
               (reduce-scatter vs all-reduce), and is the building block of
               sequence-parallel transformers.

Heterogeneous (ragged) splits: ``lbp_matmul_ragged`` takes a
``LayerAssignment`` with non-uniform {k_i} (from the §4 star solvers); shards
are padded to k_max with zeros, which leaves the partial sums exact.  This is
the execution half of the straggler-mitigation story (runtime/rebalance).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from . import collectives
from .collectives import Mode
from .partition import LayerAssignment


# ---------------------------------------------------------------------------
# reference
# ---------------------------------------------------------------------------

def lbp_matmul_reference(x: jax.Array, w: jax.Array) -> jax.Array:
    """Oracle: plain matmul (sum of all layers)."""
    return jnp.einsum("...k,kf->...f", x, w)


# ---------------------------------------------------------------------------
# even split (the production fast path)
# ---------------------------------------------------------------------------

def lbp_matmul(
    x: jax.Array,
    w: jax.Array,
    mesh: Mesh,
    axis: str = "model",
    mode: Mode = "scatter",
    batch_axis: Optional[str] = None,
) -> jax.Array:
    """k-sharded matmul ``x @ w`` over mesh axis ``axis``.

    x: (..., K) — K sharded over ``axis`` (leading batch dims may be sharded
       over ``batch_axis``); w: (K, F) — K sharded over ``axis``.

    Returns, per ``mode``:
      layers:    (p, ..., F) with the leading device axis sharded over
                 ``axis`` (device i holds layer i) — no collective at all.
      allreduce: (..., F) replicated over ``axis``.
      scatter:   (..., F) with the LAST dim sharded over ``axis``.
    """
    nbatch = x.ndim - 1
    bspec = [None] * nbatch
    if batch_axis is not None:
        bspec[0] = batch_axis
    x_spec = P(*bspec, axis)
    w_spec = P(axis, None)
    out_spec = collectives.out_spec(mode, axis, (*bspec, None))

    def local(xl: jax.Array, wl: jax.Array) -> jax.Array:
        layer = jnp.einsum("...k,kf->...f", xl, wl)  # this device's layer
        return collectives.aggregate(layer, mode, axis)

    fn = shard_map(local, mesh=mesh, in_specs=(x_spec, w_spec),
                   out_specs=out_spec, check_vma=False)
    return fn(x, w)


# ---------------------------------------------------------------------------
# ragged (heterogeneous {k_i}) split
# ---------------------------------------------------------------------------

def pad_ragged(
    x: np.ndarray | jax.Array,
    w: np.ndarray | jax.Array,
    assign: LayerAssignment,
) -> Tuple[jax.Array, jax.Array]:
    """Repack a global (.., K) x and (K, F) w into per-device padded blocks.

    Returns xp: (p, ..., k_max), wp: (p, k_max, F); device i's slice holds
    its k_i rows/cols, zero-padded to k_max (zeros keep partial sums exact).
    """
    k = assign.k
    off = assign.offsets
    p, kmax = assign.p, assign.k_max
    x = jnp.asarray(x)
    w = jnp.asarray(w)
    assert x.shape[-1] == assign.K and w.shape[0] == assign.K

    xp = jnp.zeros((p,) + x.shape[:-1] + (kmax,), x.dtype)
    wp = jnp.zeros((p, kmax) + w.shape[1:], w.dtype)
    for i in range(p):
        ki = int(k[i])
        if ki == 0:
            continue
        sl = (slice(None),) * (x.ndim - 1)
        xp = xp.at[(i,) + sl + (slice(0, ki),)].set(
            jax.lax.slice_in_dim(x, int(off[i]), int(off[i]) + ki, axis=x.ndim - 1))
        wp = wp.at[i, :ki].set(
            jax.lax.slice_in_dim(w, int(off[i]), int(off[i]) + ki, axis=0))
    return xp, wp


def lbp_matmul_ragged(
    xp: jax.Array,
    wp: jax.Array,
    mesh: Mesh,
    axis: str = "model",
    mode: Mode = "allreduce",
) -> jax.Array:
    """Matmul over pre-packed ragged shards (see ``pad_ragged``).

    xp: (p, ..., k_max), wp: (p, k_max, F), leading dim sharded over ``axis``.
    """
    ndim_b = xp.ndim - 2  # batch dims between device dim and k
    bspec = [None] * ndim_b

    x_spec = P(axis, *bspec, None)
    w_spec = P(axis, None, None)
    out_spec = collectives.out_spec(mode, axis, (*bspec, None))

    def local(xl: jax.Array, wl: jax.Array) -> jax.Array:
        # xl: (1, ..., k_max), wl: (1, k_max, F)
        layer = jnp.einsum("...k,kf->...f", xl[0], wl[0])
        return collectives.aggregate(layer, mode, axis)

    fn = shard_map(local, mesh=mesh, in_specs=(x_spec, w_spec),
                   out_specs=out_spec, check_vma=False)
    return fn(xp, wp)


def lbp_matmul_heterogeneous(
    x: jax.Array,
    w: jax.Array,
    assign: LayerAssignment,
    mesh: Mesh,
    axis: str = "model",
    mode: Mode = "allreduce",
) -> jax.Array:
    """Convenience: pack + ragged matmul in one call (demo/tests path)."""
    xp, wp = pad_ragged(x, w, assign)
    return lbp_matmul_ragged(xp, wp, mesh, axis=axis, mode=mode)


# ---------------------------------------------------------------------------
# collective-byte accounting (used by tests and the roofline narrative)
# ---------------------------------------------------------------------------

def collective_bytes_per_device(out_elems: int, p: int, mode: Mode,
                                itemsize: int = 2) -> float:
    """Analytic ICI bytes per device moved by the aggregation collective.

    Delegates to the ``core.collectives`` registry (layers: 0; allreduce
    ring: 2 (p-1)/p x bytes(out); scatter ring: (p-1)/p x bytes(out));
    kept here as a stable re-export for older call sites.
    """
    return collectives.collective_bytes_per_device(out_elems, p, mode,
                                                   itemsize)
