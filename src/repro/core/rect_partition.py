"""Rectangular-partition baselines (paper §6.1.2) and their communication cost.

All algorithms partition the N x N *output* matrix into p pieces with
prescribed areas ``s_i`` (load shares, typically proportional to processor
speed).  A piece covering ``r`` distinct rows and ``c`` distinct columns of
the output needs ``r`` rows of A and ``c`` columns of B, i.e. a volume of
``(r + c) * N`` entries; for a rectangle of (fractional) height h and width
w on the unit square this is the classical ``C_REC = N^2 * sum_i (h_i+w_i)``
(paper eq. before (1)).

Implemented baselines:

  even_col      naive equal-column partition (paper "Even-Col")
  peri_sum      Beaumont et al. [26] column-based partition; the optimal
                *column-based* layout found by an O(p^2) DP over the areas
                sorted in non-increasing order (their 1.75-approximation)
  recursive     Nagamochi-Abe [29] style recursive guillotine bisection
                (1.25-approximation)
  nrrp          Beaumont et al. [30] non-rectangular recursive partition:
                the same recursion but 2-processor leaves may use the
                square-corner (non-rectangular) layout from DeFlumere [28]
  rect_lower_bound   Ballard et al. [25]: C >= 2 * N * sum_i sqrt(s_i)

Everything is computed on the unit square with fractional areas
``f_i = s_i / N^2`` and scaled back: a unit-square (rows+cols) sum ``c``
corresponds to a volume of ``c * N^2`` matrix entries.

LBP's volume is ``2 N^2`` regardless of the split (paper Theorem 1), which
these baselines are compared against in benchmarks/fig6a.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Piece:
    """One processor's share of the output matrix.

    ``cost`` = fraction of rows covered + fraction of columns covered
    (for a rectangle: h + w; for non-rectangular shapes: their coverage).
    ``area`` = fraction of the output owned (=> compute load).
    """

    proc: int
    area: float
    cost: float
    kind: str = "rect"


@dataclasses.dataclass(frozen=True)
class RectPartition:
    pieces: List[Piece]

    def cost_unit(self) -> float:
        """sum_i (rows_i + cols_i) on the unit square."""
        return float(sum(p.cost for p in self.pieces))

    def comm_volume(self, N: int) -> float:
        """Total entries sent = N^2 * unit cost."""
        return self.cost_unit() * float(N) * float(N)

    def areas(self, p: int) -> np.ndarray:
        out = np.zeros(p)
        for pc in self.pieces:
            out[pc.proc] += pc.area
        return out


def _norm_areas(areas: Sequence[float]) -> np.ndarray:
    f = np.asarray(areas, dtype=np.float64)
    assert np.all(f >= 0) and f.sum() > 0
    return f / f.sum()


# ---------------------------------------------------------------------------
# Even-Col
# ---------------------------------------------------------------------------

def even_col(p: int) -> RectPartition:
    """p equal-width full-height columns (ignores heterogeneity)."""
    w = 1.0 / p
    return RectPartition([Piece(i, w, 1.0 + w) for i in range(p)])


# ---------------------------------------------------------------------------
# PERI-SUM: optimal column-based partition via DP (Beaumont et al. 2001)
# ---------------------------------------------------------------------------

def peri_sum(areas: Sequence[float]) -> RectPartition:
    """Optimal *column-based* partition.

    Sort areas in non-increasing order; group them into contiguous columns.
    A column holding areas ``f_a..f_b`` has width ``W = sum f`` and each
    rectangle spans the full column width with height ``f_i / W``.  Column
    cost = (#rects)*W + 1 (heights sum to 1).  DP minimizes the total.
    """
    f = _norm_areas(areas)
    order = np.argsort(-f)
    fs = f[order]
    p = len(fs)
    pref = np.concatenate([[0.0], np.cumsum(fs)])

    INF = float("inf")
    best = np.full(p + 1, INF)
    best[0] = 0.0
    choice = np.zeros(p + 1, dtype=np.int64)
    for i in range(1, p + 1):
        for j in range(i):
            width = pref[i] - pref[j]
            c = best[j] + (i - j) * width + 1.0
            if c < best[i]:
                best[i] = c
                choice[i] = j

    pieces: List[Piece] = []
    i = p
    cols: List[Tuple[int, int]] = []
    while i > 0:
        j = int(choice[i])
        cols.append((j, i))
        i = j
    for (j, i) in cols:
        width = pref[i] - pref[j]
        for t in range(j, i):
            h = fs[t] / width if width > 0 else 0.0
            pieces.append(Piece(int(order[t]), fs[t], width + h))
    return RectPartition(pieces)


# ---------------------------------------------------------------------------
# Recursive guillotine bisection (Nagamochi-Abe style) and NRRP
# ---------------------------------------------------------------------------

def _balanced_split(idx: np.ndarray, f: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Greedy split of the index set into two groups with near-equal area."""
    order = idx[np.argsort(-f[idx])]
    g1: List[int] = []
    g2: List[int] = []
    s1 = s2 = 0.0
    for t in order:
        if s1 <= s2:
            g1.append(int(t))
            s1 += f[t]
        else:
            g2.append(int(t))
            s2 += f[t]
    return np.asarray(g1, dtype=np.int64), np.asarray(g2, dtype=np.int64)


def _recurse(w: float, h: float, idx: np.ndarray, f: np.ndarray,
             out: List[Piece], square_corner: bool) -> None:
    if len(idx) == 1:
        out.append(Piece(int(idx[0]), w * h, w + h))
        return
    if square_corner and len(idx) == 2:
        # DeFlumere square-corner: the smaller share becomes a square in the
        # corner (side a); the other takes the L-shape, which covers all rows
        # and all columns of this sub-rectangle (cost w + h).
        a_idx, b_idx = (idx[0], idx[1]) if f[idx[0]] >= f[idx[1]] else (idx[1], idx[0])
        total = f[idx[0]] + f[idx[1]]
        side = float(np.sqrt((f[b_idx] / total) * w * h))
        if side <= min(w, h):
            if w >= h:
                w1 = w * (f[a_idx] / total)
                guillotine = (h + w1) + (h + (w - w1))
            else:
                h1 = h * (f[a_idx] / total)
                guillotine = (w + h1) + (w + (h - h1))
            corner = 2.0 * side + (w + h)
            if corner < guillotine:
                out.append(Piece(int(b_idx), side * side, 2.0 * side, "square"))
                out.append(Piece(int(a_idx), w * h - side * side, w + h, "L"))
                return
        # fall through to guillotine
    g1, g2 = _balanced_split(idx, f)
    s1, s2 = f[g1].sum(), f[g2].sum()
    r = s1 / (s1 + s2)
    if w >= h:
        _recurse(w * r, h, g1, f, out, square_corner)
        _recurse(w * (1 - r), h, g2, f, out, square_corner)
    else:
        _recurse(w, h * r, g1, f, out, square_corner)
        _recurse(w, h * (1 - r), g2, f, out, square_corner)


def recursive(areas: Sequence[float]) -> RectPartition:
    """Recursive guillotine bisection (all-rectangular leaves)."""
    f = _norm_areas(areas)
    out: List[Piece] = []
    _recurse(1.0, 1.0, np.arange(len(f)), f, out, False)
    return RectPartition(out)


def nrrp(areas: Sequence[float]) -> RectPartition:
    """Recursive partition with non-rectangular (square-corner) 2-proc leaves."""
    f = _norm_areas(areas)
    out: List[Piece] = []
    _recurse(1.0, 1.0, np.arange(len(f)), f, out, True)
    return RectPartition(out)


# ---------------------------------------------------------------------------
# Bounds
# ---------------------------------------------------------------------------

def rect_lower_bound_volume(areas: Sequence[float], N: int) -> float:
    """Ballard et al. [25]: C_REC >= 2 N sum_i sqrt(s_i); s_i = f_i N^2."""
    f = _norm_areas(areas)
    return float(2.0 * N * np.sum(np.sqrt(f * N * N)))


def lbp_volume(N: int) -> float:
    """Paper Theorem 1: LBP always reaches the global lower bound 2 N^2."""
    return 2.0 * float(N) * float(N)


# ---------------------------------------------------------------------------
# Finish time of a partition on a star network (PCCS mode)
# ---------------------------------------------------------------------------

def star_finish_time(partition: RectPartition, net, N: int) -> float:
    """PCCS finish time of a partition on a star network.

    A piece with unit-square coverage ``cost`` and area ``area`` receives
    ``cost * N^2`` entries and performs ``area * N^3`` multiply-accumulates.
    """
    comm = np.zeros(net.p)
    comp = np.zeros(net.p)
    n2 = float(N) * float(N)
    for pc in partition.pieces:
        comm[pc.proc] += pc.cost * n2 * net.z[pc.proc] * net.t_cm
        comp[pc.proc] += pc.area * n2 * float(N) * net.w[pc.proc] * net.t_cp
    return float(np.max(comm + comp))


def speed_proportional_areas(net) -> np.ndarray:
    """Load shares proportional to compute speed 1/w_i (paper §6.1.3:
    'each share of load is proportional to that processor's computing
    ability')."""
    inv = 1.0 / net.w
    return inv / inv.sum()
