"""Overlapped layer-streaming collective-matmul primitives (shard_map plane).

The paper's "simultaneous start" observation — distributing layer j+1 can
overlap multiplying layer j, so finish time is governed by max(comm,
compute) rather than their sum — so far lived only inside the Pallas
kernel (DMA double-buffering across the K grid).  This module lifts it to
the mesh: every blocking collective around a distributed matmul is
replaced by a ring of ``ppermute`` hops sized so one hop's transfer is in
flight while the previous hop's chunk is being multiplied (XLA's
latency-hiding scheduler overlaps them on TPU; the numerics are identical
everywhere).

Fused primitives (called INSIDE a shard_map body):

  streamed_gather_matmul   replaces all-gather(w)->einsum: the weight's
                           shard rotates around the ring and one column
                           block of this device's LBP layer is matmul'd
                           per hop while the next shard is in flight.
                           p-1 ppermutes of bytes(shard) — exactly the
                           ring all-gather's (p-1)/p x bytes(w) per device.
  streamed_scatter_matmul  replaces einsum->psum_scatter: the local
                           product is computed one output tile per hop,
                           each tile accumulated into the partial sum
                           arriving from the ring neighbour and forwarded
                           (accumulate-and-forward).  p-1 ppermutes of
                           bytes(out)/p — exactly reduce-scatter's
                           (p-1)/p x bytes(out) per device.

Aggregation-registry modes (drop-in for "allreduce"/"scatter" anywhere the
``core.collectives`` registry is consumed — ``lbp_matmul``, ragged shards,
``models/lbp_linear`` — with the same exact byte accounting):

  "stream_scatter"       ring reduce-scatter by accumulate-and-forward
                         tiles; output sharded like "scatter" mode,
                         (p-1)/p x bytes(out) per device.
  "stream_gather"        replicated result like "allreduce", decomposed
                         into the tile ring reduce-scatter followed by a
                         tile ring all-gather: 2(p-1) ppermutes moving
                         2(p-1)/p x bytes(out) per device — the all-reduce
                         ring unrolled so every hop can interleave with
                         compute.
  "stream_hierarchical"  two-level variant: tile ring reduce-scatter
                         within the pod (ICI), all-reduce of the 1/m shard
                         across pods (the DCN trunk hop), tile ring
                         all-gather within the pod.  Byte model identical
                         to "hierarchical".  axis=(pod_axis, inner_axis).
  "stream_scatter_bidir" the reduce-scatter ring split into two half-rings
                         permuting in opposite directions: contributions
                         behind this device ride the forward ring
                         (ceil((p-1)/2) hops), those ahead ride the
                         backward ring (floor((p-1)/2) hops), and the two
                         partial accumulations meet at the owner.  Total
                         ppermutes stay p-1 — byte-exact with "scatter" —
                         but the longest dependent chain is halved, so a
                         duplex link drains in ceil((p-1)/2) hop times.
  "stream_gather_bidir"  replicated result via the bidirectional RS ring
                         followed by a bidirectional AG ring: 2(p-1)
                         ppermutes (bytes == allreduce), sequential depth
                         2*ceil((p-1)/2).

Streaming requires the tiled dim to divide evenly by the axis size (the
same constraint ``psum_scatter(tiled=True)`` imposes); a clear error is
raised otherwise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import collectives
from .collectives import AggregationMode, _axis_size, _scatter_spec


def _ring_perm(p: int) -> list:
    """Forward ring: device i sends to i+1 (chunk held by i at step s was
    originally chunk (i - s) mod p)."""
    return [(i, (i + 1) % p) for i in range(p)]


def _rev_perm(p: int) -> list:
    """Backward ring: device i sends to i-1 (chunk held by i at step s was
    originally chunk (i + s) mod p)."""
    return [(i, (i - 1) % p) for i in range(p)]


def bidir_hops(p: int) -> tuple:
    """(forward, backward) hop counts of one bidirectional half-ring pass:
    ceil((p-1)/2) forward + floor((p-1)/2) backward == p-1 total."""
    hf = p // 2
    return hf, (p - 1) - hf


def _chunk_size(dim: int, p: int, what: str) -> int:
    if dim % p != 0:
        raise ValueError(
            f"layer streaming needs the {what} dim ({dim}) divisible by the "
            f"ring size ({p}) — same constraint as psum_scatter(tiled=True)")
    return dim // p


def _rs_ring(tile, axis: str, p: int) -> jax.Array:
    """Accumulate-and-forward reduce-scatter ring: ``tile(c)`` produces
    this device's contribution to chunk c (a matmul or a slice — computed
    per hop so it can interleave with the in-flight ppermute).  After p-1
    hops device i holds the fully-reduced tile i."""
    idx = jax.lax.axis_index(axis)
    perm = _ring_perm(p)
    acc = tile(jnp.mod(idx - 1, p))
    for s in range(1, p):
        acc = jax.lax.ppermute(acc, axis, perm)
        acc = acc + tile(jnp.mod(idx - 1 - s, p))
    return acc


def _ag_ring(buf: jax.Array, block, out: jax.Array, cs: int, sd: int,
             axis: str, p: int) -> jax.Array:
    """All-gather ring: ``buf`` rotates p-1 hops; each hop ``block(buf)``
    is computed (identity, or a matmul against the resident operand) and
    placed at its original owner's offset along ``sd``."""
    idx = jax.lax.axis_index(axis)
    perm = _ring_perm(p)
    for s in range(p):
        c = jnp.mod(idx - s, p)              # original owner of buf
        out = jax.lax.dynamic_update_slice_in_dim(out, block(buf), c * cs,
                                                  axis=sd)
        if s < p - 1:
            buf = jax.lax.ppermute(buf, axis, perm)
    return out


def _rs_ring_bidir(tile, axis: str, p: int) -> jax.Array:
    """Bidirectional accumulate-and-forward reduce-scatter.

    Chunk i's contributions from devices i-hf..i-1 ride the forward ring
    (hf = ceil((p-1)/2) hops), those from i+1..i+hb ride the backward ring
    (hb = floor((p-1)/2) hops), and the owner adds its own contribution
    locally.  hf + hb = p - 1 so every device contributes exactly once and
    the total ppermute count (and bytes) match the unidirectional ring,
    but the two chains are independent — XLA can keep both link directions
    busy, halving the sequential hop depth."""
    idx = jax.lax.axis_index(axis)
    hf, hb = bidir_hops(p)
    # forward chain: start hf behind the destination, accumulate towards it
    acc_f = tile(jnp.mod(idx + hf, p))
    for s in range(1, hf + 1):
        acc_f = jax.lax.ppermute(acc_f, axis, _ring_perm(p))
        if s < hf:
            acc_f = acc_f + tile(jnp.mod(idx + hf - s, p))
    out = acc_f + tile(idx)                  # owner's own contribution
    if hb > 0:
        acc_b = tile(jnp.mod(idx - hb, p))
        for s in range(1, hb + 1):
            acc_b = jax.lax.ppermute(acc_b, axis, _rev_perm(p))
            if s < hb:
                acc_b = acc_b + tile(jnp.mod(idx - hb + s, p))
        out = out + acc_b
    return out


def _ag_ring_bidir(buf: jax.Array, block, out: jax.Array, cs: int, sd: int,
                   axis: str, p: int) -> jax.Array:
    """Bidirectional all-gather: two copies of ``buf`` rotate in opposite
    directions; the forward copy delivers the hf tiles behind this device,
    the backward copy the hb tiles ahead, the own tile is placed locally.
    p-1 ppermutes total (bytes == unidirectional ring), depth halved."""
    idx = jax.lax.axis_index(axis)
    hf, hb = bidir_hops(p)
    out = jax.lax.dynamic_update_slice_in_dim(out, block(buf), idx * cs,
                                              axis=sd)
    fwd = bwd = buf
    for s in range(1, hf + 1):
        fwd = jax.lax.ppermute(fwd, axis, _ring_perm(p))
        c = jnp.mod(idx - s, p)              # original owner of fwd
        out = jax.lax.dynamic_update_slice_in_dim(out, block(fwd), c * cs,
                                                  axis=sd)
    for s in range(1, hb + 1):
        bwd = jax.lax.ppermute(bwd, axis, _rev_perm(p))
        c = jnp.mod(idx + s, p)              # original owner of bwd
        out = jax.lax.dynamic_update_slice_in_dim(out, block(bwd), c * cs,
                                                  axis=sd)
    return out


# ---------------------------------------------------------------------------
# fused primitives (matmul interleaved with the ring)
# ---------------------------------------------------------------------------

def streamed_gather_matmul(hl: jax.Array, wl: jax.Array, axis: str
                           ) -> jax.Array:
    """hl @ all_gather(wl over ``axis``, dim 1) without the all-gather.

    hl: (..., k) local activations; wl: (k, d/p) this device's shard of a
    (k, d) weight whose dim 1 is sharded over ``axis``.  The weight shard
    rotates around the ring; each hop multiplies one column block of this
    device's layer while the next shard is in flight.  Returns (..., d).
    """
    assert isinstance(axis, str), "streaming rings run over a single axis"
    p = _axis_size(axis)
    if p == 1:
        return jnp.einsum("...k,kd->...d", hl, wl)
    d_local = wl.shape[1]
    out = jnp.zeros(hl.shape[:-1] + (p * d_local,),
                    jnp.result_type(hl.dtype, wl.dtype))
    return _ag_ring(wl, lambda w: jnp.einsum("...k,kd->...d", hl, w),
                    out, d_local, out.ndim - 1, axis, p)


def streamed_scatter_matmul(hl: jax.Array, wl: jax.Array, axis: str, *,
                            scatter_dim: int) -> jax.Array:
    """psum_scatter(hl @ wl over ``axis``) without the reduce-scatter.

    hl: (..., k) with k sharded over ``axis``; wl: (k, d).  The product's
    ``scatter_dim`` is split into p tiles; tile matmuls are interleaved
    with accumulate-and-forward ppermute hops so the tile for hop s+1 is
    computed while hop s's partial sum is in flight.  Returns this
    device's fully-reduced tile (== psum_scatter(..., tiled=True)).
    """
    assert isinstance(axis, str), "streaming rings run over a single axis"
    p = _axis_size(axis)
    if p == 1:
        return jnp.einsum("...k,kd->...d", hl, wl)
    out_ndim = hl.ndim - 1 + 1
    if scatter_dim < 0:
        scatter_dim += out_ndim

    if scatter_dim == out_ndim - 1:          # tile the weight's columns
        cs = _chunk_size(wl.shape[1], p, "scattered output")

        def tile(c):
            wc = jax.lax.dynamic_slice_in_dim(wl, c * cs, cs, axis=1)
            return jnp.einsum("...k,kd->...d", hl, wc)
    else:                                    # tile a batch dim of hl
        cs = _chunk_size(hl.shape[scatter_dim], p, "scattered output")

        def tile(c):
            hc = jax.lax.dynamic_slice_in_dim(hl, c * cs, cs,
                                              axis=scatter_dim)
            return jnp.einsum("...k,kd->...d", hc, wl)

    return _rs_ring(tile, axis, p)           # device i holds tile i


# ---------------------------------------------------------------------------
# streaming rings over an already-computed partial (registry combines)
# ---------------------------------------------------------------------------

def ring_reduce_scatter(partial: jax.Array, axis: str, sd: int) -> jax.Array:
    """Accumulate-and-forward tile ring == psum_scatter(tiled=True):
    p-1 ppermutes of bytes(out)/p per device."""
    p = _axis_size(axis)
    if p == 1:
        return partial
    cs = _chunk_size(partial.shape[sd], p, "scattered output")
    return _rs_ring(
        lambda c: jax.lax.dynamic_slice_in_dim(partial, c * cs, cs, axis=sd),
        axis, p)


def ring_all_gather(tile: jax.Array, axis: str, sd: int) -> jax.Array:
    """Forward each owned tile p-1 hops == all_gather(tiled=True):
    p-1 ppermutes of bytes(tile) per device."""
    p = _axis_size(axis)
    if p == 1:
        return tile
    cs = tile.shape[sd]
    shape = tile.shape[:sd] + (p * cs,) + tile.shape[sd + 1:]
    out = jnp.zeros(shape, tile.dtype)
    return _ag_ring(tile, lambda b: b, out, cs, sd, axis, p)


def ring_reduce_scatter_bidir(partial: jax.Array, axis: str, sd: int
                              ) -> jax.Array:
    """Bidirectional tile ring == psum_scatter(tiled=True): still p-1
    ppermutes of bytes(out)/p per device, but split ceil((p-1)/2) forward /
    floor((p-1)/2) backward so the dependent chain is halved."""
    p = _axis_size(axis)
    if p == 1:
        return partial
    cs = _chunk_size(partial.shape[sd], p, "scattered output")
    return _rs_ring_bidir(
        lambda c: jax.lax.dynamic_slice_in_dim(partial, c * cs, cs, axis=sd),
        axis, p)


def ring_all_gather_bidir(tile: jax.Array, axis: str, sd: int) -> jax.Array:
    """Bidirectional all-gather == all_gather(tiled=True): p-1 ppermutes of
    bytes(tile) per device split over the two ring directions."""
    p = _axis_size(axis)
    if p == 1:
        return tile
    cs = tile.shape[sd]
    shape = tile.shape[:sd] + (p * cs,) + tile.shape[sd + 1:]
    out = jnp.zeros(shape, tile.dtype)
    return _ag_ring_bidir(tile, lambda b: b, out, cs, sd, axis, p)


def _stream_gather_combine(partial: jax.Array, axis: str, sd: int
                           ) -> jax.Array:
    """Replicated result via RS-ring + AG-ring (the all-reduce ring
    unrolled into 2(p-1) interleavable hops)."""
    tile = ring_reduce_scatter(partial, axis, sd)
    return ring_all_gather(tile, axis, sd)


def _stream_gather_bidir_combine(partial: jax.Array, axis: str, sd: int
                                 ) -> jax.Array:
    """Replicated result via bidirectional RS-ring + bidirectional AG-ring:
    2(p-1) ppermutes (bytes == allreduce), depth 2*ceil((p-1)/2)."""
    tile = ring_reduce_scatter_bidir(partial, axis, sd)
    return ring_all_gather_bidir(tile, axis, sd)


def _stream_hier_combine(partial: jax.Array, axis, sd: int) -> jax.Array:
    """Two-level streaming: tile RS-ring in pod (ICI), shard all-reduce
    across pods (DCN trunk), tile AG-ring in pod (ICI).  Numerically
    identical to the "hierarchical" mode; the in-pod hops are ppermutes so
    they can interleave with compute."""
    if not isinstance(axis, (tuple, list)) or len(axis) != 2:
        raise ValueError(
            "stream_hierarchical aggregation needs axis=(pod_axis, "
            f"inner_axis), got {axis!r}")
    pod_axis, inner = axis
    shard = ring_reduce_scatter(partial, inner, sd)
    shard = jax.lax.psum(shard, pod_axis)    # DCN: V/m per device
    return ring_all_gather(shard, inner, sd)


# ---------------------------------------------------------------------------
# registry entries — byte models exactly match the blocking counterparts
# ---------------------------------------------------------------------------

collectives.register_mode(AggregationMode(
    name="stream_scatter",
    combine=ring_reduce_scatter,
    out_spec=_scatter_spec,
    link_byte_factor=lambda p: 1.0 * (p - 1) / p,   # == "scatter"
    description="streamed reduce-scatter: accumulate-and-forward tile ring "
                "(p-1 ppermutes of out/p; bytes == scatter)",
))

collectives.register_mode(AggregationMode(
    name="stream_gather",
    combine=_stream_gather_combine,
    out_spec=lambda axis, base, _sd: collectives.P(*base),
    link_byte_factor=lambda p: 2.0 * (p - 1) / p,   # == "allreduce"
    description="streamed replicated aggregation: RS-ring + AG-ring "
                "(2(p-1) ppermutes of out/p; bytes == allreduce)",
))

collectives.register_mode(AggregationMode(
    name="stream_scatter_bidir",
    combine=ring_reduce_scatter_bidir,
    out_spec=_scatter_spec,
    link_byte_factor=lambda p: 1.0 * (p - 1) / p,   # == "scatter"
    description="bidirectional streamed reduce-scatter: two opposing "
                "half-rings, ceil((p-1)/2) hops deep (bytes == scatter)",
))

collectives.register_mode(AggregationMode(
    name="stream_gather_bidir",
    combine=_stream_gather_bidir_combine,
    out_spec=lambda axis, base, _sd: collectives.P(*base),
    link_byte_factor=lambda p: 2.0 * (p - 1) / p,   # == "allreduce"
    description="bidirectional streamed replicated aggregation: bidir "
                "RS-ring + bidir AG-ring (bytes == allreduce)",
))

collectives.register_mode(AggregationMode(
    name="stream_hierarchical",
    combine=_stream_hier_combine,
    out_spec=lambda axis, base, _sd: collectives.P(*base),
    link_byte_factor=collectives.get_mode("hierarchical").link_byte_factor,
    description="two-level streaming: tile RS-ring in pod (ICI), shard "
                "all-reduce across pods (DCN), tile AG-ring in pod "
                "(bytes == hierarchical)",
))


def expected_ppermutes(mode: str, p: int, fsdp_ring: int = 1) -> int:
    """Number of collective-permute ops the lowered HLO of one streamed
    matmul carries: the p-1 (or 2(p-1)) aggregation hops plus the m-1
    weight-shard hops when the FSDP gather is streamed too.  The
    structural check ``benchmarks/overlap.py`` asserts against this."""
    agg = {"stream_scatter": p - 1,
           "stream_scatter_bidir": p - 1,
           "stream_gather": 2 * (p - 1),
           "stream_gather_bidir": 2 * (p - 1),
           "stream_hierarchical": 2 * (p - 1)}[mode]
    return agg + max(0, fsdp_ring - 1)


def expected_direction_counts(mode: str, p: int) -> tuple:
    """(forward, backward) ppermute counts of one bidirectional aggregation
    — the per-direction structural metric ``check_regression.py`` gates:
    forward count is ceil((p-1)/2) per ring pass (halved vs the p-1 of the
    unidirectional modes)."""
    hf, hb = bidir_hops(p)
    try:
        return {"stream_scatter_bidir": (hf, hb),
                "stream_gather_bidir": (2 * hf, 2 * hb)}[mode]
    except KeyError:
        raise ValueError(f"{mode!r} is not a bidirectional streaming mode")


def sequential_hop_depth(mode: str, p: int) -> int:
    """Longest dependent ppermute chain of one aggregation — the latency
    model the bidirectional split improves: p-1 -> ceil((p-1)/2) per ring
    pass (total bytes unchanged)."""
    hf, _ = bidir_hops(p)
    return {"stream_scatter": p - 1,
            "stream_gather": 2 * (p - 1),
            "stream_hierarchical": 2 * (p - 1),
            "stream_scatter_bidir": hf,
            "stream_gather_bidir": 2 * hf}[mode]
