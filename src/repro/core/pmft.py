"""PMFT-LBP (paper Algorithm 1) and FIFS (Algorithm 2).

Three phases:
  I.   solve the LP relaxation (mesh_lp.solve_relaxed);
  II.  FIFS: round k to integers, then repair sum(k)=N one unit at a time,
       re-solving the fixed-k LP after every move to refresh T_f(i);
  III. neighbor search: move one unit from the max-T_f node to the min-T_f
       node; accept while the makespan improves.

``quantum`` generalizes the unit move to 128-aligned moves for the TPU
scheduler plane (DESIGN.md §2); quantum=1 is the paper.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .mesh_lp import LPResult, solve_fixed_k, solve_fixed_k_normalized, solve_relaxed
from .network import MeshNetwork


@dataclasses.dataclass
class MeshSchedule:
    k: np.ndarray            # (p,) integer layer counts
    result: LPResult         # fixed-k LP at the final schedule
    lp_solves: int           # number of LP solves
    simplex_iters: int       # total simplex iterations (paper Fig. 9 metric)
    k_relaxed: np.ndarray | None = None  # phase-I LP optimum (provenance)

    @property
    def t_finish(self) -> float:
        return self.result.t_finish

    @property
    def comm_volume(self) -> float:
        return self.result.comm_volume


def _storage_cap(net: MeshNetwork, N: int, i: int) -> float:
    if net.storage is None:
        return np.inf
    return max(0.0, (net.storage[i] - float(N) ** 2) / (2.0 * N))


def _eligible_receivers(net: MeshNetwork, N: int, k: np.ndarray, q: int) -> np.ndarray:
    """Non-source nodes that can take q more layers without violating (59)."""
    ok = np.ones(net.p, dtype=bool)
    ok[net.source] = False
    for i in range(net.p):
        if ok[i] and k[i] + q > _storage_cap(net, N, i):
            ok[i] = False
    return ok


def fifs(net: MeshNetwork, N: int, relaxed: LPResult, quantum: int = 1):
    """Algorithm 2: find an integer feasible solution near the LP optimum.

    Returns (k_int, last_fixed_lp, lp_solves, simplex_iters).
    """
    q = quantum
    k = np.rint(relaxed.k / q) * q
    k = np.maximum(k, 0.0)
    k[net.source] = 0.0

    solves, iters = 0, 0
    res = None
    guard = 0
    while k.sum() != N and guard < 4 * net.p + int(2 * N / q) + 8:
        guard += 1
        res = solve_fixed_k_normalized(net, N, k)  # refresh T_f(i) (paper: every iteration)
        solves += 1
        iters += res.nit
        tf = res.t_finish_nodes
        if k.sum() > N:
            loaded = (k > 0)
            loaded[net.source] = False
            i = int(np.argmax(np.where(loaded, tf, -np.inf)))
            k[i] -= q
        else:
            ok = _eligible_receivers(net, N, k, q)
            i = int(np.argmin(np.where(ok, tf, np.inf)))
            k[i] += q
    assert k.sum() == N, "FIFS failed to reach sum(k)=N"
    if res is None or True:  # always evaluate the final schedule
        res = solve_fixed_k(net, N, k)
        solves += 1
        iters += res.nit
    return k.astype(np.int64), res, solves, iters


def pmft_lbp(net: MeshNetwork, N: int, quantum: int = 1,
             max_moves: int = 200, full_search: bool = False) -> MeshSchedule:
    """Algorithm 1.  ``full_search=True`` explores the whole O(p^2) neighborhood
    (the §5.3 prose); False follows Algorithm 1's max->min single neighbor,
    which is also what §5.4 calls the gradient-descent move.
    """
    q = quantum
    relaxed = solve_relaxed(net, N)
    solves, iters = 1, relaxed.nit

    k, cur, s2, i2 = fifs(net, N, relaxed, quantum=q)
    solves += s2
    iters += i2

    for _ in range(max_moves):
        tf = cur.t_finish_nodes
        loaded = (k > 0)
        loaded[net.source] = False
        if not loaded.any():
            break
        if full_search:
            best = None
            order_a = np.argsort(-np.where(loaded, tf, -np.inf))[:4]
            ok = _eligible_receivers(net, N, k, q)
            order_b = np.argsort(np.where(ok, tf, np.inf))[:4]
            for a in order_a:
                for b in order_b:
                    if a == b or k[a] < q or not ok[b]:
                        continue
                    kk = k.copy()
                    kk[a] -= q
                    kk[b] += q
                    r = solve_fixed_k(net, N, kk)
                    solves += 1
                    iters += r.nit
                    if best is None or r.t_finish < best[2].t_finish:
                        best = (a, b, r, kk)
            if best is None or best[2].t_finish >= cur.t_finish:
                break
            k, cur = best[3], best[2]
        else:
            a = int(np.argmax(np.where(loaded, tf, -np.inf)))
            ok = _eligible_receivers(net, N, k, q)
            ok[a] = False
            if not ok.any():
                break
            b = int(np.argmin(np.where(ok, tf, np.inf)))
            kk = k.copy()
            kk[a] -= q
            kk[b] += q
            r = solve_fixed_k(net, N, kk)
            solves += 1
            iters += r.nit
            if r.t_finish >= cur.t_finish:   # Algorithm 1 line 18: break
                break
            k, cur = kk, r

    return MeshSchedule(k=k, result=cur, lp_solves=solves, simplex_iters=iters,
                        k_relaxed=relaxed.k)
