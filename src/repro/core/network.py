"""Network models for the paper's scheduling problems.

The paper (§4, §6.1) evaluates LBP on a heterogeneous *star* network (one
non-computing source, p children) and (§5, §6.2) on a heterogeneous *mesh*
(X x Y grid, source at the quadrant corner, edges directed away from the
source).  Unit costs follow the paper's conventions:

  - ``w[i]``  : inverse computing speed of processor i  (unit load -> w_i*Tcp s)
  - ``z[i]``  : inverse link speed of link i            (unit load -> z_i*Tcm s)
  - ``Tcp``   : computing intensity constant
  - ``Tcm``   : communication intensity constant

For an N x N x N matmul, processor i holding ``k_i`` layers:
  comm volume = 2*k_i*N   (k_i columns of A + k_i rows of B)
  compute     = k_i*N^2 multiplications -> k_i*N^2*w_i*Tcp seconds
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import numpy as np

# Paper §6.1/§6.2 simulation parameter ranges.
W_TCP_RANGE = (0.0005, 0.0008)  # unit processing time w*Tcp
Z_TCM_RANGE = (0.0002, 0.0005)  # unit transmission time z*Tcm


@dataclasses.dataclass(frozen=True)
class StarNetwork:
    """One source + p children. Source only transmits (never computes)."""

    w: np.ndarray  # (p,) inverse compute speed of each child
    z: np.ndarray  # (p,) inverse link speed source->child i
    t_cp: float = 1.0
    t_cm: float = 1.0

    @property
    def p(self) -> int:
        return int(self.w.shape[0])

    def validate(self) -> None:
        assert self.w.shape == self.z.shape
        assert np.all(self.w > 0) and np.all(self.z > 0)


def random_star(p: int, seed: int, t_cp: float = 1.0, t_cm: float = 1.0) -> StarNetwork:
    """Random heterogeneous star per paper §6.1 (16 children by default)."""
    rng = np.random.default_rng(seed)
    w = rng.uniform(*W_TCP_RANGE, size=p) / t_cp
    z = rng.uniform(*Z_TCM_RANGE, size=p) / t_cm
    return StarNetwork(w=w, z=z, t_cp=t_cp, t_cm=t_cm)


@dataclasses.dataclass(frozen=True)
class MeshNetwork:
    """X x Y grid; node (0,0) is the source (paper §6.2: lower-right quadrant
    with the source at the top-left corner).  Edges are directed away from
    the source: right (+x) and down (+y);  tau(i,j)=1 for those pairs.

    Node ids are row-major: id = y * X + x.
    """

    X: int
    Y: int
    w: np.ndarray          # (p,) inverse compute speed; w[source] unused
    z: Dict[Tuple[int, int], float]  # directed edge (i,j) -> inverse link speed
    t_cp: float = 1.0
    t_cm: float = 1.0
    source: int = 0
    storage: np.ndarray | None = None  # (p,) D_i, optional

    @property
    def p(self) -> int:
        return self.X * self.Y

    def node_id(self, x: int, y: int) -> int:
        return y * self.X + x

    def coords(self, i: int) -> Tuple[int, int]:
        return i % self.X, i // self.X

    @functools.cached_property
    def _adjacency(self) -> Tuple[Tuple[Tuple[Tuple[int, int], ...], ...],
                                  Tuple[Tuple[Tuple[int, int], ...], ...]]:
        """(in_edges, out_edges) per node, built once.  The LP builder asks
        for the neighbourhood of every node; re-sorting and scanning the
        full edge dict per call made it O(p*E) — this is O(E log E) total.
        (cached_property writes the instance __dict__ directly, which a
        frozen dataclass permits.)"""
        ins: List[List[Tuple[int, int]]] = [[] for _ in range(self.p)]
        outs: List[List[Tuple[int, int]]] = [[] for _ in range(self.p)]
        for e in sorted(self.z.keys()):
            outs[e[0]].append(e)
            ins[e[1]].append(e)
        return (tuple(tuple(x) for x in ins), tuple(tuple(x) for x in outs))

    @functools.cached_property
    def _sorted_edges(self) -> List[Tuple[int, int]]:
        return sorted(self.z.keys())

    def edges(self) -> List[Tuple[int, int]]:
        """Directed edges (i -> j), flowing away from the source corner."""
        return list(self._sorted_edges)

    def in_edges(self, j: int) -> List[Tuple[int, int]]:
        return list(self._adjacency[0][j])

    def out_edges(self, i: int) -> List[Tuple[int, int]]:
        return list(self._adjacency[1][i])

    def validate(self) -> None:
        assert self.w.shape[0] == self.p
        for (i, j), zz in self.z.items():
            xi, yi = self.coords(i)
            xj, yj = self.coords(j)
            assert (xj - xi, yj - yi) in ((1, 0), (0, 1)), "edges flow right/down"
            assert zz > 0


def random_mesh(X: int, Y: int, seed: int, t_cp: float = 1.0, t_cm: float = 1.0,
                storage: float | None = None) -> MeshNetwork:
    """Random heterogeneous mesh per paper §6.2.

    Source at (0,0); every right/down link gets an independent z.
    """
    rng = np.random.default_rng(seed)
    p = X * Y
    w = rng.uniform(*W_TCP_RANGE, size=p) / t_cp
    z: Dict[Tuple[int, int], float] = {}
    for y in range(Y):
        for x in range(X):
            i = y * X + x
            if x + 1 < X:
                z[(i, i + 1)] = float(rng.uniform(*Z_TCM_RANGE)) / t_cm
            if y + 1 < Y:
                z[(i, i + X)] = float(rng.uniform(*Z_TCM_RANGE)) / t_cm
    st = None
    if storage is not None:
        st = np.full(p, storage)
    return MeshNetwork(X=X, Y=Y, w=w, z=z, t_cp=t_cp, t_cm=t_cm, storage=st)


@dataclasses.dataclass(frozen=True)
class SpeedProfile:
    """Measured per-device effective speeds for the TPU runtime plane.

    ``relative_speed[i]`` ~ 1.0 nominal; a straggler at 0.5 computes half as
    fast.  Converted to the paper's ``w`` (inverse speed) for the solvers.

    NOTE: production planning goes through ``repro.plan`` — use
    ``repro.plan.StarTopology.from_speeds`` (same lowering) so the split
    comes back as a full ``PartitionPlan``; this class remains the paper's
    §6 measurement-to-model shim.
    """

    relative_speed: np.ndarray

    def to_star(self, link_cost: float = 1e-9) -> StarNetwork:
        # Near-zero z: inside a pod the solver should balance compute only
        # (PCSS limit); link heterogeneity is modeled when provided.
        w = 1.0 / np.asarray(self.relative_speed, dtype=np.float64)
        z = np.full_like(w, link_cost)
        return StarNetwork(w=w, z=z)
