"""Closed-form LBP load-balancing solvers for single-neighbor (star) networks.

Paper §4: all processors must finish at the same time (Theorem 2, from
Bharadwaj et al.'s divisible-load monograph).  Four communication modes:

  SCSS  Sequential Communication, Simultaneous Start   (eqs 5-12)
  SCCS  Sequential Communication, Consecutive Start    (eqs 13-20)
  PCCS  Parallel Communication,  Consecutive Start     (eqs 21-28)
  PCSS  Parallel Communication,  Simultaneous Start    (eqs 29-33)

plus the beyond-paper "overlap" mode backing the layer-streaming execution
plane (``core/overlap.py``): PCSS's simultaneous start priced honestly as
``T_f(i) = max(comm_i, comp_i)`` instead of assuming comm is always hidden.

Each solver returns the real-valued optimal split ``k`` (k_i >= 0, sum = N)
and the overall finishing time T_f.  Integer rounding lives in
``integer_adjust.py`` (§4.5).

Degenerate handling: in SCSS the recurrence factor
``(N w_{j-1} Tcp - 2 z_{j-1} Tcm) / (N w_j Tcp)`` can be <= 0 when a link is
so slow that transmitting processor j-1's share takes longer than computing
it; then processors j..p receive no load (k=0).  The paper implicitly
assumes the positive regime; we guard it explicitly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np

from .network import StarNetwork

Mode = str  # "SCSS" | "SCCS" | "PCCS" | "PCSS"


@dataclasses.dataclass(frozen=True)
class StarSchedule:
    mode: Mode
    k: np.ndarray          # (p,) real-valued layer counts, sum = N
    finish_time: float     # T_f
    comm_volume: float     # total source->children volume = 2 * N * sum(k) = 2N^2


def _cumprod_ratios(ratios: np.ndarray) -> np.ndarray:
    """[1, r_2, r_2*r_3, ...] with clamping at the first non-positive ratio."""
    p = ratios.shape[0] + 1
    out = np.ones(p)
    for i in range(1, p):
        r = ratios[i - 1]
        out[i] = out[i - 1] * r if r > 0 else 0.0
        if out[i] <= 0:
            out[i:] = 0.0
            break
    return out


def solve_scss(net: StarNetwork, N: int) -> StarSchedule:
    """Eqs (10)-(12): k_i = k_1 * prod_{j=2..i} (N w_{j-1} Tcp - 2 z_{j-1} Tcm)/(N w_j Tcp)."""
    w, z, tcp, tcm = net.w, net.z, net.t_cp, net.t_cm
    num = N * w[:-1] * tcp - 2.0 * z[:-1] * tcm
    den = N * w[1:] * tcp
    coef = _cumprod_ratios(num / den)
    k1 = N / coef.sum()
    k = coef * k1
    tf = float(k[0] * N * N * w[0] * tcp)  # eq (12)
    return StarSchedule("SCSS", k, tf, 2.0 * N * float(k.sum()))


def solve_sccs(net: StarNetwork, N: int) -> StarSchedule:
    """Eqs (18)-(20): k_i = k_1 * prod_{j=2..i} (N w_{j-1} Tcp)/(N w_j Tcp + 2 z_j Tcm)."""
    w, z, tcp, tcm = net.w, net.z, net.t_cp, net.t_cm
    num = N * w[:-1] * tcp
    den = N * w[1:] * tcp + 2.0 * z[1:] * tcm
    coef = _cumprod_ratios(num / den)
    k1 = N / coef.sum()
    k = coef * k1
    tf = float(k[0] * N * N * w[0] * tcp + 2.0 * k[0] * N * z[0] * tcm)  # eq (20)
    return StarSchedule("SCCS", k, tf, 2.0 * N * float(k.sum()))


def solve_pccs(net: StarNetwork, N: int) -> StarSchedule:
    """Eqs (26)-(28): k_i proportional to 1/(N w_i Tcp + 2 z_i Tcm)."""
    w, z, tcp, tcm = net.w, net.z, net.t_cp, net.t_cm
    cost = N * w * tcp + 2.0 * z * tcm       # per-unit-k finishing cost
    coef = cost[0] / cost                    # == prod form of eq (26)
    k1 = N / coef.sum()
    k = coef * k1
    tf = float(k[0] * N * N * w[0] * tcp + 2.0 * k[0] * N * z[0] * tcm)  # eq (28)
    return StarSchedule("PCCS", k, tf, 2.0 * N * float(k.sum()))


def solve_pcss(net: StarNetwork, N: int) -> StarSchedule:
    """Eqs (31)-(33): k_i proportional to 1/w_i (pure compute balance)."""
    w, tcp = net.w, net.t_cp
    coef = w[0] / w
    k1 = N / coef.sum()
    k = coef * k1
    tf = float(k[0] * N * N * w[0] * tcp)  # eq (33)
    return StarSchedule("PCSS", k, tf, 2.0 * N * float(k.sum()))


def solve_overlap(net: StarNetwork, N: int) -> StarSchedule:
    """Beyond-paper: PCSS's simultaneous start with honest comm pricing.

    PCSS assumes the streamed distribution is always hidden behind compute
    (T_f(i) = comp_i).  On the overlapped execution plane the true bound is
    ``max(comm_i, comp_i)`` — a slow link cannot be hidden by fast compute.
    Both terms are linear in k_i, so equal finish gives the closed form
    k_i proportional to 1 / max(N w_i Tcp, 2 z_i Tcm).
    """
    w, z, tcp, tcm = net.w, net.z, net.t_cp, net.t_cm
    cost = np.maximum(N * w * tcp, 2.0 * z * tcm)   # per-unit-k bound
    coef = cost[0] / cost
    k1 = N / coef.sum()
    k = coef * k1
    tf = float(k[0] * N * cost[0])
    return StarSchedule("overlap", k, tf, 2.0 * N * float(k.sum()))


SOLVERS: Dict[Mode, Callable[[StarNetwork, int], StarSchedule]] = {
    "SCSS": solve_scss,
    "SCCS": solve_sccs,
    "PCCS": solve_pccs,
    "PCSS": solve_pcss,
    "overlap": solve_overlap,
}


def solve(net: StarNetwork, N: int, mode: Mode = "PCCS") -> StarSchedule:
    return SOLVERS[mode](net, N)


def finish_time_for_split(net: StarNetwork, N: int, k: np.ndarray, mode: Mode) -> float:
    """Simulate T_f for an *arbitrary* (e.g. integer-rounded) split.

    Mirrors the timing diagrams of Figs 3-4.  Used by §4.5 integer
    adjustment and by the benchmarks to evaluate rounded schedules.
    """
    w, z, tcp, tcm = net.w, net.z, net.t_cp, net.t_cm
    k = np.asarray(k, dtype=np.float64)
    comp = k * N * N * w * tcp          # compute duration per processor
    comm = 2.0 * k * N * z * tcm        # transmission duration per processor
    if mode == "PCSS":
        # all links start at t=0, compute overlaps communication
        return float(np.max(comp))
    if mode == "overlap":
        # simultaneous start, honestly priced: max(comm, compute) per node
        return float(np.max(np.maximum(comm, comp)))
    if mode == "PCCS":
        return float(np.max(comm + comp))
    if mode == "SCSS":
        # source sends sequentially; processor i computes while receiving,
        # so P_i starts at the end of transmissions 1..i-1.
        start = np.concatenate([[0.0], np.cumsum(comm)[:-1]])
        return float(np.max(start + comp))
    if mode == "SCCS":
        # sequential sends; P_i starts after *its own* transmission completes.
        end_comm = np.cumsum(comm)
        return float(np.max(end_comm + comp))
    raise ValueError(mode)


def per_processor_finish(net: StarNetwork, N: int, k: np.ndarray, mode: Mode) -> np.ndarray:
    """Per-processor finish times T_f(i) for a given split (same model as above)."""
    w, z, tcp, tcm = net.w, net.z, net.t_cp, net.t_cm
    k = np.asarray(k, dtype=np.float64)
    comp = k * N * N * w * tcp
    comm = 2.0 * k * N * z * tcm
    if mode == "PCSS":
        return comp
    if mode == "overlap":
        return np.maximum(comm, comp)
    if mode == "PCCS":
        return comm + comp
    if mode == "SCSS":
        start = np.concatenate([[0.0], np.cumsum(comm)[:-1]])
        return start + comp
    if mode == "SCCS":
        return np.cumsum(comm) + comp
    raise ValueError(mode)
