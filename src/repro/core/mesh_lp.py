"""MFT-LBP: the paper's LP/MIP formulation for multi-neighbor (mesh) networks.

Paper §5.2, eqs (49)-(61).  Variables:

    k_i      layers assigned to node i           (integer in the MIP; real here)
    T_s(i)   start time of node i
    phi(i,j) load volume sent over directed edge (i,j)
    T_f      overall finishing time (objective)

``T_f(i) = T_s(i) + k_i N^2 w(i) Tcp`` is substituted into constraints (52)
and (61) rather than carried as an explicit variable.

Constraints (paper numbering):
  (50) T_s(i) = 0 for the source
  (51) T_s(i) >= T_s(j) + phi(j,i) z(j,i) Tcm        for every edge (j,i)
  (53) sum_j phi(s,j) = 2 N^2                        source sends everything
  (54) inflow(i) - outflow(i) = 2 k_i N              non-source consumption
  (55/56) phi >= 0 on tau=1 edges, phi = 0 otherwise (we only create tau=1 vars)
  (57->62) k_i >= 0 (relaxed; integrality handled by PMFT-LBP / heuristic)
  (58) k_source = 0
  (59) 2 k_i N + N^2 <= D_i                          storage (optional)
  (60) sum_i k_i = N
  (61) T_f >= T_s(i) + k_i N^2 w(i) Tcp

Solved with scipy HiGHS dual simplex; ``nit`` is accumulated by callers to
reproduce the paper's Fig. 9 (total simplex iterations).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np
from scipy.optimize import linprog

from .network import MeshNetwork


@dataclasses.dataclass
class LPResult:
    k: np.ndarray                      # (p,) real-valued layer counts
    t_start: np.ndarray                # (p,)
    t_finish_nodes: np.ndarray         # (p,) T_f(i)
    phi: Dict[Tuple[int, int], float]  # per-edge volume
    t_finish: float                    # T_f (makespan)
    nit: int                           # simplex iterations of this solve
    status: int

    @property
    def comm_volume(self) -> float:
        """Overall communication volume = sum of per-link traffic (paper §6.2.1)."""
        return float(sum(self.phi.values()))


def _build_and_solve(
    net: MeshNetwork,
    N: int,
    fixed_k: Optional[np.ndarray] = None,
) -> LPResult:
    net.validate()
    p = net.p
    edges = net.edges()
    E = len(edges)
    eidx = {e: i for i, e in enumerate(edges)}

    # variable layout: [k_0..k_{p-1} | Ts_0..Ts_{p-1} | phi_e0..phi_{E-1} | Tf]
    nk, nt = p, p
    n_var = nk + nt + E + 1
    K0, T0, P0, F0 = 0, nk, nk + nt, nk + nt + E

    tcp, tcm = net.t_cp, net.t_cm
    s = net.source
    N2 = float(N) * float(N)

    c = np.zeros(n_var)
    c[F0] = 1.0  # minimize T_f

    A_ub, b_ub = [], []
    A_eq, b_eq = [], []

    # Flow variables are expressed in units of 2N entries (phi' = phi / (2N)):
    # this keeps the constraint-matrix coefficients within ~4 orders of
    # magnitude (raw phi ~ 4.5e6 against z*Tcm ~ 3e-4 makes HiGHS's dual
    # simplex mis-declare feasible instances infeasible).
    PHI_UNIT = 2.0 * float(N)

    # (51): Ts_i - Ts_j - phi(j,i) z Tcm >= 0  ->  -Ts_i + Ts_j + phi*z*Tcm <= 0
    for (j, i) in edges:
        row = np.zeros(n_var)
        row[T0 + i] = -1.0
        row[T0 + j] = 1.0
        row[P0 + eidx[(j, i)]] = net.z[(j, i)] * tcm * PHI_UNIT
        A_ub.append(row)
        b_ub.append(0.0)

    # (61): Ts_i + k_i N^2 w_i Tcp - Tf <= 0
    for i in range(p):
        row = np.zeros(n_var)
        row[T0 + i] = 1.0
        row[K0 + i] = N2 * net.w[i] * tcp
        row[F0] = -1.0
        A_ub.append(row)
        b_ub.append(0.0)

    # (53): source outflow = 2 N^2  (in phi' units: = N)
    row = np.zeros(n_var)
    for e in net.out_edges(s):
        row[P0 + eidx[e]] = 1.0
    A_eq.append(row)
    b_eq.append(2.0 * N2 / PHI_UNIT)

    # (54): inflow - outflow - 2 N k_i = 0  (in phi' units: ... - k_i = 0)
    for i in range(p):
        if i == s:
            continue
        row = np.zeros(n_var)
        for e in net.in_edges(i):
            row[P0 + eidx[e]] = 1.0
        for e in net.out_edges(i):
            row[P0 + eidx[e]] = -1.0
        row[K0 + i] = -2.0 * float(N) / PHI_UNIT
        A_eq.append(row)
        b_eq.append(0.0)

    # (60): sum k = N
    row = np.zeros(n_var)
    row[K0:K0 + p] = 1.0
    A_eq.append(row)
    b_eq.append(float(N))

    # bounds
    bounds = []
    for i in range(p):  # k
        if i == s:
            bounds.append((0.0, 0.0))                       # (58)
        elif fixed_k is not None:
            v = float(fixed_k[i])
            bounds.append((v, v))
        else:
            hi = None
            if net.storage is not None:                     # (59)
                hi = max(0.0, (net.storage[i] - N2) / (2.0 * N))
            bounds.append((0.0, hi))
    for i in range(p):  # Ts
        bounds.append((0.0, None) if i != s else (0.0, 0.0))  # (50)
    for _ in range(E):  # phi
        bounds.append((0.0, None))                          # (55)
    bounds.append((0.0, None))                              # Tf

    lp_args = dict(
        A_ub=np.array(A_ub), b_ub=np.array(b_ub),
        A_eq=np.array(A_eq), b_eq=np.array(b_eq),
        bounds=bounds,
    )
    # Dual simplex, per the paper's simplex-iteration evaluation (Fig. 9).
    # HiGHS presolve mis-declares some fixed-k instances infeasible (fixed
    # bounds + exact flow equalities), so it is disabled; interior-point is
    # the fallback for the rare conditioning failures of the simplex.
    res = linprog(c, method="highs-ds", options={"presolve": False}, **lp_args)
    if res.status != 0:
        res = linprog(c, method="highs-ipm", options={"presolve": False}, **lp_args)
    if res.status != 0:
        raise RuntimeError(f"MFT-LBP LP infeasible/failed: status={res.status} {res.message}")

    x = res.x
    k = x[K0:K0 + p]
    ts = x[T0:T0 + p]
    tf_nodes = ts + k * N2 * net.w * tcp
    phi = {e: float(x[P0 + eidx[e]]) * PHI_UNIT for e in edges}
    return LPResult(
        k=k, t_start=ts, t_finish_nodes=tf_nodes, phi=phi,
        t_finish=float(x[F0]), nit=int(getattr(res, "nit", 0)), status=res.status,
    )


def solve_relaxed(net: MeshNetwork, N: int) -> LPResult:
    """Phase-I relaxation (constraint (57) -> k_i >= 0 real)."""
    return _build_and_solve(net, N, fixed_k=None)


def solve_fixed_k(net: MeshNetwork, N: int, k: np.ndarray) -> LPResult:
    """Re-solve timing/flow with {k_i} pinned (used by FIFS / neighbor search).

    With k fixed the LP computes the optimal flow routing and start times,
    i.e. it doubles as the finishing-time *simulator* for LBP on the mesh.
    """
    return _build_and_solve(net, N, fixed_k=np.asarray(k, dtype=np.float64))


def solve_fixed_k_normalized(net: MeshNetwork, N: int, k: np.ndarray) -> LPResult:
    """Fixed-k timing solve that tolerates sum(k) != N.

    (53) emits 2N^2 while (54) consumes 2*k_i*N: with sum(k) != N the flow
    constraints are inconsistent and the LP is strictly infeasible.  The
    paper's FIFS/heuristic nevertheless 're-solve MFT-LBP with {k'_i} known'
    mid-repair to rank T_f(i); the only feasible reading is the normalized
    problem k * (N / sum(k)), which preserves the per-node finish-time
    ordering used for the +1/-1 adjustment decisions.
    """
    k = np.asarray(k, dtype=np.float64)
    total = float(k.sum())
    if total <= 0:
        raise ValueError("empty schedule")
    if total == float(N):
        return _build_and_solve(net, N, fixed_k=k)
    return _build_and_solve(net, N, fixed_k=k * (float(N) / total))
