"""LBP core: the paper's contribution (schedulers, partition, distributed matmul).

Scheduler plane (pure numpy/scipy, the paper's algorithms):
  network           star/mesh heterogeneous network models (§4/§5/§6 params)
  star              closed-form {k_i} solvers: SCSS/SCCS/PCCS/PCSS (§4)
  integer_adjust    §4.5 rounding + sum repair (quantum=1 paper, 128 TPU)
  mesh_lp           MFT-LBP linear program (§5.2, eqs 49-61)
  pmft              PMFT-LBP 3-phase solver + FIFS (§5.3, Algs 1-2)
  heuristic         MFT-LBP-heuristic (§5.4, Alg 3)
  rect_partition    rectangular baselines: Even-Col/PERI-SUM/Recursive/NRRP + bounds
  mesh_baselines    SUMMA / Pipeline / Modified Pipeline mesh simulators

Execution plane (JAX):
  partition         LayerAssignment {k_i} datatype
  lbp_matmul        k-sharded distributed matmul (layers/allreduce/scatter),
                    ragged heterogeneous shards
  overlap           layer-streaming collective matmuls ("simultaneous
                    start" on the mesh): streamed gather/scatter rings +
                    the stream_* aggregation modes
"""

from .network import MeshNetwork, SpeedProfile, StarNetwork, random_mesh, random_star  # noqa: F401
from .partition import LayerAssignment  # noqa: F401
from .star import SOLVERS, StarSchedule, per_processor_finish, solve  # noqa: F401
from .integer_adjust import adjust_integer, solve_integer  # noqa: F401
