"""Mesh-network baselines for §6.2: SUMMA, Pipeline, Modified Pipeline.

All three are simulated on the paper's quadrant mesh (``network.MeshNetwork``:
X x Y grid, source at (0,0), links directed right/down) with heterogeneous
link/processor speeds, and report the two §6.2.1 metrics:

  overall communication volume  = sum of data volume crossing each link
  task finishing time           = source start -> last processor finish

Modeling choices (paper §6.2.2):

* SUMMA has no source: the matrices are pre-distributed block-wise on the
  p-1 compute nodes arranged in a (near-)square grid.  Per outer step the
  pivot column of A blocks travels along each grid row and the pivot row of
  B blocks along each grid column (hop-by-hop relays on the heterogeneous
  links), then every node updates its C block.  Homogeneous equal blocks —
  that is exactly why its finishing time suffers on a heterogeneous mesh
  (paper: +46..56% vs LBP) while its volume stays near-optimal.
* Pipeline floods the FULL 2N^2 input over every mesh edge (each node
  receives a copy from every in-neighbor, keeps the first), store-and-forward
  without chunk overlap; each node then computes a speed-proportional share.
* Modified Pipeline (Tan [35]) forwards one copy per node along a spanning
  tree with tuned chunk size -> near-perfect pipelining (receive time is
  dominated by the slowest link on the path), same speed-proportional shares.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from .network import MeshNetwork


@dataclasses.dataclass(frozen=True)
class MeshSimResult:
    algorithm: str
    comm_volume: float
    finish_time: float


# ---------------------------------------------------------------------------
# helpers on the directed quadrant mesh
# ---------------------------------------------------------------------------

def _compute_nodes(net: MeshNetwork) -> List[int]:
    return [i for i in range(net.p) if i != net.source]


def _shortest_path_tree(net: MeshNetwork) -> Dict[int, Tuple[int, float]]:
    """Dijkstra from the source over directed edges; returns
    node -> (parent, path_cost) where edge cost is z(i,j)*Tcm (per unit)."""
    import heapq

    dist = {net.source: 0.0}
    parent: Dict[int, Tuple[int, float]] = {}
    pq = [(0.0, net.source)]
    seen = set()
    while pq:
        d, i = heapq.heappop(pq)
        if i in seen:
            continue
        seen.add(i)
        for (a, b) in net.out_edges(i):
            nd = d + net.z[(a, b)] * net.t_cm
            if b not in dist or nd < dist[b]:
                dist[b] = nd
                parent[b] = (a, net.z[(a, b)] * net.t_cm)
                heapq.heappush(pq, (nd, b))
    return {b: (a, c) for b, (a, c) in parent.items()}


def _path_links(net: MeshNetwork, tree: Dict[int, Tuple[int, float]], node: int) -> List[Tuple[int, int]]:
    links = []
    cur = node
    while cur != net.source:
        par, _ = tree[cur]
        links.append((par, cur))
        cur = par
    return links[::-1]


def _speed_proportional_k(net: MeshNetwork, N: int) -> np.ndarray:
    """Integer k_i ∝ 1/w_i over compute nodes, summing to N."""
    nodes = _compute_nodes(net)
    inv = np.array([1.0 / net.w[i] for i in nodes])
    share = inv / inv.sum() * N
    k = np.floor(share).astype(np.int64)
    rem = int(N - k.sum())
    order = np.argsort(-(share - k))
    for t in range(rem):
        k[order[t % len(k)]] += 1
    out = np.zeros(net.p, dtype=np.int64)
    for j, i in enumerate(nodes):
        out[i] = k[j]
    return out


def _equal_k(net: MeshNetwork, N: int) -> np.ndarray:
    """Equal integer shares (heterogeneity-blind, like the homogeneous-origin
    pipeline broadcast schemes)."""
    nodes = _compute_nodes(net)
    base = N // len(nodes)
    k = np.full(len(nodes), base, dtype=np.int64)
    for t in range(N - base * len(nodes)):
        k[t % len(nodes)] += 1
    out = np.zeros(net.p, dtype=np.int64)
    for j, i in enumerate(nodes):
        out[i] = k[j]
    return out


# ---------------------------------------------------------------------------
# SUMMA
# ---------------------------------------------------------------------------

def simulate_summa(net: MeshNetwork, N: int) -> MeshSimResult:
    """Block SUMMA on the compute-node grid.

    Grid: we keep the mesh's own X x Y geometry but drop the source node; the
    source's block is taken over by its right neighbor (smallest perturbation
    that keeps the paper's 'no single source' setup on the same topology).

    Volume: per outer step s (X steps), the pivot A-block column relays
    right across each row (X-1 link crossings per row) and the pivot B-block
    row relays down each column (Y-1 crossings per column).

    Time: per step, hop-by-hop relay of the pivot blocks (sequential over
    hops, links in parallel), then every node computes a rank-(N/X) update
    of its (N/Y x N/X) C block; consecutive start within the step.
    """
    X, Y = net.X, net.Y
    steps = X
    blk_a = (N / Y) * (N / X)   # an A block (rows/Y x cols/X)
    blk_b = (N / Y) * (N / X)
    # --- volume ---
    vol = steps * (Y * (X - 1) * blk_a + X * (Y - 1) * blk_b)

    # --- time ---
    tcm, tcp = net.t_cm, net.t_cp
    total = 0.0
    for s in range(steps):
        # pivot column x = s broadcasts A right; pivot row y = s broadcasts B down
        t_comm = 0.0
        for y in range(Y):
            # relay along the row: cumulative hop-by-hop from x=s rightward and leftward.
            # Directed quadrant links only go right; leftward relays reuse the
            # same physical links (full-duplex), same z.
            cum = 0.0
            for x in range(s, X - 1):
                i = net.node_id(x, y)
                j = net.node_id(x + 1, y)
                cum += net.z[(i, j)] * tcm * blk_a
                t_comm = max(t_comm, cum)
            cum = 0.0
            for x in range(s, 0, -1):
                i = net.node_id(x - 1, y)
                j = net.node_id(x, y)
                cum += net.z[(i, j)] * tcm * blk_a
                t_comm = max(t_comm, cum)
        for x in range(X):
            cum = 0.0
            for y in range(s, Y - 1):
                i = net.node_id(x, y)
                j = net.node_id(x, y + 1)
                cum += net.z[(i, j)] * tcm * blk_b
                t_comm = max(t_comm, cum)
            cum = 0.0
            for y in range(s, 0, -1):
                i = net.node_id(x, y - 1)
                j = net.node_id(x, y)
                cum += net.z[(i, j)] * tcm * blk_b
                t_comm = max(t_comm, cum)
        # compute: C block (N/Y x N/X), rank N/X update
        flops = (N / Y) * (N / X) * (N / X)
        t_comp = max(flops * net.w[i] * tcp for i in range(net.p))
        total += t_comm + t_comp
    return MeshSimResult("SUMMA", float(vol), float(total))


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------

def simulate_pipeline(net: MeshNetwork, N: int) -> MeshSimResult:
    """Classic flooding pipeline.

    Every edge carries one full copy of the 2N^2 input (nodes forward to all
    out-neighbors; receivers keep the first copy).  Store-and-forward without
    chunking; each node has a single send port, so its out-edge transmissions
    serialize.  Shares are equal (the scheme is heterogeneity-blind), and a
    node starts computing only after its full copy arrived (consecutive
    start).
    """
    data = 2.0 * float(N) * float(N)
    vol = data * len(net.edges())

    # store-and-forward with single-port sends (right first, then down):
    # send_finish(i->j) = max(arrive(i), port_free(i)) + T_edge.
    order = sorted(range(net.p), key=lambda i: sum(net.coords(i)))
    arrive = {net.source: 0.0}
    port_free = {i: None for i in range(net.p)}
    for j in order:
        if j == net.source:
            continue
        cands = []
        for (i, _) in net.in_edges(j):
            if i not in arrive:
                continue
            start = arrive[i] if port_free[i] is None else max(arrive[i], port_free[i])
            t_edge = net.z[(i, j)] * net.t_cm * data
            cands.append((start + t_edge, i))
        t, i = min(cands)
        port_free[i] = t
        arrive[j] = t

    k = _equal_k(net, N)
    tf = 0.0
    for i in _compute_nodes(net):
        tf = max(tf, arrive[i] + k[i] * float(N) ** 2 * net.w[i] * net.t_cp)
    return MeshSimResult("Pipeline", float(vol), float(tf))


def simulate_modified_pipeline(net: MeshNetwork, N: int) -> MeshSimResult:
    """Tan [35]: non-blocking chunked pipeline broadcast on a spanning tree.

    One copy per node (volume = 2N^2 * (p-1)); with tuned chunk size the
    relay is fully overlapped, so a node's receive time approaches
    data * (effective bottleneck bandwidth on its tree path), where a relay
    node feeding f children serves each at 1/f of its link rate (single
    port).  Shares are equal (heterogeneity-blind).
    """
    data = 2.0 * float(N) * float(N)
    vol = data * (net.p - 1)

    tree = _shortest_path_tree(net)
    fanout = {i: 0 for i in range(net.p)}
    for child, (par, _) in tree.items():
        fanout[par] += 1
    k = _equal_k(net, N)
    tf = 0.0
    for i in _compute_nodes(net):
        links = _path_links(net, tree, i)
        bottleneck = max(net.z[e] * net.t_cm * max(1, fanout[e[0]]) for e in links)
        arrive = data * bottleneck  # pipelined chunks: bandwidth-dominated
        tf = max(tf, arrive + k[i] * float(N) ** 2 * net.w[i] * net.t_cp)
    return MeshSimResult("ModifiedPipeline", float(vol), float(tf))
