"""Layer-assignment datatypes shared by the scheduler and execution planes.

A ``LayerAssignment`` is the paper's ``{k_i}`` for one sharded contraction:
device i owns ``k[i]`` columns of A / rows of B (a contiguous slice of the
contraction dimension) and computes one *layer* of the output.

``quantum`` is the TPU adaptation of §4.5 integer adjustment: shards are
multiples of 128 so every local matmul stays MXU-lane aligned; quantum=1
reproduces the paper exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .network import StarNetwork


@dataclasses.dataclass(frozen=True)
class LayerAssignment:
    """Integer split {k_i} of a contraction dimension K across p devices."""

    k: np.ndarray            # (p,) integer layer counts, sum == K
    quantum: int = 1

    def __post_init__(self):
        k = np.asarray(self.k, dtype=np.int64)
        object.__setattr__(self, "k", k)
        assert np.all(k >= 0)
        if self.quantum > 1:
            assert np.all(k % self.quantum == 0), "shards must be quantum-aligned"

    @property
    def p(self) -> int:
        return int(self.k.shape[0])

    @property
    def K(self) -> int:
        return int(self.k.sum())

    @property
    def offsets(self) -> np.ndarray:
        """Start offset of each device's slice in the contraction dim."""
        return np.concatenate([[0], np.cumsum(self.k)[:-1]]).astype(np.int64)

    @property
    def k_max(self) -> int:
        return int(self.k.max())

    def is_even(self) -> bool:
        return bool(np.all(self.k == self.k[0]))

    @property
    def comm_volume(self) -> float:
        """Source->device volume for an N=K square matmul: 2*K*sum(k) = 2K^2
        — Theorem 1's optimum (each entry sent once)."""
        return 2.0 * self.K * float(self.k.sum())

    @staticmethod
    def even(K: int, p: int, quantum: int = 1) -> "LayerAssignment":
        assert K % (p * quantum) == 0, (K, p, quantum)
        return LayerAssignment(np.full(p, K // p, dtype=np.int64), quantum)

    @staticmethod
    def from_speeds(
        K: int,
        speeds: Sequence[float],
        quantum: int = 1,
        mode: str = "PCSS",
        net: Optional[StarNetwork] = None,
    ) -> "LayerAssignment":
        """Heterogeneity-aware split — a thin wrapper over ``repro.plan``.

        ``speeds`` are relative compute rates (1.0 = nominal) and become a
        flat-star ``Topology`` (near-zero ICI links); pass a full
        ``StarNetwork`` + mode for link-aware splits (SCSS/SCCS/PCCS).
        All solving, §4.5 integer adjustment and cost accounting live in
        ``repro.plan.plan()`` — use it directly when you also need the
        predicted finish times / comm volumes of the split.
        """
        from ..plan import StarTopology, plan  # lazy: plan imports core
        topo = (StarTopology.from_network(net) if net is not None
                else StarTopology.from_speeds(speeds))
        pp = plan(topo, K, quantum=quantum, objective=mode)
        return LayerAssignment(pp.k, quantum)
