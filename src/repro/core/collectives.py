"""Registry-based dispatch for LBP layer aggregation (the paper's §1.2).

Layer-based partition leaves each device holding one full-shape *layer*
``L_i = A[:, K_i] @ B[K_i, :]``; an *aggregation mode* decides what happens
to the partial layers.  The built-in modes:

  "layers"     keep the layers distributed (the paper's 'distributed
               storage, lazy sync-up') — zero collective bytes, output
               grows a leading device axis.
  "allreduce"  eager psum — replicated result, ring bytes
               2 (p-1)/p x bytes(out).
  "scatter"    deferred psum_scatter — each device owns 1/p of the
               aggregated output along one dim, ring bytes (p-1)/p x
               bytes(out): exactly half of allreduce, the paper's lazy
               aggregation made productive.
  "ring"       neighbour ring pass-around (ppermute relay) — replicated
               result like allreduce but the full partial travels p-1
               hops, (p-1) x bytes(out) per device: the sequential
               neighbour-relay byte model of unswitched fabrics.
  "hierarchical"  two-level ICI+DCN aggregation matching ``repro.plan``'s
               HierarchicalTopology plans: reduce-scatter within the pod,
               all-reduce the 1/m shard across pods (all the DCN traffic),
               all-gather within the pod.  axis=(pod_axis, inner_axis).

Layer-streaming modes ("stream_scatter" / "stream_gather" /
"stream_hierarchical" — the paper's simultaneous-start overlap lifted to
the mesh as ppermute rings, byte-identical to their blocking
counterparts) are defined in ``core/overlap.py`` and register themselves
here on import (see the bottom of this file).

Every shard_map body in the repo combines partial layers through
``aggregate(partial, mode, axis)`` and builds its out-spec with
``out_spec(mode, axis, base)``, so the semantics, the PartitionSpec
plumbing and the analytic per-device byte model live together in ONE
registry entry per mode.  ``analysis/`` and tests query the same numbers
the runtime executes via ``collective_bytes_per_device`` /
``bytes_table``.  Further modes plug in with ``register_mode`` without
touching any call site.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

Mode = str  # registry key: "layers" | "allreduce" | "scatter" | ...


@dataclasses.dataclass(frozen=True)
class AggregationMode:
    """One way of combining per-device partial layers inside shard_map.

    combine(partial, axis, scatter_dim) runs INSIDE the shard_map body and
    returns the per-device block of the combined result.  out_spec(axis,
    base, scatter_dim) maps the combined result's dims to mesh axes, where
    ``base`` is the spec tuple the output would carry fully replicated
    over ``axis`` (scatter replaces entry ``scatter_dim``; layers prepends
    the device axis).  link_byte_factor(p) is the analytic ring-link bytes
    each device moves, as a multiple of the combined output's byte size.
    """
    name: str
    combine: Callable[[jax.Array, str, int], jax.Array]
    out_spec: Callable[[str, Tuple, int], P]
    link_byte_factor: Callable[[int], float]
    adds_device_axis: bool = False
    description: str = ""


_REGISTRY: Dict[str, AggregationMode] = {}


def register_mode(mode: AggregationMode, *, overwrite: bool = False) -> None:
    if mode.name in _REGISTRY and not overwrite:
        raise ValueError(f"aggregation mode {mode.name!r} already registered")
    _REGISTRY[mode.name] = mode


def unregister_mode(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_mode(name: Mode) -> AggregationMode:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregation mode {name!r}; "
            f"registered: {available_modes()}") from None


def available_modes() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# uniform API used by shard_map bodies and spec builders
# ---------------------------------------------------------------------------

def aggregate(partial: jax.Array, mode: Mode, axis: str, *,
              scatter_dim: Optional[int] = None) -> jax.Array:
    """Combine this device's partial layer over mesh axis ``axis``.

    Must be called inside a shard_map body.  ``scatter_dim`` picks the
    output dim scatter-mode shards (default: last).
    """
    if scatter_dim is None:
        scatter_dim = partial.ndim - 1
    return get_mode(mode).combine(partial, axis, scatter_dim)


def out_spec(mode: Mode, axis: str, base: Sequence, *,
             scatter_dim: Optional[int] = None) -> P:
    """PartitionSpec of the aggregated output.

    ``base``: per-dim spec entries of the combined output as if replicated
    over ``axis`` (batch axes stay in place).  scatter overwrites entry
    ``scatter_dim`` (default: last) with ``axis``; layers prepends the
    device axis.
    """
    base = tuple(base)
    if scatter_dim is None:
        scatter_dim = len(base) - 1
    return get_mode(mode).out_spec(axis, base, scatter_dim)


def collective_bytes_per_device(out_elems: int, p: int, mode: Mode,
                                itemsize: int = 2) -> float:
    """Analytic ring-link bytes per device for aggregating ``out_elems``
    output elements across ``p`` devices in ``mode``."""
    return get_mode(mode).link_byte_factor(p) * out_elems * itemsize


def bytes_table(out_elems: int, p: int, itemsize: int = 2) -> Dict[str, float]:
    """Per-mode byte accounting for every registered mode (the query
    surface ``analysis/`` uses for roofline narratives and reports)."""
    return {name: collective_bytes_per_device(out_elems, p, name, itemsize)
            for name in available_modes()}


# ---------------------------------------------------------------------------
# built-in modes
# ---------------------------------------------------------------------------

def _scatter_spec(axis: str, base: Tuple, scatter_dim: int) -> P:
    entries = list(base)
    if entries[scatter_dim] is not None:
        raise ValueError(
            f"scatter_dim {scatter_dim} already sharded over "
            f"{entries[scatter_dim]!r} in base spec {base}")
    entries[scatter_dim] = axis
    return P(*entries)


register_mode(AggregationMode(
    name="layers",
    combine=lambda partial, axis, _sd: partial[None],
    out_spec=lambda axis, base, _sd: P(axis, *base),
    link_byte_factor=lambda p: 0.0,
    adds_device_axis=True,
    description="no aggregation: distributed layer storage, lazy sync-up",
))

register_mode(AggregationMode(
    name="allreduce",
    combine=lambda partial, axis, _sd: jax.lax.psum(partial, axis),
    out_spec=lambda axis, base, _sd: P(*base),
    link_byte_factor=lambda p: 2.0 * (p - 1) / p,
    description="eager psum: replicated result (paper-faithful)",
))

register_mode(AggregationMode(
    name="scatter",
    combine=lambda partial, axis, sd: jax.lax.psum_scatter(
        partial, axis, scatter_dimension=sd, tiled=True),
    out_spec=_scatter_spec,
    link_byte_factor=lambda p: 1.0 * (p - 1) / p,
    description="deferred psum_scatter: each device owns 1/p of the sum",
))


def _axis_size(axis: str) -> int:
    """Static mesh-axis size inside a shard_map body: psum of a concrete
    (non-tracer) value is constant-folded to ``value * axis_size``, so the
    result stays a Python int usable for loop bounds."""
    return int(jax.lax.psum(1, axis))


def _ring_combine(partial: jax.Array, axis: str, _sd: int) -> jax.Array:
    """Neighbour-ring pass-around reduce: each device forwards the full
    partial layer around the ring p-1 times, accumulating as it goes.
    Replicated result like allreduce, but every hop moves bytes(out) per
    link — the paper's sequential neighbour-relay regime, and the byte
    model CPU/edge clusters without switched fabrics actually see."""
    p = _axis_size(axis)
    acc, buf = partial, partial
    perm = [(i, (i + 1) % p) for i in range(p)]
    for _ in range(p - 1):
        buf = jax.lax.ppermute(buf, axis, perm)
        acc = acc + buf
    return acc


register_mode(AggregationMode(
    name="ring",
    combine=_ring_combine,
    out_spec=lambda axis, base, _sd: P(*base),
    link_byte_factor=lambda p: float(p - 1),
    description="neighbour ring pass-around: full partial forwarded p-1 "
                "hops (replicated result; p/2 x allreduce's ring bytes)",
))


def _hier_combine(partial: jax.Array, axis, sd: int) -> jax.Array:
    """Two-level aggregation matching ``repro.plan``'s HierarchicalTopology:
    reduce-scatter within the pod (ICI), all-reduce the 1/m shard across
    pods (the only traffic on the DCN trunks), then all-gather within the
    pod (ICI).  Replicated result, numerically identical to psum; each
    pod's trunk carries 2(P-1)/P x bytes(out) total vs a flat ring's
    2(p-1)/p — halved for the 2-pod production shape (per *device* the
    cross-pod shard is 1/m-sized, but m flows share the trunk).
    ``axis`` must be a (pod_axis, inner_axis) pair."""
    if not isinstance(axis, (tuple, list)) or len(axis) != 2:
        raise ValueError(
            "hierarchical aggregation needs axis=(pod_axis, inner_axis), "
            f"got {axis!r}")
    pod_axis, inner = axis
    shard = jax.lax.psum_scatter(partial, inner, scatter_dimension=sd,
                                 tiled=True)
    shard = jax.lax.psum(shard, pod_axis)            # DCN: V/m per device
    return jax.lax.all_gather(shard, inner, axis=sd, tiled=True)


def _hier_out_spec(axis, base: Tuple, _sd: int) -> P:
    return P(*base)


def hierarchical_byte_breakdown(out_elems: int, n_pods: int, pod_size: int,
                                itemsize: int = 2) -> Dict[str, float]:
    """Per-device link bytes of the two-level aggregation, per link class,
    next to what a FLAT ring all-reduce over the same p devices pushes
    through each pod's DCN trunk (the flat ring enters and leaves every
    pod, so the trunk carries the full ring traffic).

    This is the execution-plane counterpart of the plan IR's per-class
    comm accounting: the number the hierarchical PartitionPlan promises is
    the number the collective moves.
    """
    P_, m = int(n_pods), int(pod_size)
    v = float(out_elems) * itemsize
    ici = 2.0 * (m - 1) / m * v if m > 1 else 0.0       # RS + AG within pod
    dcn_dev = 2.0 * (P_ - 1) / P_ * v / m if P_ > 1 else 0.0
    p = P_ * m
    flat_ring_link = 2.0 * (p - 1) / p * v if p > 1 else 0.0
    return {
        "ici_per_device": ici,
        "dcn_per_device": dcn_dev,                     # shard-sized
        "dcn_per_pod": dcn_dev * m,                    # trunk egress
        "flat_allreduce_dcn_per_pod": flat_ring_link,  # trunk egress, flat
        "total_per_device": ici + dcn_dev,
    }


register_mode(AggregationMode(
    name="hierarchical",
    combine=_hier_combine,
    out_spec=_hier_out_spec,
    # generic-table factor: worst-device total bytes under the canonical
    # 2-pod production split (pods of m = p/2); exact per-class accounting
    # is hierarchical_byte_breakdown().
    link_byte_factor=lambda p: (
        0.0 if p < 2 else
        2.0 * (p / 2 - 1) / (p / 2) + 2.0 / p),
    description="two-level ICI+DCN: reduce-scatter in pod, shard all-reduce "
                "across pods, all-gather in pod (replicated result; per-pod "
                "trunk bytes 2(P-1)/P x out vs the flat ring's 2(p-1)/p)",
))


# The overlapped layer-streaming modes register themselves on import (the
# import sits below every definition they need, so the cycle is benign).
from . import overlap as _overlap  # noqa: E402,F401
