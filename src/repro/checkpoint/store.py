"""Checkpointing: per-leaf .npy shards, atomic manifest, async writer,
reshard-on-restore.

Layout:
    <dir>/step_000123/
        manifest.json      {"step", "leaves": {name: {shape, dtype}}, "done"}
        <leaf-name>.npy    one file per pytree leaf

Atomicity: write into ``step_X.tmp`` then ``os.rename`` (directory rename is
atomic on POSIX); readers only trust directories whose manifest says
``done``.  ``AsyncCheckpointer`` snapshots to host numpy synchronously
(cheap vs training step) and writes on a worker thread, overlapping the
next steps — save-every-N never blocks the loop on IO.

Reshard-on-restore: leaves load as host numpy and are ``device_put`` with
whatever NamedShardings the NEW mesh prescribes — restoring onto a
different device count / topology (elastic rescale) is the same code path
(tested).
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(_key_str(k) for k in path)
        flat[name] = leaf
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save_checkpoint(directory, step: int, state) -> pathlib.Path:
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(state)
    leaves_meta = {}
    for name, leaf in flat.items():
        arr = np.asarray(leaf)   # gathers sharded arrays to host
        fn = name.replace("/", "__") + ".npy"
        np.save(tmp / fn, arr)
        leaves_meta[name] = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                             "file": fn}
    _write_json_atomic(tmp / "manifest.json",
                       {"step": step, "leaves": leaves_meta, "done": True})
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _write_json_atomic(path: pathlib.Path, obj) -> None:
    """Temp file + ``os.replace``: a crash mid-write leaves either no
    manifest or the previous one, never a torn JSON document (readers
    tolerate torn manifests anyway — see ``latest_step`` — but the
    writer should not manufacture them)."""
    tmp = path.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(obj))
    os.replace(tmp, path)


def latest_step(directory) -> Optional[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    best = None
    for d in directory.iterdir():
        m = re.fullmatch(r"step_(\d+)", d.name)
        if not m or not (d / "manifest.json").exists():
            continue
        try:
            meta = json.loads((d / "manifest.json").read_text())
        except (OSError, json.JSONDecodeError):
            continue   # torn manifest (crashed writer): not a checkpoint
        if not isinstance(meta, dict) or not meta.get("done"):
            continue
        s = int(m.group(1))
        best = s if best is None else max(best, s)
    return best


def load_checkpoint(directory, step: int, target_tree,
                    shardings=None) -> Tuple[int, Any]:
    """Restore into the structure of ``target_tree`` (shapes validated).

    ``shardings``: optional pytree of NamedSharding (same structure) — each
    leaf is device_put with it, i.e. restore-with-reshard for a different
    mesh is free.
    """
    d = pathlib.Path(directory) / f"step_{step:08d}"
    meta = json.loads((d / "manifest.json").read_text())
    assert meta["done"], "incomplete checkpoint"

    flat_names = _flatten(target_tree)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for name, tgt in flat_names.items():
        lm = meta["leaves"].get(name)
        if lm is None:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = np.load(d / lm["file"])
        assert list(arr.shape) == list(tgt.shape), (name, arr.shape, tgt.shape)
        if name in flat_sh and flat_sh[name] is not None:
            out[name] = jax.device_put(arr, flat_sh[name])
        else:
            out[name] = jax.device_put(arr.astype(tgt.dtype))
    # rebuild tree
    treedef = jax.tree_util.tree_structure(target_tree)
    leaves_in_order = []
    for path, _ in jax.tree_util.tree_flatten_with_path(target_tree)[0]:
        name = "/".join(_key_str(k) for k in path)
        leaves_in_order.append(out[name])
    return meta["step"], jax.tree_util.tree_unflatten(treedef, leaves_in_order)


class AsyncCheckpointer:
    """Snapshot synchronously, write on a background thread."""

    def __init__(self, directory):
        self.directory = pathlib.Path(directory)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, state):
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            try:
                save_checkpoint(self.directory, step, snapshot)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
