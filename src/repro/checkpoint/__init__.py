from .store import (AsyncCheckpointer, latest_step, load_checkpoint,  # noqa: F401
                    save_checkpoint)
