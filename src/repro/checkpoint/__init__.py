from .reshard import (CorruptShard, load_sharded, plan_offsets,  # noqa: F401
                      reshard_state, restore_resharded, save_sharded,
                      verify_sharded)
from .store import (AsyncCheckpointer, latest_step, load_checkpoint,  # noqa: F401
                    save_checkpoint)
