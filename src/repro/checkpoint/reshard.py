"""Topology-resharding checkpoints: save under one ``PartitionPlan``,
restore re-sliced for another.

``store.py`` reshards *sharding objects* on restore (device_put with the
new mesh's NamedShardings); this module reshards the *byte layout*: a
fleet that checkpoints per-device shards of the LBP split — device i
owns rows ``[offset_i, offset_i + k_i)`` of every partitioned leaf —
can restart on a different device count or share vector, because
restore concatenates the old shards along the recorded axis into the
full leaf (bit-identical to what was saved) and re-slices it by the NEW
plan's integer shares through the PartitionPlan IR.  A ``(2,16,16)``
production plan's params restore onto a 7-device star this way, and
vice versa: the plans only have to agree on the total load.

Layout (same atomicity discipline as the store: tmp dir + rename,
readers trust only ``done`` manifests):

    <dir>/step_000123/
        manifest.json   {"step", "done", "axis", "shares", "load",
                         "solver", "topology_kind", "leaves": {...}}
        <leaf>__shard000.npy ...   partitioned leaves, one file per device
        <leaf>__shard000.npy.sha256  checksum sidecar, one per payload
        <leaf>.npy                 replicated leaves, whole

Shard integrity: the ``done`` manifest only proves the *directory*
rename landed; a torn or bit-flipped ``.npy`` payload inside it would
still load as garbage (or crash deep in ``np.load``).  ``save_sharded``
therefore writes a sha256 sidecar next to every payload file, and every
read path verifies payload-vs-sidecar before deserializing — a mismatch,
truncation, unreadable array, or missing file raises the typed
``CorruptShard`` instead of handing corrupt params to the fleet.
"""

from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
from typing import Any, Dict, List, Tuple

import numpy as np

from ..plan import PartitionPlan
from .store import _flatten, _key_str, _write_json_atomic


class CorruptShard(RuntimeError):
    """A shard payload failed integrity verification (torn write,
    truncation, bit corruption, or a missing file).  Raised by the read
    paths instead of returning garbage; the fleet's recovery scan treats
    it as "fall back to an older checkpoint"."""


def _digest(path: pathlib.Path) -> str:
    return hashlib.sha256(path.read_bytes()).hexdigest()


def _verify_payload(d: pathlib.Path, fn: str) -> None:
    """Payload-vs-sidecar check for one ``.npy`` file in ``d``."""
    f = d / fn
    if not f.exists():
        raise CorruptShard(f"{d.name}/{fn}: shard payload missing")
    side = d / (fn + ".sha256")
    if not side.exists():
        raise CorruptShard(f"{d.name}/{fn}: checksum sidecar missing "
                           f"(pre-integrity checkpoint or torn write)")
    want = side.read_text().strip()
    got = _digest(f)
    if got != want:
        raise CorruptShard(
            f"{d.name}/{fn}: sha256 mismatch (stored {want[:12]}…, "
            f"recomputed {got[:12]}…) — torn or corrupt shard")


def plan_offsets(plan: PartitionPlan) -> np.ndarray:
    """(p+1,) shard boundaries of the plan's integer shares."""
    return np.concatenate([[0], np.cumsum(plan.k)]).astype(np.int64)


def _partitioned(arr: np.ndarray, plan: PartitionPlan, axis: int) -> bool:
    """A leaf is partitioned iff the plan's load spans its ``axis``."""
    return arr.ndim > axis and int(arr.shape[axis]) == int(plan.load)


def save_sharded(directory, step: int, state, plan: PartitionPlan, *,
                 axis: int = 0) -> pathlib.Path:
    """Checkpoint ``state`` with every load-sized leaf split into the
    plan's per-device shards; everything else is saved replicated."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    offs = plan_offsets(plan)
    leaves_meta: Dict[str, Any] = {}

    def _save(fn: str, arr: np.ndarray) -> None:
        np.save(tmp / fn, arr)
        (tmp / (fn + ".sha256")).write_text(_digest(tmp / fn) + "\n")

    for name, leaf in _flatten(state).items():
        arr = np.asarray(leaf)   # gathers device arrays to host
        base = name.replace("/", "__")
        if _partitioned(arr, plan, axis):
            files: List[str] = []
            for i in range(plan.p):
                fn = f"{base}__shard{i:03d}.npy"
                shard = np.take(arr, np.arange(offs[i], offs[i + 1]),
                                axis=axis)
                _save(fn, shard)
                files.append(fn)
            leaves_meta[name] = {"shape": list(arr.shape),
                                 "dtype": str(arr.dtype),
                                 "partitioned": True, "files": files}
        else:
            fn = base + ".npy"
            _save(fn, arr)
            leaves_meta[name] = {"shape": list(arr.shape),
                                 "dtype": str(arr.dtype),
                                 "partitioned": False, "files": [fn]}
    _write_json_atomic(tmp / "manifest.json", {
        "step": step, "done": True, "axis": int(axis),
        "load": int(plan.load), "shares": [int(k) for k in plan.k],
        "solver": plan.solver, "topology_kind": plan.topology_kind,
        "leaves": leaves_meta})
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _assemble(d: pathlib.Path, meta: Dict[str, Any],
              name: str) -> np.ndarray:
    """Full host leaf from its manifest entry (concatenate the shards
    the saving plan produced — order is the plan's device order).
    Every payload is checksum-verified before deserializing; any
    integrity failure raises ``CorruptShard``."""
    lm = meta["leaves"].get(name)
    if lm is None:
        raise KeyError(f"checkpoint missing leaf {name}")
    parts = []
    for fn in lm["files"]:
        _verify_payload(d, fn)
        try:
            parts.append(np.load(d / fn))
        except Exception as e:   # checksum passed but np.load choked:
            # the sidecar itself was torn alongside the payload
            raise CorruptShard(
                f"{d.name}/{fn}: undeserializable shard ({e})") from e
    arr = (np.concatenate(parts, axis=int(meta["axis"]))
           if lm["partitioned"] else parts[0])
    if list(arr.shape) != list(lm["shape"]):
        raise CorruptShard(
            f"{d.name}: leaf {name} reassembled to {list(arr.shape)}, "
            f"manifest recorded {lm['shape']}")
    return arr


def verify_sharded(directory, step: int) -> int:
    """Checksum-verify every payload file of a sharded checkpoint
    without deserializing any of them.  Returns the number of files
    verified; raises ``CorruptShard`` on the first integrity failure
    (missing payload, missing sidecar, digest mismatch)."""
    d = pathlib.Path(directory) / f"step_{step:08d}"
    if not d.exists():
        raise CorruptShard(f"step_{step:08d}: checkpoint directory missing")
    try:
        meta = json.loads((d / "manifest.json").read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise CorruptShard(f"step_{step:08d}: unreadable manifest "
                           f"({e})") from e
    if not meta.get("done"):
        raise CorruptShard(f"step_{step:08d}: manifest not marked done")
    n = 0
    for lm in meta["leaves"].values():
        for fn in lm["files"]:
            _verify_payload(d, fn)
            n += 1
    return n


def load_sharded(directory, step: int, target_tree) -> Tuple[int, Any]:
    """Restore the FULL state from a sharded checkpoint: shards are
    concatenated back along the recorded axis, so the result is
    bit-identical to what ``save_sharded`` was handed — independent of
    the topology it was saved under."""
    import jax
    d = pathlib.Path(directory) / f"step_{step:08d}"
    meta = json.loads((d / "manifest.json").read_text())
    assert meta.get("done"), "incomplete checkpoint"
    leaves = []
    for path, tgt in jax.tree_util.tree_flatten_with_path(target_tree)[0]:
        name = "/".join(_key_str(k) for k in path)
        arr = _assemble(d, meta, name)
        assert list(arr.shape) == list(tgt.shape), (name, arr.shape,
                                                    tgt.shape)
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(target_tree)
    return int(meta["step"]), jax.tree_util.tree_unflatten(treedef, leaves)


def reshard_state(state, new_plan: PartitionPlan, *,
                  axis: int = 0) -> List[Any]:
    """Slice a full (host) state into the NEW plan's per-device shards:
    element i holds device i's view — load-sized leaves sliced to its
    ``k_i`` rows, everything else replicated whole."""
    import jax
    offs = plan_offsets(new_plan)

    def device_view(i):
        def slice_leaf(leaf):
            arr = np.asarray(leaf)
            if _partitioned(arr, new_plan, axis):
                return np.take(arr, np.arange(offs[i], offs[i + 1]),
                               axis=axis)
            return arr
        return jax.tree_util.tree_map(slice_leaf, state)

    return [device_view(i) for i in range(new_plan.p)]


def restore_resharded(directory, step: int, target_tree,
                      new_plan: PartitionPlan, *,
                      axis: int = 0) -> Tuple[int, Any, List[Any]]:
    """The elastic-restart path: load a checkpoint saved under ANY plan
    and return ``(step, full_state, per_device_shards)`` for the new
    topology's plan.  The full state is bit-identical to what was saved;
    the shards are its re-slices by ``new_plan.k``."""
    d = pathlib.Path(directory) / f"step_{step:08d}"
    meta = json.loads((d / "manifest.json").read_text())
    if int(meta["load"]) != int(new_plan.load):
        raise ValueError(
            f"cannot reshard: checkpoint was saved for load "
            f"{meta['load']} but the new plan splits {new_plan.load} — "
            f"the partitioned dimension itself changed")
    if int(meta["axis"]) != int(axis):
        raise ValueError(
            f"cannot reshard: checkpoint partitions axis {meta['axis']} "
            f"but the caller asked for axis {axis}")
    step_loaded, full = load_sharded(directory, step, target_tree)
    return step_loaded, full, reshard_state(full, new_plan, axis=axis)
