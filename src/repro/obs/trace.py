"""Deterministic tracer: nested spans, instant events, counter tracks.

A ``Tracer`` records what happened and WHEN — but "when" is read from an
injectable clock callable, never the wall clock: the serving engine hands
its iteration clock, the fleet controller its tick counter, wall-clock
replay tests a ``ManualClock``.  Two identical runs therefore record
identical event streams, and the Chrome-trace export (``obs.export``) is
byte-identical — the property the trace-determinism tests pin.

Events carry a ``track`` (Perfetto process row: one per replica, one for
the controller, one per engine) and a ``lane`` (thread row within the
track: per-request lanes like ``req:3``, an ``engine`` lane for step
spans, a ``membership`` lane for kill/join).  Spans that stay open across
engine iterations (queue-wait, a request's whole decode residency) are
keyed: ``begin(..., key=...)`` then ``end(key)`` from a later step.

``NullTracer`` is the default everywhere: every hook in a hot loop costs
exactly one no-op method call and allocates nothing — the engine's
dispatch count with tracing on equals the count with it off (tested),
because hooks only read host-side state the loop already owns.
"""

from __future__ import annotations

import contextlib
from typing import Any, Callable, Dict, List, Optional

__all__ = ["Tracer", "NullTracer"]


def _jsonable(v: Any) -> Any:
    """Coerce numpy scalars/arrays so exports are plain JSON."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
        return v.item()
    if hasattr(v, "tolist"):
        return v.tolist()
    return str(v)


class Tracer:
    """Append-only event recorder against an injectable clock."""

    enabled = True

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.clock = clock
        self.events: List[Dict[str, Any]] = []
        self._open: Dict[Any, Dict[str, Any]] = {}
        self._auto = 0

    # -- clock ----------------------------------------------------------
    def use_clock(self, fn: Callable[[], float]) -> None:
        """Adopt ``fn`` as the timeline.  The outermost timeline owner
        wins (a fleet controller overrides the engines' step clocks so
        the whole fleet renders on one tick axis)."""
        self.clock = fn

    def now(self) -> float:
        return float(self.clock()) if self.clock is not None else 0.0

    # -- recording ------------------------------------------------------
    def _emit(self, ph: str, name: str, track: str, lane: str,
              args: Dict[str, Any]) -> Dict[str, Any]:
        ev = {"ph": ph, "name": name, "ts": self.now(), "track": track,
              "lane": lane,
              "args": {k: _jsonable(v) for k, v in args.items()}}
        self.events.append(ev)
        return ev

    def event(self, name: str, *, track: str = "main",
              lane: str = "events", **args) -> None:
        """Instant event (Perfetto arrow tick)."""
        self._emit("i", name, track, lane, args)

    def begin(self, name: str, *, track: str = "main",
              lane: str = "events", key: Any = None, **args) -> Any:
        """Open a span; ``key`` lets a later call close it (idempotent
        keys: re-beginning an open key first closes the stale span so a
        crashed path cannot leak an unbounded open set)."""
        if key is None:
            self._auto += 1
            key = ("__auto__", self._auto)
        if key in self._open:
            self.end(key)
        self._open[key] = self._emit("B", name, track, lane, args)
        return key

    def end(self, key: Any, **args) -> None:
        """Close the span opened under ``key`` (no-op for unknown keys:
        failure paths may kill a request whose span someone else already
        closed)."""
        b = self._open.pop(key, None)
        if b is None:
            return
        self._emit("E", b["name"], b["track"], b["lane"], args)

    @contextlib.contextmanager
    def span(self, name: str, *, track: str = "main",
             lane: str = "events", **args):
        key = self.begin(name, track=track, lane=lane, **args)
        try:
            yield self
        finally:
            self.end(key)

    def counter(self, name: str, value: float, *,
                track: str = "main") -> None:
        """Counter sample (Perfetto renders a stacked area track)."""
        self._emit("C", name, track, name, {"value": _jsonable(value)})

    # -- introspection --------------------------------------------------
    def open_spans(self) -> List[str]:
        return [ev["name"] for ev in self._open.values()]

    def __len__(self) -> int:
        return len(self.events)


class _NullSpan:
    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: the default.  Every hook is one no-op call."""

    enabled = False
    events: List[Dict[str, Any]] = []   # always empty, shared sentinel

    def use_clock(self, fn) -> None:
        pass

    def now(self) -> float:
        return 0.0

    def event(self, name, **kw) -> None:
        pass

    def begin(self, name, **kw) -> Any:
        return None

    def end(self, key, **kw) -> None:
        pass

    def span(self, name, **kw):
        return _NULL_SPAN

    def counter(self, name, value, **kw) -> None:
        pass

    def open_spans(self) -> List[str]:
        return []

    def __len__(self) -> int:
        return 0
