"""Sanctioned wall-clock providers.

The repo's clock-injection policy (CI grep gate): no module outside
``repro/obs`` may call ``time.time(`` or ``time.monotonic(`` directly —
deterministic planes (engine steps, fleet ticks, ``ManualClock``) must
never fall back to the wall clock silently, and the places that
legitimately need wall time (trainer step timing, dry-run compile timing,
throughput reports) read it through these names so every wall-clock
dependency is grep-visible in one module.
"""

from __future__ import annotations

import time


def wall_time() -> float:
    """Seconds since the epoch (``time.time``): timestamps for humans."""
    return time.time()


def monotonic() -> float:
    """Monotonic seconds (``time.monotonic``): wall-clock arrival replay
    when no injectable clock was provided."""
    return time.monotonic()


def perf_counter() -> float:
    """Highest-resolution monotonic seconds: latency measurement."""
    return time.perf_counter()
