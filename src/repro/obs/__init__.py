"""Observability plane: deterministic tracing, metrics, plan-vs-actual drift.

The paper's promise is *predicted* behavior — the §4 equality split makes
every processor finish together, the LBP byte model says what every link
carries — and this package is how a live run is checked against those
predictions:

  trace.py    ``Tracer``: nested spans + instant events + counter tracks
              against an INJECTABLE clock (engine steps, controller ticks,
              ``ManualClock`` seconds — never the wall clock), with a
              ``NullTracer`` no-op default so hot loops pay one method call.
  export.py   Chrome-trace/Perfetto JSON exporter (byte-deterministic for
              deterministic runs).
  metrics.py  process-local registry of counters / gauges / fixed-bucket
              histograms — no wall clock in the data path, order-invariant
              histogram merge.
  drift.py    plan-vs-actual: observed finishes or shares scored against a
              ``PartitionPlan``'s predictions; the normalized drift gauge
              is the re-plan trigger signal (ROADMAP item 5).
  clock.py    the ONE sanctioned home of wall-clock reads
              (``time.time``/``time.monotonic`` are CI-grep-gated to this
              package).

Clock-injection policy: every runtime layer times its trace against the
clock it already owns — the serving engine's iteration clock, the fleet
controller's tick counter, a ``ManualClock`` in tests — so two identical
runs export byte-identical traces.  Wall-clock quantities (TTFT and
throughput seconds) stay in the metrics/report plane and are never gated
or traced.
"""

from .clock import monotonic, perf_counter, wall_time  # noqa: F401
from .drift import DriftMonitor, drift_fractions  # noqa: F401
from .export import to_chrome_json, write_chrome_trace  # noqa: F401
from .metrics import (Counter, Gauge, Histogram,  # noqa: F401
                      MetricsRegistry, throughput_summary)
from .trace import NullTracer, Tracer  # noqa: F401
