"""Process-local metrics: counters, gauges, fixed-bucket histograms.

Design constraints (the observability plane's contract):

  * NO wall clock in the data path — a metric records what the caller
    hands it; wall-clock quantities enter only as observed values (TTFT
    seconds), never as implicit timestamps, so a deterministic run
    produces a deterministic snapshot.
  * fixed bucket edges — histograms are declared with their edges and
    never rebucket, so merging partial histograms (per-replica -> fleet)
    is exact integer addition and ORDER-INVARIANT (hypothesis-tested).
  * labels are part of the identity — ``counter("rejections",
    reason="queue_full")`` and ``reason="max_new"`` are separate series;
    a snapshot key renders as ``rejections{reason=queue_full}``.

``throughput_summary`` is the ONE derivation of tok/s, TTFT and
occupancy: the serving engine's report and the fixed-batch benchmark
baseline both call it, so benchmark-vs-engine metric skew is impossible
by construction (the dedup the benchmarks satellite pinned).
"""

from __future__ import annotations

import bisect
import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "throughput_summary"]

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render(name: str, labels: LabelKey) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone event count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counters only go up, got inc({n})")
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-edge histogram: ``edges`` split the line into
    ``len(edges) + 1`` buckets (``(-inf, e0], (e0, e1], ..., (en, inf)``).

    ``merge`` adds bucket counts / totals of a same-shaped histogram;
    because counts are integers and addition commutes, merging any
    permutation of partials yields the identical histogram.
    """

    __slots__ = ("edges", "counts", "total", "n")

    def __init__(self, edges: Sequence[float]):
        e = tuple(float(x) for x in edges)
        if not e or list(e) != sorted(set(e)):
            raise ValueError(
                f"histogram edges must be non-empty, strictly increasing, "
                f"got {edges!r}")
        self.edges = e
        self.counts = [0] * (len(e) + 1)
        self.total = 0.0
        self.n = 0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.edges, float(v))] += 1
        self.total += float(v)
        self.n += 1

    def merge(self, other: "Histogram") -> "Histogram":
        if self.edges != other.edges:
            raise ValueError(
                f"cannot merge histograms with different edges: "
                f"{self.edges} vs {other.edges}")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.total += other.total
        self.n += other.n
        return self

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def snapshot(self) -> Dict[str, object]:
        return {"edges": list(self.edges), "counts": list(self.counts),
                "total": self.total, "count": self.n}


class MetricsRegistry:
    """Name+labels -> instrument, with a deterministic JSON snapshot."""

    def __init__(self):
        self._counters: Dict[Tuple[str, LabelKey], Counter] = {}
        self._gauges: Dict[Tuple[str, LabelKey], Gauge] = {}
        self._hists: Dict[Tuple[str, LabelKey], Histogram] = {}

    def counter(self, name: str, **labels) -> Counter:
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        key = (name, _label_key(labels))
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge()
        return g

    def histogram(self, name: str, edges: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        key = (name, _label_key(labels))
        h = self._hists.get(key)
        if h is None:
            if edges is None:
                raise ValueError(
                    f"first use of histogram {name!r} must declare edges")
            h = self._hists[key] = Histogram(edges)
        elif edges is not None and tuple(float(e) for e in edges) != h.edges:
            raise ValueError(
                f"histogram {name!r} already declared with edges "
                f"{h.edges}, got {tuple(edges)!r}")
        return h

    # -- read side ------------------------------------------------------
    def counter_value(self, name: str, **labels) -> int:
        return self.counter(name, **labels).value

    def counter_total(self, name: str) -> int:
        """Sum over every label combination of ``name``."""
        return sum(c.value for (n, _), c in self._counters.items()
                   if n == name)

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        return {
            "counters": {_render(n, lk): c.value for (n, lk), c
                         in sorted(self._counters.items())},
            "gauges": {_render(n, lk): g.value for (n, lk), g
                       in sorted(self._gauges.items())},
            "histograms": {_render(n, lk): h.snapshot() for (n, lk), h
                           in sorted(self._hists.items())},
        }

    def write_json(self, path) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)
        return str(path)


def throughput_summary(*, useful_tokens: int, wall_s: float,
                       ttfts_s: Iterable[float],
                       occupancy_sum: float, decode_steps: int,
                       decode_tokens: int = 0, decode_wall_s: float = 0.0
                       ) -> Dict[str, float]:
    """The one tok/s + TTFT + occupancy derivation.

    ``occupancy_sum`` accumulates (active rows / total rows) per decode
    step (the engine's running sum; a fixed batch contributes its useful
    fraction once per step), so occupancy is the mean over decode steps.
    """
    ttfts: List[float] = [float(t) for t in ttfts_s]
    return {
        "tokens_per_sec": useful_tokens / max(wall_s, 1e-9),
        "decode_tokens_per_sec": decode_tokens / max(decode_wall_s, 1e-9),
        "ttft_mean_s": (sum(ttfts) / len(ttfts)) if ttfts else 0.0,
        "occupancy": (occupancy_sum / decode_steps) if decode_steps else 0.0,
        "useful_tokens": int(useful_tokens),
        "wall_s": float(wall_s),
        "decode_steps": int(decode_steps),
    }
