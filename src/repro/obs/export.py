"""Chrome-trace/Perfetto JSON export of a ``Tracer``'s event stream.

Open ``chrome://tracing`` (or https://ui.perfetto.dev) and load the file:
tracks become processes (one row group per replica / controller / engine),
lanes become threads (per-request lanes, step lanes, membership lanes).

Determinism contract: the export is a pure function of the recorded event
stream — pid/tid ids are assigned in first-appearance order, keys are
sorted, floats are emitted by ``repr`` via ``json.dumps`` — so two
identical runs (same workload, same fault schedule, same injected clock)
produce BYTE-IDENTICAL files.  That is a tested invariant, which is what
makes committed traces diffable evidence rather than screenshots.

Timestamps: trace clocks are in run-native units (engine iterations,
fleet ticks, or ``ManualClock`` seconds).  Chrome's ``ts`` field is
microseconds, so one clock unit maps to ``time_scale`` microseconds
(default 1000 — a tick renders as a millisecond, comfortably zoomable).
"""

from __future__ import annotations

import json
from typing import Dict, List, Tuple

__all__ = ["to_chrome_events", "to_chrome_json", "write_chrome_trace"]

_PH_MAP = {"B": "B", "E": "E", "i": "i", "C": "C"}


def to_chrome_events(tracer, time_scale: float = 1000.0) -> List[dict]:
    """Tracer events -> Chrome trace-event dicts (list form)."""
    pids: Dict[str, int] = {}
    tids: Dict[Tuple[str, str], int] = {}
    out: List[dict] = []
    for ev in tracer.events:
        track, lane = ev["track"], ev["lane"]
        if track not in pids:
            pids[track] = len(pids) + 1
            out.append({"ph": "M", "name": "process_name",
                        "pid": pids[track], "tid": 0,
                        "args": {"name": track}})
        if (track, lane) not in tids:
            tids[(track, lane)] = len(tids) + 1
            out.append({"ph": "M", "name": "thread_name",
                        "pid": pids[track], "tid": tids[(track, lane)],
                        "args": {"name": lane}})
        rec = {"ph": _PH_MAP[ev["ph"]], "name": ev["name"],
               "pid": pids[track], "tid": tids[(track, lane)],
               "ts": ev["ts"] * time_scale}
        if ev["ph"] == "i":
            rec["s"] = "t"               # thread-scoped instant
        if ev["args"]:
            rec["args"] = ev["args"]
        out.append(rec)
    return out


def to_chrome_json(tracer, time_scale: float = 1000.0) -> str:
    """Byte-deterministic Chrome trace JSON (object form)."""
    doc = {"traceEvents": to_chrome_events(tracer, time_scale),
           "displayTimeUnit": "ms"}
    return json.dumps(doc, sort_keys=True, separators=(",", ":"))


def write_chrome_trace(tracer, path, time_scale: float = 1000.0) -> str:
    text = to_chrome_json(tracer, time_scale)
    with open(path, "w") as f:
        f.write(text)
    return str(path)
