"""Plan-vs-actual drift: score a live run against its ``PartitionPlan``.

The §4 equality-based split predicts per-node finish times (and the
overlap objective a ``max(comm, compute)`` variant); the LBP byte model
predicts link volumes.  Static plans hold only while the measured speeds
hold — Beaumont et al. show they drift under real platform noise — so
this module turns "how far is reality from the plan" into one normalized
gauge, the trigger signal ``runtime.rebalance`` re-planning (and ROADMAP
item 5's dynamic corrector) consumes:

  drift_i = |observed_i - predicted_i| / predicted makespan

An UNDISTURBED run is not expected to hit zero: integer adjustment moves
each node's share up to one quantum off the real-valued equal-finish
optimum, so ``tolerance()`` prices exactly that — the worst per-node
finish shift one quantum of load can cause.  A drift gauge within
tolerance means "the run matches the plan as closely as an integer split
can"; past it means the platform moved and the plan is stale.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from ..plan.ir import PartitionPlan

__all__ = ["DriftMonitor", "drift_fractions"]


def drift_fractions(predicted: Sequence[float],
                    observed: Sequence[float]) -> np.ndarray:
    """Per-node |observed - predicted| normalized by the predicted
    makespan (NOT per-node predictions: a near-zero-share node would
    otherwise blow up the ratio while being irrelevant to the finish)."""
    pred = np.asarray(predicted, dtype=np.float64)
    obs = np.asarray(observed, dtype=np.float64)
    if pred.shape != obs.shape:
        raise ValueError(
            f"predicted and observed describe different node sets: "
            f"{pred.shape} vs {obs.shape}")
    scale = max(float(pred.max(initial=0.0)), 1e-12)
    return np.abs(obs - pred) / scale


class DriftMonitor:
    """Scores observed finishes/shares against one plan's predictions.

    ``overlap=True`` scores against ``finish_times_overlap`` (the
    streamed plane's max(comm, compute) prediction) when the plan
    carries it.
    """

    def __init__(self, plan: PartitionPlan, *, overlap: bool = False,
                 metrics=None, gauge_name: str = "plan_drift"):
        self.plan = plan
        pred = (plan.finish_times_overlap
                if overlap and plan.finish_times_overlap is not None
                else plan.finish_times)
        self.predicted = np.asarray(pred, dtype=np.float64)
        self.metrics = metrics
        self.gauge_name = gauge_name
        self.last_drift: Optional[float] = None

    # -- the quantum tolerance ------------------------------------------
    def tolerance(self) -> float:
        """Largest normalized finish shift one quantum of load causes:
        quantum * max per-unit service time / predicted makespan.  The
        per-unit time of node i is recovered from the plan itself
        (finish_i / k_i over loaded nodes), so the tolerance needs no
        access to the solver's raw ``w``."""
        loaded = self.plan.k > 0
        if not loaded.any():
            return 0.0
        per_unit = self.predicted[loaded] / self.plan.k[loaded]
        scale = max(float(self.predicted[loaded].max()), 1e-12)
        return float(self.plan.quantum) * float(per_unit.max()) / scale

    def share_tolerance(self) -> float:
        """Quantization tolerance in SHARE-FRACTION space — the scale
        ``observe_shares`` drift lives on: integer adjustment moves each
        node at most one quantum off the real optimum, i.e. quantum/load
        of share fraction.  ``tolerance()`` is the finish-time-space
        counterpart for ``observe_finish`` (one quantum on the slowest
        node can shift its finish much further than its share)."""
        return float(self.plan.quantum) / max(int(self.plan.load), 1)

    # -- observation surfaces -------------------------------------------
    def observe_finish(self, observed: Sequence[float]) -> float:
        """Record observed per-node finish times; returns (and gauges)
        the max normalized drift."""
        d = drift_fractions(self.predicted, observed)
        return self._record(float(d.max(initial=0.0)))

    def observe_shares(self, observed_work: Sequence[float]) -> float:
        """Record observed per-node work (any proportional unit: tokens
        served, layers multiplied) against the plan's share fractions —
        the serving-plane signal, where "finish time" is continuous
        throughput rather than a single makespan."""
        work = np.asarray(observed_work, dtype=np.float64)
        if work.shape != self.plan.k.shape:
            raise ValueError(
                f"observed work describes {work.shape[0]} nodes, plan has "
                f"{self.plan.p}")
        total = float(work.sum())
        obs_frac = work / total if total > 0 else np.zeros_like(work)
        d = np.abs(obs_frac - self.plan.fractions())
        return self._record(float(d.max(initial=0.0)))

    def _record(self, drift: float) -> float:
        self.last_drift = drift
        if self.metrics is not None:
            self.metrics.gauge(self.gauge_name).set(drift)
        return drift

    # -- the re-plan trigger --------------------------------------------
    def should_replan(self, threshold: Optional[float] = None) -> bool:
        """True once observed drift exceeds ``threshold`` (default: the
        quantum tolerance — anything beyond what integer adjustment can
        explain is platform movement)."""
        if self.last_drift is None:
            return False
        t = self.tolerance() if threshold is None else float(threshold)
        return self.last_drift > t
