"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

Nothing here allocates: shapes come from ``jax.eval_shape`` over the init
functions, and the dry-run lowers against these structs (the shannon/kernels
pattern: weak-type-correct, shardable, no device memory).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import get_config
from ..configs.shapes import SHAPES, ShapeCell
from ..models import transformer as T
from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig
from ..sharding.rules import Rules, make_rules
from ..train.step import (batch_specs, default_grad_accum, init_train_state,
                          make_train_step, train_state_specs)
from ..serve.step import make_decode_step, make_prefill_step


class CellPlan(NamedTuple):
    """Everything the dry-run needs to lower one (arch x shape) cell."""
    arch: str
    shape: str
    cfg: ModelConfig
    rules: Rules
    fn: Any                    # callable to jit
    args: Tuple[Any, ...]      # ShapeDtypeStructs
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate: Tuple[int, ...] = ()


def _structs(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _as_bf16(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype), tree)


def _shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def token_split(cfg: ModelConfig, seq_len: int) -> int:
    """Tokens per row once the frontend prefix is carved out of seq_len."""
    return seq_len - cfg.prefix_len


def make_plan(arch: str, shape: str, mesh: Mesh,
              profile_override: Optional[str] = None,
              grad_accum: Optional[int] = None) -> CellPlan:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    B, S = cell.global_batch, cell.seq_len

    if cell.kind == "train":
        profile = profile_override or "train"
        rules = make_rules(profile, mesh)
        ga = grad_accum or default_grad_accum(cfg)
        opt_cfg = AdamWConfig()
        step = make_train_step(cfg, rules, opt_cfg, grad_accum=ga)

        state_shapes = jax.eval_shape(
            functools.partial(init_train_state, cfg), jax.random.PRNGKey(0))
        state_specs = train_state_specs(cfg, rules)
        S_tok = token_split(cfg, S)
        batch = {"tokens": jax.ShapeDtypeStruct((B, S_tok), jnp.int32)}
        if cfg.frontend != "none":
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
        b_specs = batch_specs(cfg, rules)

        args = (state_shapes, batch)
        in_sh = (_shardings(mesh, state_specs), _shardings(mesh, b_specs))
        out_sh = (_shardings(mesh, state_specs),
                  jax.tree.map(lambda _: NamedSharding(mesh, P()),
                               {"loss": 0, "grad_norm": 0, "lr": 0}))
        return CellPlan(arch, shape, cfg, rules, step, args, in_sh, out_sh,
                        donate=(0,))

    profile = profile_override or ("long" if shape == "long_500k" else cell.kind)
    rules = make_rules(profile, mesh)
    params = _as_bf16(jax.eval_shape(
        functools.partial(T.init_params, cfg), jax.random.PRNGKey(0)))
    p_specs = T.param_specs(cfg, rules)
    cache = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    c_specs = T.cache_specs(cfg, rules)

    if cell.kind == "prefill":
        step = make_prefill_step(cfg, rules)
        S_tok = token_split(cfg, S)
        tokens = jax.ShapeDtypeStruct((B, S_tok), jnp.int32)
        args = [params, tokens, cache]
        in_sh = [_shardings(mesh, p_specs),
                 NamedSharding(mesh, rules.spec("batch", None)),
                 _shardings(mesh, c_specs)]
        if cfg.frontend != "none":
            args.append(jax.ShapeDtypeStruct(
                (B, cfg.prefix_len, cfg.d_model), jnp.bfloat16))
            in_sh.append(NamedSharding(mesh, rules.spec("batch", None, None)))
        out_sh = (_shardings(mesh, c_specs),
                  NamedSharding(mesh, rules.spec("batch", "vocab")))
        return CellPlan(arch, shape, cfg, rules, step, tuple(args),
                        tuple(in_sh), out_sh, donate=(2,))

    # decode (decode_32k / long_500k): one token against a seq_len cache
    step = make_decode_step(cfg, rules)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    args = (params, token, pos, cache)
    in_sh = (_shardings(mesh, p_specs),
             NamedSharding(mesh, rules.spec("batch", None)),
             NamedSharding(mesh, rules.spec("batch")),
             _shardings(mesh, c_specs))
    out_sh = (NamedSharding(mesh, rules.spec("batch")),
              NamedSharding(mesh, rules.spec("batch", None, "vocab")),
              _shardings(mesh, c_specs))
    return CellPlan(arch, shape, cfg, rules, step, args, in_sh, out_sh,
                    donate=(3,))
