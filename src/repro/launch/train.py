"""Training launcher.

Full-scale (dry-run container: compiles only; real pod: runs):
  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_3b --demo

``--demo`` runs an actual reduced-config training on the local devices
(the end-to-end driver required by deliverable (b)): synthetic pipeline,
AdamW, async checkpoints, resume, loss printed per step.
"""

from __future__ import annotations

import argparse

import jax

from ..configs import ARCH_IDS, get_config, get_reduced
from ..runtime.trainer import Trainer, TrainerConfig
from ..sharding.rules import Rules, make_rules
from .mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3_2_3b")
    ap.add_argument("--demo", action="store_true",
                    help="reduced config on local devices (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--overlap", action="store_true",
                    help="overlapped layer-streaming plane: explicit "
                         "shard_map LBP with stream_* aggregation "
                         "(sequence-parallel train_sp profile)")
    ap.add_argument("--bidir", action="store_true",
                    help="bidirectional half-rings on the streamed "
                         "plane (stream_*_bidir modes: same bytes, "
                         "ceil((p-1)/2) sequential hops); implies "
                         "--overlap")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the "
                         "training run (open at ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot as JSON")
    args = ap.parse_args()

    if args.bidir:
        args.overlap = True
    if args.overlap:
        from ..models.tuning import set_tuning
        set_tuning(explicit_lbp_scatter=True, overlap_streaming=True,
                   overlap_bidir=args.bidir)

    if args.demo:
        from ..obs import MetricsRegistry, Tracer, write_chrome_trace
        cfg = get_reduced(args.arch)
        rules = Rules.null()
        if not args.resume:
            import shutil
            shutil.rmtree(args.ckpt_dir, ignore_errors=True)
        tracer = Tracer() if args.trace_out else None
        metrics = MetricsRegistry() if args.metrics_out else None
        tr = Trainer(cfg, rules,
                     TrainerConfig(total_steps=args.steps,
                                   checkpoint_dir=args.ckpt_dir,
                                   grad_accum=args.grad_accum,
                                   checkpoint_every=10),
                     batch_size=args.batch, seq_len=args.seq,
                     tracer=tracer, metrics=metrics)
        hist = tr.run()
        if tracer is not None:
            print(f"trace:   {write_chrome_trace(tracer, args.trace_out)}")
        if metrics is not None:
            print(f"metrics: {metrics.write_json(args.metrics_out)}")
        for m in hist:
            if m["step"] % 5 == 0 or m["step"] == len(hist) - 1:
                print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
                      f"gnorm {m['grad_norm']:.3f}  lr {m['lr']:.2e}  "
                      f"{m['dt']*1e3:.0f} ms")
        first, last = hist[0]["loss"], hist[-1]["loss"]
        print(f"loss: {first:.4f} -> {last:.4f} "
              f"({'DOWN' if last < first else 'FLAT'})")
        return

    # production path: build the pod mesh and compile the step
    mesh = make_production_mesh()
    rules = make_rules("train_sp" if args.overlap else "train", mesh)
    cfg = get_config(args.arch)
    print(f"arch={cfg.name}  N={cfg.n_params()/1e9:.2f}B  mesh={mesh.shape}")
    print("production launch requires a real pod; use launch.dryrun to "
          "verify the compiled step on this host.")


if __name__ == "__main__":
    main()
