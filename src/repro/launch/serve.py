"""Serving launcher: continuous-batching engine over the reduced configs.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_3b --demo

``--demo`` serves a batch of synthetic staggered-arrival prompts through
``serve.engine.ServingEngine`` on local devices and reports prefill
latency (time-to-first-token) separately from decode throughput.
``--paged`` switches the KV cache to the paged plane (fixed-size token
pages + per-request page tables; admission gated on free pages) —
outputs are token-identical to the slot plane by construction.
``--oracle`` additionally replays every request through the reference
``greedy_generate`` and verifies the engine reproduced it token-for-token.
``--fleet N`` serves the same workload through N heterogeneous replicas
behind the async fleet front-end (repro.fleet); ``--kill-at T`` kills
one replica at fleet tick T and ``--join-at T`` joins a fresh one — the
oracle check holds under any such schedule (exactly-once requeue).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import ARCH_IDS, get_reduced
from ..models import transformer as T
from ..obs import MetricsRegistry, Tracer, write_chrome_trace
from ..serve import (EngineConfig, PagedTransformerModel, ServingEngine,
                     TransformerModel, greedy_generate)
from ..sharding.rules import Rules


def _positive_int(flag: str):
    def parse(text: str) -> int:
        try:
            value = int(text)
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"{flag} expects an integer, got {text!r}") from None
        if value < 1:
            raise argparse.ArgumentTypeError(
                f"{flag} must be >= 1, got {value} (the engine cannot "
                f"serve an empty batch or generate zero tokens)")
        return value
    return parse


def build_workload(args, vocab_size: int):
    """Synthetic staggered trace: prompt lengths vary below --prompt-len."""
    from ..serve.engine import synthetic_workload
    lens = sorted({max(2, args.prompt_len // 4), max(2, args.prompt_len // 2),
                   max(2, (3 * args.prompt_len) // 4), args.prompt_len})
    return synthetic_workload(args.batch, vocab_size, lens=lens,
                              news=(args.max_new,),
                              stagger=1.0 / max(1, args.slots))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3_2_3b")
    ap.add_argument("--demo", action="store_true",
                    help="serve the synthetic staggered workload (also the "
                         "default behaviour; kept for script compatibility)")
    ap.add_argument("--batch", type=_positive_int("--batch"), default=4)
    ap.add_argument("--prompt-len", type=_positive_int("--prompt-len"),
                    default=32)
    ap.add_argument("--max-new", type=_positive_int("--max-new"), default=16)
    ap.add_argument("--slots", type=_positive_int("--slots"), default=4,
                    help="continuous-batching cache slots")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV plane: page-table cache, admission "
                         "gated on free pages instead of free slots")
    ap.add_argument("--page-size", type=_positive_int("--page-size"),
                    default=8, help="tokens per KV page (with --paged)")
    ap.add_argument("--pages", type=_positive_int("--pages"), default=None,
                    help="physical page budget (default: slot-equivalent)")
    ap.add_argument("--oracle", action="store_true",
                    help="verify every output against greedy_generate")
    ap.add_argument("--fleet", type=_positive_int("--fleet"), default=None,
                    help="serve through N replicas behind the async "
                         "fleet front-end instead of one engine")
    ap.add_argument("--kill-at", type=_positive_int("--kill-at"),
                    default=None,
                    help="fleet tick at which to kill one replica "
                         "(requires --fleet >= 2)")
    ap.add_argument("--join-at", type=_positive_int("--join-at"),
                    default=None,
                    help="fleet tick at which a fresh replica joins "
                         "(requires --fleet)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(open at ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot as JSON")
    args = ap.parse_args(argv)
    if (args.kill_at or args.join_at) and not args.fleet:
        ap.error("--kill-at/--join-at need --fleet")
    if args.kill_at and args.fleet < 2:
        ap.error("--kill-at needs --fleet >= 2 (a survivor must exist)")

    cfg = get_reduced(args.arch)
    rules = Rules.null()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    workload = build_workload(args, cfg.vocab_size)

    if args.fleet:
        return _serve_fleet(args, params, cfg, rules, workload)

    model_cls = PagedTransformerModel if args.paged else TransformerModel
    model = model_cls(params, cfg, rules)
    tracer = Tracer() if args.trace_out else None
    metrics = MetricsRegistry() if args.metrics_out else None
    engine = ServingEngine(model, EngineConfig(
        n_slots=args.slots, max_prompt_len=args.prompt_len,
        max_new_cap=args.max_new,
        cache_len=args.prompt_len + args.max_new,
        page_size=args.page_size if args.paged else None,
        n_pages=args.pages if args.paged else None),
        tracer=tracer, metrics=metrics)
    for prompt, max_new, arrival in workload:
        engine.submit(prompt, max_new, arrival=arrival)
    report = engine.run()
    _write_obs(args, tracer, metrics)

    plane = (f"paged(page_size={args.page_size}, "
             f"pages={engine.pool.n_pages})" if args.paged else "slots")
    print(f"arch={cfg.name}  requests={args.batch}  slots={args.slots}  "
          f"max_prompt={args.prompt_len}  new={args.max_new}  "
          f"cache={plane}")
    print(f"prefill: {report.prefill_count} prompts, "
          f"{report.prefill_tokens} tokens in {report.prefill_wall:.2f}s  "
          f"(TTFT mean {report.ttft_mean*1e3:.0f}ms)")
    print(f"decode:  {report.decode_tokens} tokens in "
          f"{report.decode_wall:.2f}s "
          f"({report.decode_tokens_per_sec:.1f} tok/s, "
          f"occupancy {report.occupancy:.2f})")
    print(f"total:   {report.total_tokens} tokens in {report.wall:.2f}s "
          f"({report.tokens_per_sec:.1f} tok/s aggregate)")
    if args.paged:
        print(f"pages:   occupancy {report.page_occupancy:.2f} "
              f"(mean used/total over decode steps)")
    first = report.completed[0]
    print("generated token ids (first request):",
          list(map(int, first[:16])))

    if args.oracle:
        for rid, (prompt, max_new, _) in enumerate(workload):
            ref = np.asarray(greedy_generate(
                params, cfg, rules, np.asarray(prompt)[None],
                max_new=max_new))[0]
            got = report.completed[rid]
            assert np.array_equal(ref, got), (
                f"request {rid}: engine {got} != oracle {ref}")
        print(f"oracle check: {len(workload)} requests token-identical")


def _write_obs(args, tracer, metrics):
    """Export the observability artifacts the flags asked for."""
    if tracer is not None:
        print(f"trace:   {write_chrome_trace(tracer, args.trace_out)} "
              f"({len(tracer)} events; open at ui.perfetto.dev)")
    if metrics is not None:
        print(f"metrics: {metrics.write_json(args.metrics_out)}")


def _serve_fleet(args, params, cfg, rules, workload):
    """Serve the workload through N replicas behind the async front-end,
    with optional mid-run kill/join (elastic rescale demo)."""
    from ..fleet import FaultPlan, FleetController, FleetFrontend, Replica

    tracer = Tracer() if args.trace_out else None
    metrics = MetricsRegistry() if args.metrics_out else None
    ec = EngineConfig(
        n_slots=args.slots, max_prompt_len=args.prompt_len,
        max_new_cap=args.max_new,
        cache_len=args.prompt_len + args.max_new,
        page_size=args.page_size if args.paged else None,
        n_pages=args.pages if args.paged else None)

    def make_model():
        cls = PagedTransformerModel if args.paged else TransformerModel
        return cls(params, cfg, rules)

    # a slot-plane TransformerModel is stateless wrt the cache (it is
    # passed in) so ONE adapter serves every replica — one compilation
    # set for the whole fleet; the paged adapter binds its page pool and
    # needs one instance per replica
    shared = None if args.paged else make_model()
    rates = [1.0, 2.0, 0.5, 1.5]   # heterogeneous fleet, cycled
    replicas = [Replica(f"r{i}", shared if shared is not None
                        else make_model(), ec,
                        rate=rates[i % len(rates)],
                        tracer=tracer, metrics=metrics)
                for i in range(args.fleet)]
    controller = FleetController(replicas, tracer=tracer, metrics=metrics)
    if args.kill_at:
        controller.schedule_kill("r0", at_tick=args.kill_at)
    if args.join_at:
        controller.schedule_join(
            Replica(f"r{args.fleet}", shared if shared is not None
                    else make_model(), ec, rate=rates[0],
                    fault=FaultPlan(), tracer=tracer, metrics=metrics),
            at_tick=args.join_at)
    frontend = FleetFrontend(controller, max_pending=4 * args.fleet)
    for prompt, max_new, arrival in workload:
        controller.submit(prompt, max_new, arrival=arrival)
    report = asyncio_run_drain(frontend)
    _write_obs(args, tracer, metrics)

    print(f"arch={cfg.name}  requests={args.batch}  fleet={args.fleet} "
          f"replicas  slots/replica={args.slots}  "
          f"plane={'paged' if args.paged else 'slots'}")
    print(f"ticks={report.ticks}  completed={report.n_completed}  "
          f"requeues={report.requeues}  kills={report.kills}  "
          f"joins={report.joins}")
    for name in sorted(report.occupancy):
        print(f"  {name}: occupancy {report.occupancy[name]:.2f}  "
              f"decode_tokens {report.decode_tokens[name]}")
    if args.oracle:
        for rid, (prompt, max_new, _) in enumerate(workload):
            ref = np.asarray(greedy_generate(
                params, cfg, rules, np.asarray(prompt)[None],
                max_new=max_new))[0]
            got = report.completed[rid]
            assert np.array_equal(ref, got), (
                f"request {rid}: fleet {got} != oracle {ref}")
        print(f"oracle check: {len(workload)} requests token-identical "
              f"under the kill/join schedule")


def asyncio_run_drain(frontend):
    import asyncio
    return asyncio.run(frontend.drain())


if __name__ == "__main__":
    main()
