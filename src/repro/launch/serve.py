"""Serving launcher: continuous-batching engine over the reduced configs.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_3b --demo

``--demo`` serves a batch of synthetic staggered-arrival prompts through
``serve.engine.ServingEngine`` on local devices and reports prefill
latency (time-to-first-token) separately from decode throughput.
``--paged`` switches the KV cache to the paged plane (fixed-size token
pages + per-request page tables; admission gated on free pages) —
outputs are token-identical to the slot plane by construction.
``--oracle`` additionally replays every request through the reference
``greedy_generate`` and verifies the engine reproduced it token-for-token.
``--fleet N`` serves the same workload through N heterogeneous replicas
behind the async fleet front-end (repro.fleet); ``--kill-at T`` kills
one replica at fleet tick T and ``--join-at T`` joins a fresh one — the
oracle check holds under any such schedule (exactly-once requeue).

Fault-domain flags (all tick-addressed, all deterministic):
``--transient-at T`` injects a transient step failure on one replica
(clearing after ``--transient-for`` ticks) to exercise the controller's
retry/backoff path; ``--checkpoint-every N`` snapshots a demo state
dict every N ticks into ``--checkpoint-dir`` and restores it re-sliced
onto the new plan on every kill/join; ``--min-alive K`` sets the
graceful-degradation floor (the front-end rejects with a typed
``FleetDegraded`` + retry-after below it); ``--drain-deadline T``
bounds the drain in ticks so a wedged schedule fails loud, never hangs.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import ARCH_IDS, get_reduced
from ..models import transformer as T
from ..obs import MetricsRegistry, Tracer, write_chrome_trace
from ..serve import (EngineConfig, PagedTransformerModel, ServingEngine,
                     TransformerModel, greedy_generate)
from ..sharding.rules import Rules


def _positive_int(flag: str):
    def parse(text: str) -> int:
        try:
            value = int(text)
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"{flag} expects an integer, got {text!r}") from None
        if value < 1:
            raise argparse.ArgumentTypeError(
                f"{flag} must be >= 1, got {value} (the engine cannot "
                f"serve an empty batch or generate zero tokens)")
        return value
    return parse


def build_workload(args, vocab_size: int):
    """Synthetic staggered trace: prompt lengths vary below --prompt-len.
    With --prefix-sharing the trace is template-heavy instead (shared
    system-prompt prefixes + random suffixes) so sharing has something
    to share."""
    from ..serve.engine import shared_prefix_workload, synthetic_workload
    if getattr(args, "prefix_sharing", False):
        template_len = max(args.page_size, (args.prompt_len // 2
                                            // args.page_size)
                           * args.page_size)
        suffix_max = max(2, args.prompt_len - template_len)
        # varied decode lengths stagger retirements so same-template
        # requests overlap in flight — a single max_new retires whole
        # admission groups in lockstep and the creator's pages hit
        # refcount zero (index eviction) before the next match arrives
        news = tuple(sorted({max(1, args.max_new // 4),
                             max(1, args.max_new // 2), args.max_new}))
        return shared_prefix_workload(
            args.batch, vocab_size,
            n_templates=max(1, min(4, args.batch // 3)),
            template_len=template_len,
            suffix_lens=tuple(sorted({max(2, suffix_max // 2), suffix_max})),
            news=news, stagger=1.0 / max(1, args.slots))
    lens = sorted({max(2, args.prompt_len // 4), max(2, args.prompt_len // 2),
                   max(2, (3 * args.prompt_len) // 4), args.prompt_len})
    return synthetic_workload(args.batch, vocab_size, lens=lens,
                              news=(args.max_new,),
                              stagger=1.0 / max(1, args.slots))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3_2_3b")
    ap.add_argument("--demo", action="store_true",
                    help="serve the synthetic staggered workload (also the "
                         "default behaviour; kept for script compatibility)")
    ap.add_argument("--batch", type=_positive_int("--batch"), default=4)
    ap.add_argument("--prompt-len", type=_positive_int("--prompt-len"),
                    default=32)
    ap.add_argument("--max-new", type=_positive_int("--max-new"), default=16)
    ap.add_argument("--slots", type=_positive_int("--slots"), default=4,
                    help="continuous-batching cache slots")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV plane: page-table cache, admission "
                         "gated on free pages instead of free slots")
    ap.add_argument("--page-size", type=_positive_int("--page-size"),
                    default=8, help="tokens per KV page (with --paged)")
    ap.add_argument("--pages", type=_positive_int("--pages"), default=None,
                    help="physical page budget (default: slot-equivalent)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="share matching prompt-prefix pages across "
                         "requests (refcounted, copy-on-write; requires "
                         "--paged) and serve a template-heavy workload")
    ap.add_argument("--oracle", action="store_true",
                    help="verify every output against greedy_generate")
    ap.add_argument("--fleet", type=_positive_int("--fleet"), default=None,
                    help="serve through N replicas behind the async "
                         "fleet front-end instead of one engine")
    ap.add_argument("--kill-at", type=_positive_int("--kill-at"),
                    default=None,
                    help="fleet tick at which to kill one replica "
                         "(requires --fleet >= 2)")
    ap.add_argument("--join-at", type=_positive_int("--join-at"),
                    default=None,
                    help="fleet tick at which a fresh replica joins "
                         "(requires --fleet)")
    ap.add_argument("--transient-at", type=_positive_int("--transient-at"),
                    default=None,
                    help="replica tick at which one replica starts "
                         "raising transient step errors (requires "
                         "--fleet; exercises retry/backoff)")
    ap.add_argument("--transient-for",
                    type=_positive_int("--transient-for"), default=2,
                    help="how many replica ticks the transient lasts "
                         "before clearing (with --transient-at)")
    ap.add_argument("--max-retries", type=_positive_int("--max-retries"),
                    default=3,
                    help="transient retries before the controller "
                         "escalates to the kill/requeue path")
    ap.add_argument("--checkpoint-every",
                    type=_positive_int("--checkpoint-every"), default=None,
                    help="fleet ticks between sharded snapshots; also "
                         "enables restore-on-rescale (requires --fleet)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="snapshot directory (default: a temp dir, "
                         "with --checkpoint-every)")
    ap.add_argument("--min-alive", type=_positive_int("--min-alive"),
                    default=1,
                    help="graceful-degradation floor: below this many "
                         "live replicas the front-end rejects with "
                         "FleetDegraded + retry-after")
    ap.add_argument("--drain-deadline",
                    type=_positive_int("--drain-deadline"), default=None,
                    help="max fleet ticks to drain before raising "
                         "FleetDegraded instead of hanging")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(open at ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot as JSON")
    args = ap.parse_args(argv)
    if ((args.kill_at or args.join_at or args.transient_at
         or args.checkpoint_every or args.drain_deadline) and not args.fleet):
        ap.error("--kill-at/--join-at/--transient-at/--checkpoint-every/"
                 "--drain-deadline need --fleet")
    if args.kill_at and args.fleet < 2:
        ap.error("--kill-at needs --fleet >= 2 (a survivor must exist)")
    if args.checkpoint_dir and not args.checkpoint_every:
        ap.error("--checkpoint-dir needs --checkpoint-every")
    if args.prefix_sharing and not args.paged:
        ap.error("--prefix-sharing needs --paged (slot rows have no page "
                 "granularity to share)")

    cfg = get_reduced(args.arch)
    rules = Rules.null()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    workload = build_workload(args, cfg.vocab_size)

    if args.fleet:
        return _serve_fleet(args, params, cfg, rules, workload)

    model_cls = PagedTransformerModel if args.paged else TransformerModel
    model = model_cls(params, cfg, rules)
    tracer = Tracer() if args.trace_out else None
    metrics = MetricsRegistry() if args.metrics_out else None
    engine = ServingEngine(model, EngineConfig(
        n_slots=args.slots, max_prompt_len=args.prompt_len,
        max_new_cap=args.max_new,
        cache_len=args.prompt_len + args.max_new,
        page_size=args.page_size if args.paged else None,
        n_pages=args.pages if args.paged else None,
        prefix_sharing=args.prefix_sharing),
        tracer=tracer, metrics=metrics)
    for prompt, max_new, arrival in workload:
        engine.submit(prompt, max_new, arrival=arrival)
    report = engine.run()
    _write_obs(args, tracer, metrics)

    plane = (f"paged(page_size={args.page_size}, "
             f"pages={engine.pool.n_pages})" if args.paged else "slots")
    print(f"arch={cfg.name}  requests={args.batch}  slots={args.slots}  "
          f"max_prompt={args.prompt_len}  new={args.max_new}  "
          f"cache={plane}")
    print(f"prefill: {report.prefill_count} prompts, "
          f"{report.prefill_tokens} tokens in {report.prefill_wall:.2f}s  "
          f"(TTFT mean {report.ttft_mean*1e3:.0f}ms)")
    print(f"decode:  {report.decode_tokens} tokens in "
          f"{report.decode_wall:.2f}s "
          f"({report.decode_tokens_per_sec:.1f} tok/s, "
          f"occupancy {report.occupancy:.2f})")
    print(f"total:   {report.total_tokens} tokens in {report.wall:.2f}s "
          f"({report.tokens_per_sec:.1f} tok/s aggregate)")
    if args.paged:
        print(f"pages:   occupancy {report.page_occupancy:.2f} "
              f"(mean used/total over decode steps)")
    if args.prefix_sharing:
        print(f"sharing: {engine.pool.n_shared_attached} page attaches, "
              f"max refcount {engine.pool.max_refcount}, "
              f"peak pages {engine.pool.peak_used_pages}")
    first = report.completed[0]
    print("generated token ids (first request):",
          list(map(int, first[:16])))

    if args.oracle:
        for rid, (prompt, max_new, _) in enumerate(workload):
            ref = np.asarray(greedy_generate(
                params, cfg, rules, np.asarray(prompt)[None],
                max_new=max_new))[0]
            got = report.completed[rid]
            assert np.array_equal(ref, got), (
                f"request {rid}: engine {got} != oracle {ref}")
        print(f"oracle check: {len(workload)} requests token-identical")


def _write_obs(args, tracer, metrics):
    """Export the observability artifacts the flags asked for."""
    if tracer is not None:
        print(f"trace:   {write_chrome_trace(tracer, args.trace_out)} "
              f"({len(tracer)} events; open at ui.perfetto.dev)")
    if metrics is not None:
        print(f"metrics: {metrics.write_json(args.metrics_out)}")


def _serve_fleet(args, params, cfg, rules, workload):
    """Serve the workload through N replicas behind the async front-end,
    with optional mid-run kill/join/transient faults, live
    checkpoint-recovery rescale, and graceful-degradation floors."""
    import contextlib
    import tempfile

    from ..fleet import (FaultPlan, FleetController, FleetFrontend, Replica,
                         RetryPolicy)

    tracer = Tracer() if args.trace_out else None
    metrics = MetricsRegistry() if args.metrics_out else None
    ec = EngineConfig(
        n_slots=args.slots, max_prompt_len=args.prompt_len,
        max_new_cap=args.max_new,
        cache_len=args.prompt_len + args.max_new,
        page_size=args.page_size if args.paged else None,
        n_pages=args.pages if args.paged else None,
        prefix_sharing=args.prefix_sharing)

    def make_model():
        cls = PagedTransformerModel if args.paged else TransformerModel
        return cls(params, cfg, rules)

    # a slot-plane TransformerModel is stateless wrt the cache (it is
    # passed in) so ONE adapter serves every replica — one compilation
    # set for the whole fleet; the paged adapter binds its page pool and
    # needs one instance per replica
    shared = None if args.paged else make_model()
    rates = [1.0, 2.0, 0.5, 1.5]   # heterogeneous fleet, cycled
    # the transient lands on a replica --kill-at does NOT target, so the
    # two faults compose instead of shadowing each other
    transient_on = (f"r{min(1, args.fleet - 1)}"
                    if args.transient_at else None)

    def fault_for(name):
        if name != transient_on:
            return None
        return FaultPlan(transient_at=args.transient_at,
                         transient_for=args.transient_for)

    replicas = [Replica(f"r{i}", shared if shared is not None
                        else make_model(), ec,
                        rate=rates[i % len(rates)],
                        fault=fault_for(f"r{i}"),
                        tracer=tracer, metrics=metrics)
                for i in range(args.fleet)]

    with contextlib.ExitStack() as stack:
        ckpt_dir = ckpt_state = None
        if args.checkpoint_every:
            ckpt_dir = (args.checkpoint_dir or
                        stack.enter_context(
                            tempfile.TemporaryDirectory(prefix="fleet_ckpt_")))
            # a demo state dict sized to the controller's virtual load:
            # partitioned leaves carry one row per virtual-k unit, so
            # restore re-slices them by the new plan's integer shares
            ckpt_state = {
                "w": np.arange(1024 * 4, dtype=np.float32).reshape(1024, 4),
                "bias": np.arange(8, dtype=np.float32),
            }
        controller = FleetController(
            replicas, retry=RetryPolicy(max_retries=args.max_retries),
            min_alive=args.min_alive, checkpoint_dir=ckpt_dir,
            checkpoint_state=ckpt_state,
            checkpoint_every=args.checkpoint_every or 0,
            tracer=tracer, metrics=metrics)
        if args.kill_at:
            controller.schedule_kill("r0", at_tick=args.kill_at)
        if args.join_at:
            controller.schedule_join(
                Replica(f"r{args.fleet}", shared if shared is not None
                        else make_model(), ec, rate=rates[0],
                        fault=FaultPlan(), tracer=tracer, metrics=metrics),
                at_tick=args.join_at)
        frontend = FleetFrontend(controller, max_pending=4 * args.fleet)
        for prompt, max_new, arrival in workload:
            controller.submit(prompt, max_new, arrival=arrival)
        report = asyncio_run_drain(frontend, deadline=args.drain_deadline)
    _write_obs(args, tracer, metrics)

    print(f"arch={cfg.name}  requests={args.batch}  fleet={args.fleet} "
          f"replicas  slots/replica={args.slots}  "
          f"plane={'paged' if args.paged else 'slots'}")
    print(f"ticks={report.ticks}  completed={report.n_completed}  "
          f"requeues={report.requeues}  kills={report.kills}  "
          f"joins={report.joins}")
    if report.retries or report.restores or report.corrupt_shards:
        print(f"faults:  retries={report.retries}  "
              f"recoveries={report.recoveries}  "
              f"restores={report.restores}  "
              f"corrupt_shards_skipped={report.corrupt_shards}")
    for name in sorted(report.occupancy):
        print(f"  {name}: occupancy {report.occupancy[name]:.2f}  "
              f"decode_tokens {report.decode_tokens[name]}")
    if args.oracle:
        for rid, (prompt, max_new, _) in enumerate(workload):
            ref = np.asarray(greedy_generate(
                params, cfg, rules, np.asarray(prompt)[None],
                max_new=max_new))[0]
            got = report.completed[rid]
            assert np.array_equal(ref, got), (
                f"request {rid}: fleet {got} != oracle {ref}")
        print(f"oracle check: {len(workload)} requests token-identical "
              f"under the fault schedule")


def asyncio_run_drain(frontend, deadline=None):
    import asyncio
    return asyncio.run(frontend.drain(deadline=deadline))


if __name__ == "__main__":
    main()
