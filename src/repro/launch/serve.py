"""Serving launcher: batched prefill + greedy decode.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_3b --demo

``--demo`` serves the reduced config on local devices with a batch of
synthetic prompts (deliverable (b): runnable serving driver).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_reduced
from ..models import transformer as T
from ..serve.step import greedy_generate
from ..sharding.rules import Rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3_2_3b")
    ap.add_argument("--demo", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    rules = Rules.null()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    out = greedy_generate(params, cfg, rules, prompt, max_new=args.max_new)
    dt = time.time() - t0
    print(f"arch={cfg.name}  batch={args.batch}  prompt={args.prompt_len}  "
          f"new={args.max_new}  {dt:.2f}s "
          f"({args.batch*args.max_new/dt:.1f} tok/s)")
    print("generated token ids (first row):", list(map(int, out[0][:16])))


if __name__ == "__main__":
    main()
