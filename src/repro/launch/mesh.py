"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis is the
outer data-parallel dim crossing DCN (slower links — the scheduler plane
models it with a larger z, see core/network.SpeedProfile).

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types="auto")


def make_smoke_mesh():
    """Single-device mesh for CPU smoke paths (no named axes used)."""
    return make_mesh((1,), ("data",), axis_types="auto")


def device_count_required(multi_pod: bool) -> int:
    return 512 if multi_pod else 256
