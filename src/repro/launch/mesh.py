"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis is the
outer data-parallel dim crossing DCN.  The shape tuples live in
``repro.plan.topology.production_shape`` — the planning subsystem's
``production_topology()`` describes the same platform to the schedulers
(per-pod DCN trunks, near-zero ICI within), so the mesh the launcher
builds and the topology the planners solve can never drift apart.

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init;
``repro.plan`` is numpy/scipy-only).
"""

from __future__ import annotations

from ..compat import make_mesh
from ..plan.topology import production_shape


def make_production_mesh(*, multi_pod: bool = False):
    shape = production_shape(multi_pod)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types="auto")


def make_smoke_mesh():
    """Single-device mesh for CPU smoke paths (no named axes used)."""
    return make_mesh((1,), ("data",), axis_types="auto")


def device_count_required(multi_pod: bool) -> int:
    shape = production_shape(multi_pod)
    n = 1
    for d in shape:
        n *= d
    return n
