import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective artifacts.

This is the proof that the distribution config is coherent without real
hardware: ``.lower().compile()`` must succeed for the 16x16 (256-chip
single-pod) mesh AND the 2x16x16 (512-chip multi-pod) mesh for every cell.
Artifacts (bytes/device, HLO FLOPs, collective bytes) land in
``artifacts/dryrun/<mesh>/<arch>__<shape>.json`` and feed EXPERIMENTS.md
§Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_2_3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--profile train_sp]
"""

import argparse
import json
import pathlib
import traceback

import jax

from ..analysis.hlo_cost import analyze_hlo
from ..obs import clock as obs_clock
from ..compat import cost_analysis as compat_cost_analysis
from ..configs import ARCH_IDS
from ..configs.shapes import cells_for
from .input_specs import make_plan
from .mesh import make_production_mesh

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             profile: str | None = None, grad_accum: int | None = None,
             save: bool = True, tag: str = "", tuning: dict | None = None) -> dict:
    if tuning:
        from ..models.tuning import set_tuning
        set_tuning(**tuning)
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_plan(arch, shape, mesh, profile_override=profile,
                     grad_accum=grad_accum)

    t0 = obs_clock.wall_time()
    with mesh:
        jitted = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                         out_shardings=plan.out_shardings,
                         donate_argnums=plan.donate)
        lowered = jitted.lower(*plan.args)
        t_lower = obs_clock.wall_time() - t0
        compiled = lowered.compile()
        t_compile = obs_clock.wall_time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compat_cost_analysis(compiled)
    parsed = analyze_hlo(compiled.as_text())
    coll = parsed["collectives"]

    n_dev = mesh.devices.size
    result = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "profile": profile or "default",
        "grad_accum": grad_accum,
        "tag": tag,
        "n_devices": int(n_dev),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "bytes_per_device": {
            "argument": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak": int(getattr(mem, "peak_memory_in_bytes", 0) or
                        (getattr(mem, "argument_size_in_bytes", 0) +
                         getattr(mem, "temp_size_in_bytes", 0))),
        },
        # loop-aware (trip-count-multiplied) instruction-level parse:
        "hlo_flops": parsed["flops"],
        "hlo_bytes": parsed["hbm_bytes"],
        # raw XLA aggregates (NOT loop-multiplied; kept for cross-checking):
        "xla_flops_raw": float(cost.get("flops", 0.0)) if cost else 0.0,
        "xla_bytes_raw": float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
        "collectives": coll,
    }
    if save:
        sub = ARTIFACTS / result["mesh"]
        sub.mkdir(parents=True, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        (sub / f"{arch}__{shape}{suffix}.json").write_text(
            json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--profile", default=None)
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--tuning", default="",
                    help="comma list k=true/false for models.tuning flags")
    args = ap.parse_args()

    tuning = {}
    for kv in filter(None, args.tuning.split(",")):
        k, v = kv.split("=")
        tuning[k] = v.lower() in ("1", "true", "yes", "on")

    if args.all:
        cells = [(a, n) for a in ARCH_IDS for (n, _) in cells_for(a)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            label = f"{arch} x {shape} [{'2x16x16' if mp else '16x16'}]"
            try:
                r = run_cell(arch, shape, multi_pod=mp, profile=args.profile,
                             grad_accum=args.grad_accum, tag=args.tag,
                             tuning=tuning)
                peak = r["bytes_per_device"]["peak"] / 2**30
                print(f"OK   {label:55s} peak={peak:6.2f} GiB/dev "
                      f"flops={r['hlo_flops']:.3e} "
                      f"coll={r['collectives']['total_bytes']/2**30:.2f} GiB "
                      f"compile={r['compile_s']:.0f}s", flush=True)
            except Exception as e:
                failures.append((label, repr(e)))
                traceback.print_exc()
                print(f"FAIL {label}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for l, e in failures:
            print(" ", l, e[:200])
        raise SystemExit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
