"""Docs gate: every relative link and anchor in the markdown docs resolves.

    python tools/check_docs.py [--root .]

Checks ``README.md``, ``ROADMAP.md`` and ``docs/*.md`` for
``[text](target)`` links:

- relative file targets must exist on disk (external http(s)/mailto
  links are skipped — CI must not depend on the network);
- ``#anchor`` fragments (same-file or on a relative target) must match a
  heading in the target file under GitHub's slugification rules.

Exit status is non-zero with one line per broken link, so the CI step
fails loudly and the docs can never drift from the tree they describe.
"""

from __future__ import annotations

import argparse
import pathlib
import re
import sys

LINK_RE = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
EXTERNAL = ("http://", "https://", "mailto:")


def slugify(heading: str) -> str:
    """GitHub's markdown heading -> anchor id rule.

    Lowercase; inline-code backticks and markdown emphasis markers drop;
    anything that is not alphanumeric, space, hyphen or underscore drops;
    spaces become hyphens.
    """
    text = heading.strip().lower()
    text = text.replace("`", "").replace("*", "")
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(path: pathlib.Path) -> set[str]:
    anchors: set[str] = set()
    in_fence = False
    for line in path.read_text().splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if m:
            anchors.add(slugify(m.group(1)))
    return anchors


def doc_files(root: pathlib.Path) -> list[pathlib.Path]:
    files = [root / "README.md", root / "ROADMAP.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.is_file()]


def check(root: pathlib.Path) -> list[str]:
    errors: list[str] = []
    anchor_cache: dict[pathlib.Path, set[str]] = {}
    for doc in doc_files(root):
        for lineno, line in enumerate(doc.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(EXTERNAL):
                    continue
                path_part, _, anchor = target.partition("#")
                where = f"{doc.relative_to(root)}:{lineno}"
                if path_part:
                    dest = (doc.parent / path_part).resolve()
                    if not dest.exists():
                        errors.append(f"{where}: missing target {target}")
                        continue
                else:
                    dest = doc
                if anchor:
                    if dest.suffix != ".md" or not dest.is_file():
                        continue
                    if dest not in anchor_cache:
                        anchor_cache[dest] = anchors_of(dest)
                    if anchor not in anchor_cache[dest]:
                        errors.append(
                            f"{where}: anchor #{anchor} not found in "
                            f"{dest.relative_to(root)}")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=".",
                    help="repo root holding README.md and docs/")
    args = ap.parse_args()
    root = pathlib.Path(args.root).resolve()
    errors = check(root)
    for err in errors:
        print(err, file=sys.stderr)
    n_docs = len(doc_files(root))
    if errors:
        print(f"docs gate: {len(errors)} broken link(s) across "
              f"{n_docs} file(s)", file=sys.stderr)
        return 1
    print(f"docs gate: {n_docs} file(s) clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
