"""Custom-VJP XLA flash attention: values + gradients vs naive reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (decode_attention, flash_attention_xla,
                                    reference_attention)


def rand(shape, k):
    return jax.random.normal(jax.random.PRNGKey(k), shape, jnp.float32)


@pytest.mark.parametrize("B,S,KV,G,hd,window", [
    (2, 64, 2, 2, 16, 0),      # GQA causal
    (1, 96, 1, 4, 32, 0),      # MQA-style grouping, ragged chunks
    (2, 64, 2, 1, 16, 24),     # local window
    (1, 128, 4, 2, 8, 32),     # window smaller than chunk
])
def test_forward_matches_reference(B, S, KV, G, hd, window):
    q = rand((B, S, KV, G, hd), 0)
    k = rand((B, S, KV, hd), 1)
    v = rand((B, S, KV, hd), 2)
    out = flash_attention_xla(q, k, v, True, window, 32, 32)
    expect = reference_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [0, 24])
def test_gradients_match_reference(window):
    B, S, KV, G, hd = 1, 64, 2, 2, 16
    q = rand((B, S, KV, G, hd), 3)
    k = rand((B, S, KV, hd), 4)
    v = rand((B, S, KV, hd), 5)

    def loss_flash(q, k, v):
        o = flash_attention_xla(q, k, v, True, window, 16, 16)
        return jnp.sum(jnp.sin(o))

    def loss_ref(q, k, v):
        o = reference_attention(q, k, v, causal=True, window=window)
        return jnp.sum(jnp.sin(o))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4, err_msg=f"d{name}")


def test_chunk_size_invariance():
    B, S, KV, G, hd = 1, 120, 1, 2, 16
    q = rand((B, S, KV, G, hd), 6)
    k = rand((B, S, KV, hd), 7)
    v = rand((B, S, KV, hd), 8)
    outs = [np.asarray(flash_attention_xla(q, k, v, True, 0, qc, kc))
            for qc, kc in [(8, 8), (24, 40), (120, 120), (60, 30)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=2e-5)


def test_decode_matches_last_row_of_full():
    B, T, KV, G, hd = 2, 32, 2, 2, 16
    q_full = rand((B, T, KV, G, hd), 9)
    k = rand((B, T, KV, hd), 10)
    v = rand((B, T, KV, hd), 11)
    full = reference_attention(q_full, k, v, causal=True)
    pos = jnp.full((B,), T - 1, jnp.int32)
    dec = decode_attention(q_full[:, T - 1:T], k, v, pos)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, T - 1]),
                               rtol=2e-5, atol=2e-5)


def test_decode_windowed():
    B, T, KV, G, hd, W = 1, 48, 1, 2, 8, 16
    q_full = rand((B, T, KV, G, hd), 12)
    k = rand((B, T, KV, hd), 13)
    v = rand((B, T, KV, hd), 14)
    full = reference_attention(q_full, k, v, causal=True, window=W)
    pos = jnp.full((B,), T - 1, jnp.int32)
    dec = decode_attention(q_full[:, T - 1:T], k, v, pos, window=W)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, T - 1]),
                               rtol=2e-5, atol=2e-5)
