"""MoE dispatch invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_ffn
from repro.models.layers import swiglu_ffn
from repro.sharding.rules import Rules

RULES = Rules.null()
KEY = jax.random.PRNGKey(0)


def _weights(E, d, ff, k=0):
    ks = jax.random.split(jax.random.PRNGKey(k), 4)
    return (jax.random.normal(ks[0], (d, E)) * 0.02,
            jax.random.normal(ks[1], (E, d, ff)) * 0.05,
            jax.random.normal(ks[2], (E, d, ff)) * 0.05,
            jax.random.normal(ks[3], (E, ff, d)) * 0.05)


def test_identical_experts_equal_dense():
    """If every expert has the same weights, routing is irrelevant and the
    MoE must equal the dense SwiGLU with those weights (combine weights sum
    to 1)."""
    B, S, d, ff, E, K = 2, 8, 16, 32, 8, 2
    router, wg, wu, wd = _weights(E, d, ff)
    wg = jnp.broadcast_to(wg[0:1], wg.shape)
    wu = jnp.broadcast_to(wu[0:1], wu.shape)
    wd = jnp.broadcast_to(wd[0:1], wd.shape)
    x = jax.random.normal(KEY, (B, S, d))
    out, aux = moe_ffn(x, router, wg, wu, wd, RULES, experts_per_token=K,
                       capacity_factor=8.0)   # no drops
    dense = swiglu_ffn(x, wg[0], wu[0], wd[0], RULES)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense), rtol=2e-4,
                               atol=2e-4)


def test_capacity_drops_tokens():
    """Tiny capacity factor must drop tokens (output smaller norm), never
    produce NaNs."""
    B, S, d, ff, E, K = 2, 16, 8, 16, 4, 2
    router, wg, wu, wd = _weights(E, d, ff, k=1)
    x = jax.random.normal(KEY, (B, S, d))
    full, _ = moe_ffn(x, router, wg, wu, wd, RULES, experts_per_token=K,
                      capacity_factor=8.0)
    tight, _ = moe_ffn(x, router, wg, wu, wd, RULES, experts_per_token=K,
                       capacity_factor=0.25)
    assert np.all(np.isfinite(np.asarray(tight)))
    assert np.linalg.norm(np.asarray(tight)) < np.linalg.norm(np.asarray(full))


def test_aux_loss_uniform_router_is_one():
    """Switch aux loss == 1 exactly when routing is perfectly balanced."""
    B, S, d, ff, E, K = 1, 64, 8, 16, 4, 1
    router = jnp.zeros((d, E))   # uniform probs
    _, wg, wu, wd = _weights(E, d, ff, k=2)
    x = jax.random.normal(KEY, (B, S, d))
    _, aux = moe_ffn(x, router, wg, wu, wd, RULES, experts_per_token=K)
    # probs uniform => mean prob = 1/E; top-1 ties broken by index =>
    # fraction may be skewed, but aux = E * sum(frac * 1/E) = 1 always.
    assert float(aux) == pytest.approx(1.0, rel=1e-5)


def test_grads_flow_through_dispatch():
    B, S, d, ff, E, K = 2, 8, 8, 16, 4, 2
    router, wg, wu, wd = _weights(E, d, ff, k=3)
    x = jax.random.normal(KEY, (B, S, d))

    def loss(params):
        out, aux = moe_ffn(x, *params, RULES, experts_per_token=K)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)((router, wg, wu, wd))
    for a in g:
        assert np.all(np.isfinite(np.asarray(a)))
    assert np.abs(np.asarray(g[0])).max() > 0   # router receives gradient
