"""Sharding rules + spec/pytree structural consistency for all 10 archs.

These catch the class of bug that would only explode on a real pod: a
PartitionSpec tree that does not match the parameter tree, or a spec whose
rank disagrees with its leaf.
"""

import dataclasses

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.sharding.rules import Rules, make_rules


class _FakeMesh:
    axis_names = ("pod", "data", "model")


class _FakeMeshSingle:
    axis_names = ("data", "model")


def test_make_rules_filters_absent_axes():
    r = make_rules("train", _FakeMeshSingle())
    assert r.batch == ("data",)        # "pod" dropped
    assert r.heads == "model"
    r2 = make_rules("train", _FakeMesh())
    assert r2.batch == ("pod", "data")


def test_null_rules_noop():
    r = Rules.null()
    for f in dataclasses.fields(r):
        assert getattr(r, f.name) is None
    assert r.spec("batch", None) == P(None, None)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_match_param_tree(arch):
    cfg = get_config(arch)
    rules = make_rules("train", _FakeMesh())
    shapes = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    specs = T.param_specs(cfg, rules)
    # identical structure
    jax.tree.structure(shapes) == jax.tree.structure(
        jax.tree.map(lambda s: 0, specs, is_leaf=lambda s: isinstance(s, P)))
    flat_sh = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_sp = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda s: isinstance(s, P))[0]
    assert len(flat_sh) == len(flat_sp)
    for (pa, leaf), (pb, spec) in zip(flat_sh, flat_sp):
        assert pa == pb
        assert len(spec) <= leaf.ndim, (pa, spec, leaf.shape)
        # every sharded dim must divide by 16 (one pod axis width)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is not None:
                assert dim % 16 == 0, (pa, leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("profile", ["decode", "long"])
def test_cache_specs_match_cache_tree(arch, profile):
    cfg = get_config(arch)
    rules = make_rules(profile, _FakeMesh())
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 16, 2048))
    specs = T.cache_specs(cfg, rules)
    flat_c = jax.tree_util.tree_flatten_with_path(cache)[0]
    flat_s = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda s: isinstance(s, P))[0]
    assert len(flat_c) == len(flat_s)
    for (pa, leaf), (pb, spec) in zip(flat_c, flat_s):
        assert pa == pb, (pa, pb)
        assert len(spec) <= leaf.ndim


def test_spec_lookup():
    r = Rules(batch=("pod", "data"), heads="model")
    assert r.spec("batch", None, "heads", None) == \
        P(("pod", "data"), None, "model", None)
