"""Deterministic stand-in for the `hypothesis` API surface this suite uses.

Installed into ``sys.modules`` by conftest ONLY when the real package is
missing (hermetic containers without the dev extra), so the property tests
still execute instead of breaking collection.  It is intentionally tiny:
``@given`` draws ``max_examples`` samples from each strategy with an RNG
seeded from the test's qualified name (stable across runs and
PYTHONHASHSEED), with no shrinking and no example database.  Install the
real hypothesis (``pip install -e .[dev]``) to get full property testing.
"""

from __future__ import annotations

import functools
import random
import types
import zlib

__version__ = "0.0.0-repro-fallback"
IS_FALLBACK = True

_SETTINGS_ATTR = "_fallback_hyp_settings"
_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw, label):
        self._draw = draw
        self._label = label

    def draw_with(self, rng):
        return self._draw(rng)

    def __repr__(self):
        return f"fallback_strategy({self._label})"


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     f"integers({min_value}, {max_value})")


def _floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     f"floats({min_value}, {max_value})")


def _booleans():
    return _Strategy(lambda rng: bool(rng.getrandbits(1)), "booleans()")


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements),
                     f"sampled_from({elements!r})")


def _just(value):
    return _Strategy(lambda rng: value, f"just({value!r})")


def _lists(elem, min_size=0, max_size=10):
    return _Strategy(
        lambda rng: [elem.draw_with(rng)
                     for _ in range(rng.randint(min_size, max_size))],
        f"lists({elem!r})")


strategies = types.ModuleType("hypothesis.strategies")
for _name, _fn in [("integers", _integers), ("floats", _floats),
                   ("booleans", _booleans), ("sampled_from", _sampled_from),
                   ("just", _just), ("lists", _lists)]:
    setattr(strategies, _name, _fn)


class settings:
    def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None,
                 **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        setattr(fn, _SETTINGS_ATTR, self)
        return fn


def given(*arg_strategies, **kwarg_strategies):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = (getattr(wrapper, _SETTINGS_ATTR, None)
                   or getattr(fn, _SETTINGS_ATTR, None))
            n = cfg.max_examples if cfg else _DEFAULT_MAX_EXAMPLES
            rng = random.Random(zlib.crc32(fn.__qualname__.encode("utf-8")))
            for i in range(n):
                drawn = tuple(s.draw_with(rng) for s in arg_strategies)
                kdrawn = {k: s.draw_with(rng)
                          for k, s in kwarg_strategies.items()}
                try:
                    fn(*args, *drawn, **kwargs, **kdrawn)
                except Exception as e:  # surface the failing example
                    raise AssertionError(
                        f"fallback-hypothesis example {i + 1}/{n} failed "
                        f"for {fn.__qualname__} with args={drawn} "
                        f"kwargs={kdrawn}: {e}") from e

        # pytest follows __wrapped__ to the original signature and would
        # demand fixtures for the strategy-drawn params; hide it.
        del wrapper.__wrapped__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return decorate
