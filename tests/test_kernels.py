"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def rand(shape, dtype, k=0):
    return jax.random.normal(jax.random.PRNGKey(k), shape).astype(dtype)


# ---------------------------------------------------------------------------
# lbp_matmul kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),       # single block
    (256, 384, 128),       # multi k-block (layer accumulation)
    (100, 200, 60),        # ragged -> padding path
    (64, 1024, 64),        # deep contraction, many layers
])
def test_matmul_sweep(m, k, n, dtype, tol):
    x = rand((m, k), dtype, 1)
    w = rand((k, n), dtype, 2)
    out = ops.matmul(x, w, block_m=128, block_n=128, block_k=128,
                     out_dtype=jnp.float32, interpret=True)
    expect = ref.matmul_ref(x, w, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


def test_matmul_block_shape_invariance():
    x = rand((256, 256), jnp.float32, 3)
    w = rand((256, 256), jnp.float32, 4)
    outs = [np.asarray(ops.matmul(x, w, block_m=bm, block_n=bn, block_k=bk,
                                  interpret=True))
            for bm, bn, bk in [(64, 64, 64), (128, 128, 128), (256, 256, 64)]]
    for o in outs[1:]:
        # different block_k reassociates the layer sum -> small fp drift
        np.testing.assert_allclose(o, outs[0], rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (160, 96, 200, 64, 128, 32),    # all three blocks distinct + padding
    (128, 256, 64, 32, 16, 128),    # block_k > block_m/block_n
    (100, 60, 40, 64, 32, 16),      # ragged every dim, non-square blocks
])
def test_matmul_nonsquare_blocks(m, k, n, bm, bn, bk):
    """block_m != block_n != block_k must stay exact vs the oracle."""
    x = rand((m, k), jnp.float32, 11)
    w = rand((k, n), jnp.float32, 12)
    out = ops.matmul(x, w, block_m=bm, block_n=bn, block_k=bk,
                     out_dtype=jnp.float32, interpret=True)
    expect = ref.matmul_ref(x, w, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_matmul_smaller_than_one_block():
    """Shapes far below a single block: the whole product lives in the
    padding path (zero layers are exact by Theorem-1 linearity)."""
    x = rand((7, 5), jnp.float32, 13)
    w = rand((5, 3), jnp.float32, 14)
    out = ops.matmul(x, w, block_m=128, block_n=128, block_k=128,
                     out_dtype=jnp.float32, interpret=True)
    expect = ref.matmul_ref(x, w, out_dtype=jnp.float32)
    assert out.shape == (7, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)
    # degenerate single row/col
    x1 = rand((1, 2), jnp.float32, 15)
    w1 = rand((2, 1), jnp.float32, 16)
    out1 = ops.matmul(x1, w1, interpret=True)
    np.testing.assert_allclose(np.asarray(out1),
                               np.asarray(ref.matmul_ref(x1, w1)),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# rglru kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,D,bd,chunk", [
    (1, 8, 32, 32, 8),
    (2, 37, 96, 32, 16),    # ragged seq + channel padding
    (3, 64, 64, 64, 16),    # multi-chunk carry
])
def test_rglru_sweep(B, S, D, bd, chunk):
    a = jax.nn.sigmoid(rand((B, S, D), jnp.float32, 5))
    b = rand((B, S, D), jnp.float32, 6) * 0.1
    h0 = rand((B, D), jnp.float32, 7)
    h, hend = ops.rglru(a, b, h0, block_d=bd, chunk=chunk, interpret=True)
    hr, hendr = ref.rglru_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(hend), np.asarray(hendr),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# weight-stationary sLSTM kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (1, 8, 1, 16, 8),
    (2, 24, 2, 32, 8),      # multi-chunk carry
    (1, 15, 3, 8, 4),       # ragged chunking (falls back to c=5)
])
def test_slstm_sweep(B, S, H, hd, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 9)
    pre = {g: jax.random.normal(ks[i], (B, S, H, hd)) * 0.5
           for i, g in enumerate("zifo")}
    R = {g: jax.random.normal(ks[4 + i], (H, hd, hd)) * hd ** -0.5
         for i, g in enumerate("zifo")}
    state = tuple(jax.random.normal(ks[8], (B, H, hd)) * 0.1
                  for _ in range(3))
    hs, st = ops.slstm(pre, R, state, chunk=chunk, interpret=True)
    hr, sr = ref.slstm_ref(pre, R, state)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hr), rtol=2e-5,
                               atol=2e-5)
    for a, b in zip(st, sr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("B,H,S,D,bq,bk", [
    (1, 2, 128, 64, 64, 64),
    (2, 3, 200, 64, 64, 64),     # ragged seq -> padding path (causal)
    (1, 1, 256, 128, 128, 64),   # asymmetric blocks
])
def test_flash_causal_sweep(B, H, S, D, bq, bk, dtype, tol):
    q = rand((B, H, S, D), dtype, 8)
    k = rand((B, H, S, D), dtype, 9)
    v = rand((B, H, S, D), dtype, 10)
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
    expect = ref.attention_ref(
        q.reshape(B * H, S, D), k.reshape(B * H, S, D),
        v.reshape(B * H, S, D), causal=True).reshape(B, H, S, D)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("S,bq,bk", [
    (96, 96, 64),     # q unpadded, keys padded 96 -> 128 (T % block_k != 0)
    (100, 128, 48),   # T % block_k = 4; q padded too
    (40, 64, 64),     # whole sequence smaller than one KV block
])
def test_flash_key_padding_ragged_T(S, bq, bk):
    """Key/value padding on a T that is NOT a block_k multiple: the padded
    keys sit at positions >= T and the causal mask of every real query row
    must exclude them exactly (no mass leaks into the softmax)."""
    B, H, D = 2, 2, 32
    q = rand((B, H, S, D), jnp.float32, 21)
    k = rand((B, H, S, D), jnp.float32, 22)
    # huge-magnitude values in the *real* tail of k/v: if padded keys were
    # mis-masked the online softmax would visibly shift
    k = k.at[:, :, -1].mul(8.0)
    v = rand((B, H, S, D), jnp.float32, 23)
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
    expect = ref.attention_ref(
        q.reshape(B * H, S, D), k.reshape(B * H, S, D),
        v.reshape(B * H, S, D), causal=True).reshape(B, H, S, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_flash_noncausal():
    B, H, S, D = 1, 2, 128, 64
    q = rand((B, H, S, D), jnp.float32, 11)
    out = ops.flash_attention(q, q, q, causal=False, block_q=64, block_k=64,
                              interpret=True)
    expect = ref.attention_ref(q.reshape(B * H, S, D), q.reshape(B * H, S, D),
                               q.reshape(B * H, S, D),
                               causal=False).reshape(B, H, S, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_xla_flash():
    """Pallas kernel == the models' custom-VJP XLA implementation."""
    from repro.models.attention import flash_attention_xla
    B, H, S, D = 1, 2, 128, 32
    q = rand((B, H, S, D), jnp.float32, 12)
    k = rand((B, H, S, D), jnp.float32, 13)
    v = rand((B, H, S, D), jnp.float32, 14)
    pallas = ops.flash_attention(q, k, v, causal=True, block_q=64,
                                 block_k=64, interpret=True)
    # models layout: (B, S, KV, G, hd) with KV=H, G=1
    qx = q.transpose(0, 2, 1, 3)[:, :, :, None, :]
    kx = k.transpose(0, 2, 1, 3)
    vx = v.transpose(0, 2, 1, 3)
    xla = flash_attention_xla(qx, kx, vx, True, 0, 64, 64)
    xla = xla[:, :, :, 0, :].transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(pallas), np.asarray(xla),
                               rtol=2e-5, atol=2e-5)
