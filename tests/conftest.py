import pathlib
import sys

# tests import the package from src/ (same as PYTHONPATH=src)
ROOT = pathlib.Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device.  Multi-device tests spawn subprocesses with
# --xla_force_host_platform_device_count set (tests/test_distributed.py).
