import importlib.util
import pathlib
import sys

# tests import the package from src/ (same as PYTHONPATH=src)
ROOT = pathlib.Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# Property tests need `hypothesis`; hermetic containers may lack the dev
# extra.  Rather than failing collection, install the deterministic
# fallback shim (see tests/_hypothesis_fallback.py) under the real name.
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", pathlib.Path(__file__).with_name(
            "_hypothesis_fallback.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device.  Multi-device tests spawn subprocesses with
# --xla_force_host_platform_device_count set (tests/test_distributed.py).
