"""repro.plan: topology lowering, solver registry, PartitionPlan IR.

Covers the oracle contract (flat-star plans are bit-for-bit the seed
``SOLVERS + adjust_integer`` path, so refactoring the consumers onto
``plan()`` changed nothing), the new hierarchical solver's properties
(conservation, quantum alignment, beats the naive flat-star model on the
multi-pod platform), the mesh backends, and the consumer routing
(``from_speeds`` / ``plan_rebalance`` / ``CapacityPlanner`` /
``drop_devices`` mode+net forwarding).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.integer_adjust import adjust_integer
from repro.core.network import SpeedProfile, random_mesh, random_star
from repro.core.partition import LayerAssignment
from repro.core.star import SOLVERS, per_processor_finish
from repro.plan import (DCN_LINK, ICI_LINK, HierarchicalTopology,
                        MeshTopology, PartitionPlan, StarTopology,
                        available_planners, compare_flat_hierarchical,
                        comm_for_split, evaluate_split, plan,
                        production_shape, production_topology,
                        register_planner)

MODES = ["SCSS", "SCCS", "PCCS", "PCSS"]


# ---------------------------------------------------------------------------
# oracle: flat-star plans == the seed solver path, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("quantum", [1, 4])
def test_star_plan_matches_seed_path(mode, quantum):
    net = random_star(12, seed=5)
    N = 512
    seed_k = adjust_integer(net, N, SOLVERS[mode](net, N).k, mode,
                            quantum=quantum)
    pp = plan(StarTopology.from_network(net), N, quantum=quantum,
              objective=mode)
    np.testing.assert_array_equal(pp.k, seed_k)
    np.testing.assert_allclose(pp.k_real, SOLVERS[mode](net, N).k)
    np.testing.assert_allclose(
        pp.finish_times, per_processor_finish(net, N, seed_k, mode))
    assert pp.solver == f"star:{mode}" and pp.topology_kind == "star"


def test_from_speeds_is_thin_wrapper():
    """LayerAssignment.from_speeds == plan() on the same topology — and both
    equal the seed SpeedProfile.to_star + PCSS path."""
    speeds = [1.0, 2.0, 3.0, 4.0]
    net = SpeedProfile(np.asarray(speeds, dtype=np.float64)).to_star()
    seed_k = adjust_integer(net, 1024, SOLVERS["PCSS"](net, 1024).k, "PCSS",
                            quantum=1)
    a = LayerAssignment.from_speeds(1024, speeds, quantum=1)
    pp = plan(StarTopology.from_speeds(speeds), 1024, objective="PCSS")
    np.testing.assert_array_equal(a.k, seed_k)
    np.testing.assert_array_equal(pp.k, seed_k)


def test_capacity_planner_routes_through_plan():
    from repro.serve import CapacityPlanner
    rates = [120.0, 60.0, 180.0, 45.0]
    pl = CapacityPlanner(rates, mode="PCCS")
    rp = pl.plan(64)
    # bit-for-bit the seed path: StarNetwork(w=1/rates, z=ICI) + PCCS
    net = StarTopology.from_rates(rates).to_network()
    seed_k = adjust_integer(net, 64, SOLVERS["PCCS"](net, 64).k, "PCCS",
                            quantum=1)
    np.testing.assert_array_equal(rp.shares, seed_k)
    assert isinstance(rp.partition, PartitionPlan)
    assert rp.partition.solver == "star:PCCS"
    np.testing.assert_allclose(pl.finish_times(rp),
                               per_processor_finish(net, 64, seed_k, "PCCS"))


# ---------------------------------------------------------------------------
# hierarchical solver properties
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), load=st.sampled_from([128, 256, 512]),
       m0=st.integers(2, 6), m1=st.integers(2, 6),
       quantum=st.sampled_from([1, 4]))
def test_hierarchical_conserving_and_aligned(seed, load, m0, m1, quantum):
    rng = np.random.default_rng(seed)
    topo = HierarchicalTopology.from_pod_speeds(
        [rng.uniform(0.5, 2.0, m0), rng.uniform(0.5, 2.0, m1)])
    pp = plan(topo, load, quantum=quantum, objective="PCCS")
    assert int(pp.k.sum()) == load                       # load-conserving
    assert np.all(pp.k >= 0)
    assert np.all(pp.k % quantum == 0)                   # quantum-aligned
    # pod shares in the meta match the per-device shares
    shares = [int(pp.k[sl].sum()) for sl in topo.pod_slices()]
    assert shares == pp.meta["pod_shares"]
    # the real-valued split conserves load too
    assert pp.k_real.sum() == pytest.approx(load, rel=1e-9)
    # finish_times is the IR's own evaluation of its integer split
    np.testing.assert_allclose(
        pp.finish_times, evaluate_split(topo, pp.k, load, objective="PCCS"))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), load=st.sampled_from([256, 512]))
def test_hierarchical_beats_flat_on_two_pods(seed, load):
    """Priced on the true shared-trunk platform: the hierarchical
    real-valued optimum is never worse than the flat plan's (it IS the
    true model's optimum — within-pod PCSS makes pods exact
    super-processors), and the integer plans agree up to the §4.5
    rounding guarantee (one quantum-unit of work per level)."""
    from repro.core.network import W_TCP_RANGE
    rng = np.random.default_rng(seed)
    topo = HierarchicalTopology(
        pod_w=(rng.uniform(*W_TCP_RANGE, 6), rng.uniform(*W_TCP_RANGE, 6)),
        trunk_z=np.array([ICI_LINK, DCN_LINK]))
    cmp = compare_flat_hierarchical(topo, load, objective="PCCS")
    hier, flat = cmp["hierarchical"], cmp["flat"]
    # real-valued: strict domination on the true cost model
    hier_real = float(np.max(evaluate_split(topo, hier.k_real, load,
                                            objective="PCCS")))
    flat_real = float(np.max(evaluate_split(topo, flat.k_real, load,
                                            objective="PCCS")))
    assert hier_real <= flat_real * (1 + 1e-9)
    # integer: within one unit of work/transfer per adjustment level
    unit = (float(load) ** 2 * float(topo.w.max()) * topo.t_cp
            + 2.0 * load * float(topo.trunk_z.max()) * topo.t_cm)
    assert hier.finish_time <= cmp["flat_finish_on_topology"] + 2 * unit
    assert hier.comm.dcn <= cmp["flat_comm_on_topology"].dcn + 4.0 * load


def test_hierarchical_beats_flat_on_production_topology():
    """The acceptance bar: on the 2x16x16 multi-pod shape the two-level
    plan strictly beats the flat single-level star on both axes."""
    topo = production_topology(multi_pod=True, seed=0)
    assert topo.p == 512 and topo.pod_sizes == (256, 256)
    cmp = compare_flat_hierarchical(topo, 2048, objective="PCCS")
    hier = cmp["hierarchical"]
    assert hier.finish_time < cmp["flat_finish_on_topology"]
    assert hier.comm.dcn < cmp["flat_comm_on_topology"].dcn
    assert cmp["finish_speedup"] > 1.05
    assert cmp["dcn_reduction"] > 0.05


def test_hierarchical_super_processor_is_exact():
    """Within-pod PCSS makes k_i * w_i constant inside a pod, so each pod
    finishes exactly like one processor of rate sum(1/w_i)."""
    rng = np.random.default_rng(7)
    topo = HierarchicalTopology.from_pod_speeds(
        [rng.uniform(0.5, 2.0, 5), rng.uniform(0.5, 2.0, 5)])
    pp = plan(topo, 400, objective="PCCS")
    w = topo.w
    for j, sl in enumerate(topo.pod_slices()):
        prod = pp.k_real[sl] * w[sl]
        np.testing.assert_allclose(prod, prod[0], rtol=1e-9)


def test_hierarchical_quantum_alignment_both_levels():
    topo = HierarchicalTopology.from_pod_speeds(
        [[1.0, 2.0, 1.0, 1.0], [1.0, 1.0, 0.5, 1.0]])
    pp = plan(topo, 512, quantum=128, objective="PCCS")
    assert np.all(pp.k % 128 == 0) and int(pp.k.sum()) == 512
    assert all(s % 128 == 0 for s in pp.meta["pod_shares"])


def test_comm_accounting_hierarchical():
    """Trunk hop counted per pod by link class, intra-pod hop always ICI
    (multi-hop counted per traversal, like LPResult.comm_volume)."""
    topo = HierarchicalTopology.from_pod_speeds([[1.0, 1.0], [1.0, 1.0]])
    load = 100
    k = np.array([30, 30, 20, 20])
    cv = comm_for_split(topo, k, load)
    assert cv.dcn == pytest.approx(2.0 * load * 40)      # pod 1's trunk
    assert cv.ici == pytest.approx(2.0 * load * 60 + 2.0 * load * 100)
    assert cv.total == pytest.approx(cv.dcn + cv.ici)


# ---------------------------------------------------------------------------
# mesh backends as planning backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("objective", ["heuristic", "lp"])
def test_mesh_planner(objective):
    net = random_mesh(3, 3, seed=1)
    pp = plan(MeshTopology.from_network(net), 200, objective=objective)
    assert int(pp.k.sum()) == 200
    assert pp.k[net.source] == 0
    assert pp.solver == f"mesh:{objective}"
    assert pp.meta["lp_solves"] >= 1 and pp.comm.total > 0
    # finish prediction is the fixed-k LP's per-node times
    assert pp.finish_time == pytest.approx(float(pp.finish_times.max()),
                                           rel=1e-6)


def test_mesh_adjacency_cache_consistent():
    """Perf fix: cached in/out adjacency == brute-force scan of the dict."""
    net = random_mesh(4, 4, seed=3)
    edges = sorted(net.z.keys())
    for i in range(net.p):
        assert net.in_edges(i) == [e for e in edges if e[1] == i]
        assert net.out_edges(i) == [e for e in edges if e[0] == i]
    assert net.edges() == edges


# ---------------------------------------------------------------------------
# registry + validation
# ---------------------------------------------------------------------------

def test_registry():
    assert set(available_planners()) >= {"star", "mesh", "hierarchical"}
    with pytest.raises(ValueError, match="already registered"):
        register_planner("star", lambda *a: None)


def test_plan_rejects_misaligned_load():
    with pytest.raises(ValueError, match="quantum"):
        plan(StarTopology.from_speeds([1.0, 1.0]), 100, quantum=64)


def test_production_shapes():
    assert production_shape(False) == (16, 16)
    assert production_shape(True) == (2, 16, 16)
    flat = production_topology(multi_pod=False, seed=0)
    assert isinstance(flat, StarTopology) and flat.p == 256


# ---------------------------------------------------------------------------
# consumer routing: rebalance + drop_devices bugfix
# ---------------------------------------------------------------------------

def test_plan_rebalance_carries_plan_ir():
    from repro.runtime.rebalance import plan_rebalance
    rp = plan_rebalance(4096, [1.0, 1.0, 2.0, 4.0], quantum=128)
    assert isinstance(rp.plan, PartitionPlan)
    assert rp.plan.solver == "star:PCSS"
    np.testing.assert_array_equal(rp.plan.k, rp.assignment.k)


def test_plan_rebalance_accepts_topology():
    from repro.runtime.rebalance import plan_rebalance
    topo = HierarchicalTopology.from_pod_speeds(
        [[1.0, 1.0, 2.0, 1.0], [1.0, 0.5, 1.0, 1.0]])
    rp = plan_rebalance(1024, quantum=128, mode="PCCS", topology=topo)
    assert rp.assignment.K == 1024
    assert rp.plan.topology_kind == "hierarchical"


def test_drop_devices_forwards_mode_and_net():
    """Bugfix: survivors are re-planned under the caller's mode and link
    model, with the network shrunk to the alive set — not default PCSS on
    a fresh near-zero-link star."""
    from repro.runtime.rebalance import drop_devices
    base = LayerAssignment.even(512, 8, quantum=1)
    # heterogeneous links: device 6 sits behind a DCN-class link
    z = np.full(8, ICI_LINK)
    z[6] = DCN_LINK
    net = StarTopology(w=np.full(8, 6e-4), z=z).to_network()
    rp = drop_devices(base, dead=[2], speeds=[1.0] * 8, quantum=1,
                      mode="PCCS", net=net)
    assert rp.plan.solver == "star:PCCS"            # mode forwarded
    assert rp.assignment.p == 7
    assert int(rp.assignment.k.sum()) == 512
    # the slow link survives the shrink: device 6 (now index 5) gets less
    k = rp.assignment.k
    assert k[5] < k[0]
    # oracle: identical to planning directly on the restricted topology
    alive = [0, 1, 3, 4, 5, 6, 7]
    want = plan(StarTopology.from_network(net).restrict(alive), 512,
                objective="PCCS")
    np.testing.assert_array_equal(k, want.k)


def test_drop_devices_restricts_hierarchical_topology():
    from repro.runtime.rebalance import drop_devices
    topo = HierarchicalTopology.from_pod_speeds(
        [[1.0, 1.0, 1.0], [1.0, 1.0, 1.0]])
    base = LayerAssignment.even(600, 6, quantum=1)
    rp = drop_devices(base, dead=[4], speeds=[1.0] * 6, quantum=1,
                      mode="PCCS", topology=topo)
    assert rp.assignment.p == 5
    assert rp.plan.topology_kind == "hierarchical"
    assert rp.plan.meta["pod_shares"][1] > 0         # pod 1 kept its trunk


def test_restrict_drops_empty_pods():
    topo = HierarchicalTopology.from_pod_speeds([[1.0, 1.0], [1.0, 1.0]])
    shrunk = topo.restrict([0, 1])                   # pod 1 fully dead
    assert shrunk.n_pods == 1 and shrunk.p == 2


def test_consumers_reject_mesh_topology_cleanly():
    """plan() supports meshes, but the device-fleet consumers need a
    per-device speed view / restrict() — they must say so, not crash."""
    from repro.runtime.rebalance import drop_devices, plan_rebalance
    from repro.serve import CapacityPlanner
    mt = MeshTopology.from_network(random_mesh(3, 3, seed=0))
    with pytest.raises(ValueError, match="speeds"):
        plan_rebalance(1024, topology=mt)
    with pytest.raises(ValueError, match="shrink"):
        drop_devices(LayerAssignment.even(90, 9), dead=[1],
                     speeds=[1.0] * 9, topology=mt)
    with pytest.raises(ValueError, match="topology"):
        CapacityPlanner(topology=mt)


def test_replica_plan_without_ir_still_prices():
    """Hand-built ReplicaPlans (partition=None) keep the pre-plan-IR
    finish_times behavior."""
    from repro.core.star import StarSchedule
    from repro.serve import CapacityPlanner
    pl = CapacityPlanner([100.0, 50.0], mode="PCCS")
    rp = pl.plan(30)
    assert rp.schedule.mode == "PCCS"       # a valid core.star Mode
    import dataclasses as dc
    legacy = dc.replace(rp, partition=None)
    np.testing.assert_allclose(pl.finish_times(legacy),
                               pl.finish_times(rp))


def test_evaluate_split_star_matches_core():
    net = random_star(6, seed=2)
    k = np.array([100, 80, 60, 40, 20, 0], dtype=np.float64)
    ft = evaluate_split(StarTopology.from_network(net), k, 300,
                        objective="SCCS")
    np.testing.assert_allclose(ft, per_processor_finish(net, 300, k, "SCCS"))
