"""Paper §4: closed-form star-network solvers + §4.5 integer adjustment."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.network import StarNetwork, random_star
from repro.core.star import (SOLVERS, finish_time_for_split,
                             per_processor_finish, solve)
from repro.core.integer_adjust import adjust_integer, solve_integer

MODES = ["SCSS", "SCCS", "PCCS", "PCSS"]


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_equal_finish_time(mode, seed):
    """Theorem 2: optimal split => all processors finish simultaneously."""
    net = random_star(16, seed=seed)
    N = 700
    s = solve(net, N, mode)
    assert s.k.sum() == pytest.approx(N, rel=1e-9)
    assert np.all(s.k >= 0)
    tf = per_processor_finish(net, N, s.k, mode)
    live = s.k > 1e-9
    assert tf[live].max() - tf[live].min() < 1e-6 * tf.max()
    assert s.finish_time == pytest.approx(tf.max(), rel=1e-9)


@pytest.mark.parametrize("mode", MODES)
def test_comm_volume_is_2N2(mode):
    """Theorem 1: LBP total communication volume == 2 N^2 (the bound)."""
    net = random_star(16, seed=7)
    N = 512
    s = solve(net, N, mode)
    assert s.comm_volume == pytest.approx(2 * N * N, rel=1e-9)


def test_pcss_proportional_to_speed():
    """Eqs (31)-(33): PCSS k_i proportional to 1/w_i."""
    net = random_star(8, seed=3)
    s = solve(net, 400, "PCSS")
    ratio = s.k * net.w
    assert np.allclose(ratio, ratio[0], rtol=1e-9)


def test_any_other_split_is_worse():
    """Perturbing the optimal split cannot reduce the makespan."""
    net = random_star(12, seed=5)
    N = 600
    rng = np.random.default_rng(0)
    for mode in MODES:
        s = solve(net, N, mode)
        for _ in range(20):
            delta = rng.normal(0, 0.5, net.p)
            delta -= delta.mean()
            k2 = np.maximum(s.k + delta, 0)
            k2 *= N / k2.sum()
            assert finish_time_for_split(net, N, k2, mode) >= s.finish_time - 1e-9


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("quantum", [1, 4])
def test_integer_adjustment(mode, quantum):
    net = random_star(16, seed=11)
    N = 512
    s = solve(net, N, mode)
    k_int = adjust_integer(net, N, s.k, mode, quantum=quantum)
    assert k_int.sum() == N
    assert np.all(k_int >= 0)
    assert np.all(k_int % quantum == 0)
    # rounding costs little: within one quantum-unit of work per processor
    tf_int = finish_time_for_split(net, N, k_int, mode)
    unit = quantum * N * N * net.w.max() * net.t_cp
    assert tf_int <= s.finish_time + 2 * unit


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(64, 1024),
       p=st.integers(2, 24))
def test_property_solvers_valid(seed, n, p):
    net = random_star(p, seed=seed)
    for mode in MODES:
        s = solve(net, n, mode)
        assert s.k.sum() == pytest.approx(n, rel=1e-6)
        assert np.all(s.k >= -1e-9)
        assert np.isfinite(s.finish_time)
        ki, tfi = solve_integer(net, n, mode)
        assert ki.sum() == n and np.all(ki >= 0)


def test_degenerate_slow_link_scss():
    """SCSS with a pathologically slow link: later processors get 0 load."""
    w = np.full(4, 6e-4)
    z = np.array([3e-4, 3e-4, 1e3, 3e-4])   # link 3 unusable
    net = StarNetwork(w=w, z=z)
    s = solve(net, 100, "SCSS")
    assert s.k.sum() == pytest.approx(100)
    assert np.all(s.k >= 0)
    assert s.k[3] == 0.0 or s.k[3] < 1e-9
