"""Loop-aware HLO cost parser: known-flops programs as ground truth."""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_cost import analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    M, K, N = 64, 128, 32
    x = jnp.zeros((M, K), jnp.float32)
    w = jnp.zeros((K, N), jnp.float32)
    res = analyze_hlo(_compile(lambda a, b: a @ b, x, w))
    assert res["flops"] == pytest.approx(2 * M * K * N, rel=1e-6)


def test_scan_multiplies_flops():
    """A matmul inside an 8-step scan must count 8x."""
    M = 32
    x = jnp.zeros((M, M), jnp.float32)
    w = jnp.zeros((8, M, M), jnp.float32)

    def fn(x, w):
        def body(c, wi):
            return wi @ c, None
        out, _ = jax.lax.scan(body, x, w)
        return out

    res = analyze_hlo(_compile(fn, x, w))
    assert res["flops"] == pytest.approx(8 * 2 * M ** 3, rel=1e-6)
    assert res["collectives"]["n_while_loops"] == 1
    assert 8 in res["collectives"]["trip_counts"]


def test_nested_scan_multiplies():
    M = 16
    x = jnp.zeros((M, M), jnp.float32)
    w = jnp.zeros((3, 4, M, M), jnp.float32)

    def fn(x, w):
        def outer(c, wo):
            def inner(ci, wi):
                return wi @ ci, None
            c2, _ = jax.lax.scan(inner, c, wo)
            return c2, None
        out, _ = jax.lax.scan(outer, x, w)
        return out

    res = analyze_hlo(_compile(fn, x, w))
    assert res["flops"] == pytest.approx(12 * 2 * M ** 3, rel=1e-6)


def test_bytes_reasonable_for_elementwise():
    x = jnp.zeros((1024, 1024), jnp.float32)
    res = analyze_hlo(_compile(lambda a: a * 2.0 + 1.0, x))
    # one fused read + one write = 8 MiB (allow copies/layout slack)
    assert 0.5 * 8e6 <= res["hbm_bytes"] <= 4 * 8e6


def _call_module(n_calls: int) -> str:
    body = ["ENTRY %main (a: f32[16,16]) -> f32[16,16] {",
            "  %a = f32[16,16]{1,0} parameter(0)"]
    prev = "a"
    for i in range(n_calls):
        kw = "ROOT " if i == n_calls - 1 else ""
        body.append(f"  {kw}%c{i} = f32[16,16]{{1,0}} "
                    f"call(f32[16,16]{{1,0}} %{prev}), to_apply=%sub")
        prev = f"c{i}"
    return "\n".join([
        "HloModule m, is_scheduled=true",
        "",
        "%sub (p: f32[16,16]) -> f32[16,16] {",
        "  %p = f32[16,16]{1,0} parameter(0)",
        "  ROOT %add = f32[16,16]{1,0} add(f32[16,16]{1,0} %p, "
        "f32[16,16]{1,0} %p)",
        "}",
        "",
        *body,
        "}"])


def test_call_sites_sum_not_max():
    """A computation reached from two call sites executes twice; its cost
    must be charged per call site, not once at the max multiplier."""
    once = analyze_hlo(_call_module(1))["hbm_bytes"]
    twice = analyze_hlo(_call_module(2))["hbm_bytes"]
    assert once > 0
    assert twice == pytest.approx(2 * once)


def test_dynamic_slice_counts_window_not_operand():
    big = jnp.zeros((4096, 256), jnp.float32)

    def fn(a, i):
        return jax.lax.dynamic_slice_in_dim(a, i, 16, 0) * 1.0

    res = analyze_hlo(_compile(fn, big, jnp.asarray(2)))
    # window = 16*256*4 = 16 KiB; full operand would be 4 MiB
    assert res["hbm_bytes"] < 1e6
