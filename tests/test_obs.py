"""Observability-plane invariants: deterministic traces, order-invariant
metric merges, plan-vs-actual drift, and the zero-added-dispatch contract.

The acceptance oracle: a 32-request staggered fleet with one replica
killed mid-decode and a later join exports a BYTE-identical Chrome trace
across two runs (every timeline is an injectable tick clock — wall time
never enters the event stream), the trace carries a requeue instant for
every request outstanding at the kill, and tracing adds zero model
dispatches over the NullTracer run.
"""

import asyncio
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.fleet import (FaultPlan, FleetClosed, FleetController,
                         FleetFrontend, Replica, UnknownRequest,
                         build_engine)
from repro.obs import (DriftMonitor, Histogram, MetricsRegistry, NullTracer,
                       Tracer, drift_fractions, throughput_summary,
                       to_chrome_json, write_chrome_trace)
from repro.serve.engine import AdmissionError, EngineConfig, synthetic_workload
from repro.serve.engine.planner import CapacityPlanner
from test_serve_engine import FakeModel


def fake_workload(n, seed=0, stagger=0.5):
    return synthetic_workload(n, FakeModel.V, lens=(5, 8, 12, 16),
                              news=(2, 3, 6, 9), stagger=stagger, seed=seed)


ENGINE_CFG = dict(n_slots=4, max_prompt_len=32, max_new_cap=16,
                  cache_len=48)


def traced_fleet_run(n=32, seed=0):
    """One deterministic kill+join fleet run on a shared tracer/registry."""
    tracer, metrics = Tracer(), MetricsRegistry()
    cfg = EngineConfig(**ENGINE_CFG)
    replicas = [
        Replica("r0", FakeModel(), cfg, rate=1.0,
                fault=FaultPlan(kill_at=6), tracer=tracer, metrics=metrics),
        Replica("r1", FakeModel(), cfg, rate=2.0,
                tracer=tracer, metrics=metrics),
        Replica("r2", FakeModel(), cfg, rate=0.5,
                tracer=tracer, metrics=metrics),
    ]
    controller = FleetController(replicas, miss_threshold=3,
                                 tracer=tracer, metrics=metrics)
    controller.schedule_join(
        Replica("r3", FakeModel(), cfg, rate=1.5,
                tracer=tracer, metrics=metrics), at_tick=10)
    for p, m, a in fake_workload(n, seed):
        controller.submit(p, m, arrival=a)
    report = controller.run()
    return tracer, metrics, report, controller


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

def test_tracer_spans_events_counters():
    clock = iter(range(100))
    tr = Tracer(clock=lambda: next(clock))
    key = tr.begin("work", track="t", lane="l", a=1)
    tr.event("mark", track="t", lane="l")
    tr.counter("depth", 3, track="t")
    tr.end(key, b=2)
    phs = [e["ph"] for e in tr.events]
    assert phs == ["B", "i", "C", "E"]
    # timestamps come from the injected clock, in call order
    assert [e["ts"] for e in tr.events] == [0.0, 1.0, 2.0, 3.0]
    assert tr.events[0]["args"] == {"a": 1}
    assert tr.events[-1]["args"] == {"b": 2}
    assert tr.open_spans() == []


def test_tracer_keyed_spans_cross_calls_and_rebegin_closes_stale():
    tr = Tracer(clock=lambda: 0.0)
    tr.begin("qw", key=("qw", 1))
    assert tr.open_spans() == ["qw"]
    # re-begin of the same key closes the stale span first
    tr.begin("qw", key=("qw", 1))
    assert [e["ph"] for e in tr.events] == ["B", "E", "B"]
    tr.end(("qw", 1))
    tr.end(("qw", 1))          # unknown key: no-op
    tr.end(("never", 9))       # never opened: no-op
    assert [e["ph"] for e in tr.events] == ["B", "E", "B", "E"]


def test_null_tracer_is_inert():
    nt = NullTracer()
    assert not nt.enabled
    with nt.span("x"):
        nt.event("y")
        nt.end(nt.begin("z"))
        nt.counter("c", 1)
    assert len(nt) == 0 and nt.events == [] and nt.open_spans() == []


def test_chrome_export_shape_and_lane_assignment():
    tr = Tracer(clock=lambda: 2.0)
    with tr.span("s", track="engine", lane="engine"):
        tr.event("e", track="engine", lane="req:0", rids=[1, 2])
    doc = json.loads(to_chrome_json(tr))
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    # one process_name per track, one thread_name per (track, lane)
    assert {m["name"] for m in meta} == {"process_name", "thread_name"}
    body = [e for e in evs if e["ph"] != "M"]
    assert all(e["ts"] == 2000.0 for e in body)  # ticks -> ms -> us
    inst = next(e for e in body if e["ph"] == "i")
    assert inst["args"]["rids"] == [1, 2]        # lists survive as JSON


# ---------------------------------------------------------------------------
# the determinism oracle (acceptance)
# ---------------------------------------------------------------------------

def test_fleet_trace_byte_identical_across_runs():
    tr1, m1, rep1, _ = traced_fleet_run()
    tr2, m2, rep2, _ = traced_fleet_run()
    assert rep1.requeues >= 1 and rep1.kills and rep1.joins
    j1, j2 = to_chrome_json(tr1), to_chrome_json(tr2)
    assert len(tr1.events) > 100
    assert j1 == j2                       # byte-identical export
    # counters and gauges are tick-determined and equally deterministic;
    # histograms hold wall-clock OBSERVED VALUES (TTFT seconds) so only
    # their event counts are schedule-determined, not their bucket fill
    s1, s2 = m1.snapshot(), m2.snapshot()
    assert s1["counters"] == s2["counters"]
    assert s1["gauges"] == s2["gauges"]
    assert ({k: v["count"] for k, v in s1["histograms"].items()}
            == {k: v["count"] for k, v in s2["histograms"].items()})


def test_fleet_trace_has_requeue_event_per_outstanding_request():
    tracer, metrics, report, controller = traced_fleet_run()
    requeued_rids = sorted(e["args"]["rid"] for e in tracer.events
                           if e["name"] == "requeue")
    expect = sorted(rid for rid, fr in controller.requests.items()
                    if fr.n_requeues > 0)
    assert requeued_rids == expect and len(requeued_rids) == report.requeues
    assert metrics.counter_value("requeues") == report.requeues
    # membership events landed on the controller track
    names = {e["name"] for e in tracer.events if e["track"] == "controller"}
    assert {"kill", "join", "replan", "route"} <= names


def test_trace_file_roundtrip(tmp_path):
    tracer, _, _, _ = traced_fleet_run(n=8)
    path = write_chrome_trace(tracer, tmp_path / "trace.json")
    doc = json.loads(open(path).read())
    assert doc["traceEvents"] and doc["displayTimeUnit"] == "ms"


# ---------------------------------------------------------------------------
# zero added dispatches (acceptance)
# ---------------------------------------------------------------------------

class CountingFake(FakeModel):
    """FakeModel that counts its jit-dispatch-equivalent entry points."""

    def __init__(self):
        self.dispatches = 0

    def prefill(self, *a):
        self.dispatches += 1
        return super().prefill(*a)

    def decode_multi(self, *a, **k):
        self.dispatches += 1
        return super().decode_multi(*a, **k)


def run_counting_engine(tracer):
    model = CountingFake()
    eng = build_engine(model, EngineConfig(**ENGINE_CFG), tracer=tracer)
    for p, m, a in fake_workload(12, seed=3):
        eng.submit(p, m, arrival=a)
    rep = eng.run()
    return model.dispatches, rep


def test_tracing_adds_zero_dispatches():
    d_null, rep_null = run_counting_engine(NullTracer())
    tr = Tracer()
    d_traced, rep_traced = run_counting_engine(tr)
    assert d_traced == d_null
    assert rep_traced.steps == rep_null.steps
    for rid in rep_null.completed:
        np.testing.assert_array_equal(rep_null.completed[rid],
                                      rep_traced.completed[rid])
    assert len(tr.events) > 0


# ---------------------------------------------------------------------------
# engine instrumentation
# ---------------------------------------------------------------------------

def test_engine_spans_and_rejection_metrics():
    tr, reg = Tracer(), MetricsRegistry()
    eng = build_engine(FakeModel(), EngineConfig(**ENGINE_CFG),
                       tracer=tr, metrics=reg)
    rid = eng.submit(np.arange(1, 6), 4)
    with pytest.raises(AdmissionError):
        eng.submit(np.zeros(99, np.int32), 1)          # prompt too long
    with pytest.raises(AdmissionError):
        eng.submit(np.arange(1, 6), 0)                 # max_new < 1
    eng.run()
    assert reg.counter_value("admission_rejections", reason="prompt_len") == 1
    assert reg.counter_value("admission_rejections", reason="max_new") == 1
    assert reg.counter_total("admission_rejections") == 2
    assert reg.counter_value("requests_submitted") == 1
    assert reg.counter_value("requests_retired") == 1
    names = [(e["ph"], e["name"]) for e in tr.events
             if e["lane"] == f"req:{rid}"]
    # queue-wait opens at submit, closes at admit; serve spans admit->retire
    assert names[0] == ("B", "queue_wait")
    assert ("E", "queue_wait") in names and ("B", "serve") in names
    assert names[-2:] == [("E", "serve"), ("i", "retire")]
    # TTFT is observed into the fixed-bucket histogram
    assert reg.histogram("ttft_s").n == 1
    snap = reg.snapshot()
    assert "queue_depth" in snap["gauges"]
    assert "pool_occupancy" in snap["gauges"]


def test_engine_report_as_dict_matches_throughput_summary():
    eng = build_engine(FakeModel(), EngineConfig(**ENGINE_CFG))
    for p, m, a in fake_workload(8, seed=1):
        eng.submit(p, m, arrival=a)
    rep = eng.run()
    d = rep.as_dict()
    ref = throughput_summary(
        useful_tokens=rep.total_tokens, wall_s=rep.wall,
        ttfts_s=rep.ttft.values(),
        occupancy_sum=rep.occupancy * rep.decode_steps,
        decode_steps=rep.decode_steps, decode_tokens=rep.decode_tokens,
        decode_wall_s=rep.decode_wall)
    for k, v in ref.items():
        assert d[k] == v, k
    assert d["tokens_per_sec"] == rep.tokens_per_sec
    assert d["ttft_mean_s"] == pytest.approx(rep.ttft_mean)
    assert d["occupancy"] == pytest.approx(rep.occupancy)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counters_gauges_labels():
    reg = MetricsRegistry()
    reg.counter("rej", reason="full").inc()
    reg.counter("rej", reason="full").inc(2)
    reg.counter("rej", reason="len").inc()
    reg.gauge("depth").set(7)
    assert reg.counter_value("rej", reason="full") == 3
    assert reg.counter_total("rej") == 4
    snap = reg.snapshot()
    assert snap["counters"]["rej{reason=full}"] == 3
    assert snap["gauges"]["depth"] == 7.0
    with pytest.raises(ValueError):
        reg.counter("rej").inc(-1)


def test_histogram_buckets_and_edge_validation():
    h = Histogram(edges=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 3.0, 100.0):
        h.observe(v)
    # (-inf,1] (1,2] (2,4] (4,inf) -- bisect_left puts v==edge in the
    # bucket left of the edge
    assert h.counts == [2, 1, 1, 1]
    assert h.n == 5 and h.mean == pytest.approx(106.0 / 5)
    with pytest.raises(ValueError):
        Histogram(edges=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram(edges=())
    with pytest.raises(ValueError):
        h.merge(Histogram(edges=(1.0, 2.0)))
    reg = MetricsRegistry()
    reg.histogram("h", edges=(1.0, 2.0))
    with pytest.raises(ValueError):
        reg.histogram("h", edges=(1.0, 3.0))   # redeclare with new edges
    with pytest.raises(ValueError):
        reg.histogram("fresh")                 # first use needs edges


@settings(max_examples=60, deadline=None)
@given(st.lists(st.lists(st.integers(0, 1000), max_size=20), max_size=8),
       st.integers(0, 2**31))
def test_histogram_merge_is_order_invariant(partials, seed):
    """Merging per-replica partial histograms in ANY order yields the
    identical fleet histogram (integer counts + integer-valued totals)."""
    import random
    edges = (10.0, 100.0, 500.0)

    def merged(order):
        acc = Histogram(edges)
        for obs in order:
            part = Histogram(edges)
            for v in obs:
                part.observe(v)
            acc.merge(part)
        return acc.snapshot()

    shuffled = list(partials)
    random.Random(seed).shuffle(shuffled)
    assert merged(shuffled) == merged(partials)


# ---------------------------------------------------------------------------
# plan-vs-actual drift
# ---------------------------------------------------------------------------

def test_drift_fractions_normalized_by_makespan():
    d = drift_fractions([10.0, 5.0], [12.0, 5.0])
    np.testing.assert_allclose(d, [0.2, 0.0])
    with pytest.raises(ValueError):
        drift_fractions([1.0], [1.0, 2.0])


def test_undisturbed_star_run_within_quantum_tolerance():
    """Acceptance: an undisturbed run — every node serving exactly the
    real-valued equal-finish optimum at its true speed — drifts from the
    integer plan by no more than the integer-adjustment quantum prices."""
    reg = MetricsRegistry()
    planner = CapacityPlanner(rates=[1.0, 2.0, 0.5, 1.5], quantum=1)
    plan = planner.plan(200).partition
    mon = DriftMonitor(plan, metrics=reg, gauge_name="plan_drift")
    assert (plan.k > 0).all()
    per_unit = plan.finish_times / plan.k
    observed = plan.k_real * per_unit     # the equal-finish optimum
    drift = mon.observe_finish(observed)
    assert drift <= mon.tolerance() + 1e-12
    assert not mon.should_replan()
    assert reg.snapshot()["gauges"]["plan_drift"] == pytest.approx(drift)
    # a genuinely disturbed run (one node 2x slower) must trip the trigger
    slow = observed.copy()
    slow[0] = 2.0 * plan.finish_times[0]
    mon.observe_finish(slow)
    assert mon.should_replan()


def test_drift_observe_shares_serving_plane():
    plan = CapacityPlanner(rates=[1.0, 3.0], quantum=1).plan(100).partition
    mon = DriftMonitor(plan)
    # serving exactly the planned fractions -> zero drift
    assert mon.observe_shares(plan.k.astype(float)) == pytest.approx(0.0)
    with pytest.raises(ValueError):
        mon.observe_shares([1.0])


def test_fleet_drift_gauge_present_and_bounded():
    _, metrics, _, _ = traced_fleet_run()
    snap = metrics.snapshot()
    assert "fleet_drift" in snap["gauges"]
    assert 0.0 <= snap["gauges"]["fleet_drift"] <= 1.0


# ---------------------------------------------------------------------------
# frontend error paths (satellite: defined exceptions, no hangs)
# ---------------------------------------------------------------------------

def frontend_fixture():
    cfg = EngineConfig(**ENGINE_CFG)
    controller = FleetController(
        [Replica("r0", FakeModel(), cfg, rate=1.0)])
    return FleetFrontend(controller, max_pending=8)


def test_stream_unknown_rid_raises():
    fe = frontend_fixture()

    async def go():
        with pytest.raises(UnknownRequest):
            async for _ in fe.stream(404):
                pass
    asyncio.run(go())


def test_submit_after_drain_raises_fleet_closed():
    fe = frontend_fixture()

    async def go():
        rid = await fe.submit(np.arange(1, 6), 3)
        report = await fe.drain()
        assert rid in report.completed
        with pytest.raises(FleetClosed):
            await fe.submit(np.arange(1, 6), 3)
        # streaming a completed rid after drain still works (results are
        # final) — only NEW work is refused
        got = [t async for t in fe.stream(rid)]
        assert np.array_equal(got, report.completed[rid])
    asyncio.run(go())
