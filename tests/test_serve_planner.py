"""LBP capacity planner: §4 equal-finish-time traffic splits + drift."""

import numpy as np
import pytest

from repro.core.star import StarSchedule, per_processor_finish
from repro.serve import CapacityPlanner
from repro.serve.engine import ReplicaPlan


def _per_unit_cost(planner, n):
    """Finish-time cost of one extra request on the costliest replica."""
    net = planner.network()
    return float(np.max(n * net.w * net.t_cp + 2.0 * net.z * net.t_cm)) * n


def test_plan_shares_sum_and_schedule():
    pl = CapacityPlanner([120.0, 60.0, 180.0, 45.0], mode="PCCS")
    plan = pl.plan(64)
    assert isinstance(plan, ReplicaPlan)
    assert isinstance(plan.schedule, StarSchedule)
    assert plan.shares.sum() == 64
    assert np.all(plan.shares >= 0)
    assert plan.schedule.k.sum() == pytest.approx(64)
    # faster replica gets at least as much traffic
    order = np.argsort(pl.rates)
    assert np.all(np.diff(plan.shares[order]) >= 0)


@pytest.mark.parametrize("mode", ["PCSS", "PCCS", "SCSS", "SCCS"])
def test_equal_finish_time_property(mode):
    """§4 Theorem 2: the real-valued split equalizes replica finish times;
    the integer shares stay within one adjustment quantum of equal."""
    rng = np.random.default_rng(3)
    rates = rng.uniform(40.0, 250.0, 6)
    pl = CapacityPlanner(rates, mode=mode, quantum=1)
    n = 96
    plan = pl.plan(n)
    # real-valued: equal finish for every replica with load
    real_ft = per_processor_finish(pl.network(), n, plan.schedule.k, mode)
    loaded = plan.schedule.k > 1e-9
    spread = real_ft[loaded].max() - real_ft[loaded].min()
    assert spread <= 1e-6 * max(real_ft.max(), 1.0)
    # integer: within the cost of one quantum on the costliest replica
    int_ft = pl.finish_times(plan)
    assert int_ft.max() - int_ft.min() <= _per_unit_cost(pl, n) + 1e-9


def test_quantum_micro_batches():
    pl = CapacityPlanner([100.0, 50.0, 25.0], quantum=4, mode="PCSS")
    plan = pl.plan(32)
    assert plan.shares.sum() == 32
    assert np.all(plan.shares % 4 == 0)
    with pytest.raises(ValueError, match="quantum"):
        pl.plan(30)


def test_route_interleaves_by_share():
    pl = CapacityPlanner([100.0, 50.0, 50.0])
    plan = pl.plan(20)
    routed = pl.route(plan)
    assert routed.shape == (20,)
    np.testing.assert_array_equal(np.bincount(routed, minlength=3),
                                  plan.shares)
    # smooth round-robin: the heavy replica never waits long — every
    # window of 3 consecutive requests touches it at least once
    heavy = int(np.argmax(plan.shares))
    for j in range(len(routed) - 2):
        assert heavy in routed[j:j + 3]


def test_drift_replan_threshold():
    pl = CapacityPlanner([100.0, 100.0], drift_threshold=0.2)
    assert pl.observe([105.0, 100.0], 16) is None       # 5% drift: keep
    assert pl.rates[0] == 100.0
    plan = pl.observe([50.0, 100.0], 16)                # 50% drift: re-plan
    assert plan is not None
    assert pl.rates[0] == 50.0
    assert plan.shares[1] > plan.shares[0]


def test_observe_rejects_dead_replica():
    """A 0 tok/s measurement must not poison w = 1/rate with inf."""
    pl = CapacityPlanner([100.0, 100.0])
    with pytest.raises(ValueError, match="positive"):
        pl.observe([100.0, 0.0], 16)
    with pytest.raises(ValueError, match="positive"):
        pl.observe([100.0], 16)             # shrunk set needs a new planner
    assert np.all(pl.rates == 100.0)        # state untouched after reject


def test_replan_from_step_times():
    """The runtime.rebalance measurement path feeds the planner."""
    pl = CapacityPlanner([100.0, 100.0], drift_threshold=0.1)
    plan = pl.observe_step_times([0.02, 0.01], 16, tokens_per_step=1.0)
    assert plan is not None
    # replica 1 is twice as fast: about twice the traffic under PCCS
    assert plan.shares[1] >= 2 * plan.shares[0] - 2
