"""LBP capacity planner: §4 equal-finish-time traffic splits + drift +
page-capacity (memory-honest) splits for paged fleets."""

import numpy as np
import pytest

from repro.core.star import StarSchedule, per_processor_finish
from repro.serve import CapacityPlanner
from repro.serve.engine import PagedReplicaPlan, ReplicaPlan


def _per_unit_cost(planner, n):
    """Finish-time cost of one extra request on the costliest replica."""
    net = planner.network()
    return float(np.max(n * net.w * net.t_cp + 2.0 * net.z * net.t_cm)) * n


def test_plan_shares_sum_and_schedule():
    pl = CapacityPlanner([120.0, 60.0, 180.0, 45.0], mode="PCCS")
    plan = pl.plan(64)
    assert isinstance(plan, ReplicaPlan)
    assert isinstance(plan.schedule, StarSchedule)
    assert plan.shares.sum() == 64
    assert np.all(plan.shares >= 0)
    assert plan.schedule.k.sum() == pytest.approx(64)
    # faster replica gets at least as much traffic
    order = np.argsort(pl.rates)
    assert np.all(np.diff(plan.shares[order]) >= 0)


@pytest.mark.parametrize("mode", ["PCSS", "PCCS", "SCSS", "SCCS"])
def test_equal_finish_time_property(mode):
    """§4 Theorem 2: the real-valued split equalizes replica finish times;
    the integer shares stay within one adjustment quantum of equal."""
    rng = np.random.default_rng(3)
    rates = rng.uniform(40.0, 250.0, 6)
    pl = CapacityPlanner(rates, mode=mode, quantum=1)
    n = 96
    plan = pl.plan(n)
    # real-valued: equal finish for every replica with load
    real_ft = per_processor_finish(pl.network(), n, plan.schedule.k, mode)
    loaded = plan.schedule.k > 1e-9
    spread = real_ft[loaded].max() - real_ft[loaded].min()
    assert spread <= 1e-6 * max(real_ft.max(), 1.0)
    # integer: within the cost of one quantum on the costliest replica
    int_ft = pl.finish_times(plan)
    assert int_ft.max() - int_ft.min() <= _per_unit_cost(pl, n) + 1e-9


def test_quantum_micro_batches():
    pl = CapacityPlanner([100.0, 50.0, 25.0], quantum=4, mode="PCSS")
    plan = pl.plan(32)
    assert plan.shares.sum() == 32
    assert np.all(plan.shares % 4 == 0)
    with pytest.raises(ValueError, match="quantum"):
        pl.plan(30)


def test_route_interleaves_by_share():
    pl = CapacityPlanner([100.0, 50.0, 50.0])
    plan = pl.plan(20)
    routed = pl.route(plan)
    assert routed.shape == (20,)
    np.testing.assert_array_equal(np.bincount(routed, minlength=3),
                                  plan.shares)
    # smooth round-robin: the heavy replica never waits long — every
    # window of 3 consecutive requests touches it at least once
    heavy = int(np.argmax(plan.shares))
    for j in range(len(routed) - 2):
        assert heavy in routed[j:j + 3]


def test_drift_replan_threshold():
    pl = CapacityPlanner([100.0, 100.0], drift_threshold=0.2)
    assert pl.observe([105.0, 100.0], 16) is None       # 5% drift: keep
    assert pl.rates[0] == 100.0
    plan = pl.observe([50.0, 100.0], 16)                # 50% drift: re-plan
    assert plan is not None
    assert pl.rates[0] == 50.0
    assert plan.shares[1] > plan.shares[0]


def test_observe_rejects_dead_replica():
    """A 0 tok/s measurement must not poison w = 1/rate with inf."""
    pl = CapacityPlanner([100.0, 100.0])
    with pytest.raises(ValueError, match="positive"):
        pl.observe([100.0, 0.0], 16)
    with pytest.raises(ValueError, match="positive"):
        pl.observe([100.0], 16)             # shrunk set needs a new planner
    assert np.all(pl.rates == 100.0)        # state untouched after reject


def test_replan_from_step_times():
    """The runtime.rebalance measurement path feeds the planner."""
    pl = CapacityPlanner([100.0, 100.0], drift_threshold=0.1)
    plan = pl.observe_step_times([0.02, 0.01], 16, tokens_per_step=1.0)
    assert plan is not None
    # replica 1 is twice as fast: about twice the traffic under PCCS
    assert plan.shares[1] >= 2 * plan.shares[0] - 2


# ---------------------------------------------------------------------------
# page-capacity (memory-honest) splits for paged fleets
# ---------------------------------------------------------------------------

def test_plan_paged_unconstrained_matches_plan():
    """Ample memory everywhere: the paged split IS the §4 split."""
    rates = [120.0, 60.0, 180.0]
    pl = CapacityPlanner(rates, pages=[10_000] * 3)
    base = pl.plan(60)
    paged = pl.plan_paged(60, pages_per_request=4)
    assert isinstance(paged, PagedReplicaPlan)
    np.testing.assert_array_equal(paged.shares, base.shares)
    assert paged.partition is not None           # unclamped: full IR kept
    assert not paged.saturated.any()
    # page-seconds price the memory footprint of each share
    np.testing.assert_allclose(
        paged.page_seconds, paged.shares * 4 / np.asarray(rates))


def test_plan_paged_memory_caps_fast_replica():
    """A fast replica with a tiny page pool must be clamped at its
    capacity; the §4 solver redistributes the rest (waterfilling)."""
    rates = [300.0, 100.0, 100.0]                # replica 0 is fastest...
    pages = [8, 1000, 1000]                      # ...but memory-starved
    pl = CapacityPlanner(rates, pages=pages)
    paged = pl.plan_paged(40, pages_per_request=4)
    assert paged.shares[0] == 2                  # 8 pages / 4 per request
    assert bool(paged.saturated[0])
    assert not paged.saturated[1:].any()
    assert paged.shares.sum() == 40
    # the displaced load went to the unconstrained replicas evenly
    # (equal rates): within one request of each other
    assert abs(int(paged.shares[1]) - int(paged.shares[2])) <= 1
    assert paged.capacity[0] == 2


def test_plan_paged_all_replicas_at_capacity():
    pl = CapacityPlanner([100.0, 100.0], pages=[8, 8])
    paged = pl.plan_paged(4, pages_per_request=4)
    np.testing.assert_array_equal(paged.shares, [2, 2])
    assert paged.saturated.all()


def test_plan_paged_over_capacity_raises():
    pl = CapacityPlanner([100.0, 100.0], pages=[8, 8])
    with pytest.raises(ValueError, match="capacity"):
        pl.plan_paged(5, pages_per_request=4)


def test_plan_paged_requires_page_capacities():
    pl = CapacityPlanner([100.0, 100.0])
    with pytest.raises(ValueError, match="pages"):
        pl.plan_paged(4, pages_per_request=2)


def test_plan_paged_routes_like_any_plan():
    """PagedReplicaPlan flows through route() unchanged."""
    pl = CapacityPlanner([200.0, 100.0, 50.0], pages=[64, 64, 4])
    paged = pl.plan_paged(20, pages_per_request=4)
    routed = pl.route(paged)
    np.testing.assert_array_equal(np.bincount(routed, minlength=3),
                                  paged.shares)
    assert paged.shares[2] <= 1                  # memory-capped straggler
