"""Overlapped layer-streaming plane: modes, bytes, HLO structure, plan.

The paper's "simultaneous start" lifted from the kernel to the mesh:

  * stream_* aggregation modes are byte-identical to their blocking
    counterparts (stream_scatter == scatter, stream_gather == allreduce,
    stream_hierarchical == hierarchical) but lower to ppermute rings;
  * the streamed matmul primitives are allclose to all-gather->einsum and
    einsum->psum_scatter on a real 8-device (2-pod) mesh, including the
    uneven plan()-assigned ragged shares;
  * the lowered overlapped ``lbp_row_parallel`` carries ZERO all-gathers
    and exactly p-1 collective-permutes whose bytes match the registry;
  * a full train step on a (pod, data, model) mesh is loss-identical to
    the blocking path;
  * the "overlap" planning objective predicts finish = max(comm, comp)
    and its split equalizes that bound.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import collectives
from repro.core.network import StarNetwork
from repro.core.star import SOLVERS, per_processor_finish
from repro.plan import (HierarchicalTopology, StarTopology, evaluate_split,
                        plan)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# registry: modes + exact byte accounting
# ---------------------------------------------------------------------------

def test_stream_modes_registered():
    for name in ("stream_scatter", "stream_gather", "stream_hierarchical"):
        assert name in collectives.available_modes()
        assert not collectives.get_mode(name).adds_device_axis


def test_stream_bytes_match_blocking_counterparts():
    """Streaming changes the op shape, never the bytes: each stream mode's
    per-device link bytes equal its blocking counterpart for every p —
    including the bidirectional half-ring variants (direction split moves
    hops between links, never adds bytes)."""
    pairs = [("stream_scatter", "scatter"), ("stream_gather", "allreduce"),
             ("stream_hierarchical", "hierarchical"),
             ("stream_scatter_bidir", "scatter"),
             ("stream_gather_bidir", "allreduce")]
    for out_elems in (1, 4096, 1 << 20):
        for p in (2, 4, 8, 64):
            for itemsize in (1, 2, 4):
                for stream, blocking in pairs:
                    assert collectives.collective_bytes_per_device(
                        out_elems, p, stream, itemsize) == pytest.approx(
                        collectives.collective_bytes_per_device(
                            out_elems, p, blocking, itemsize)), (stream, p)


def test_stream_out_specs():
    assert collectives.out_spec("stream_gather", "model",
                                ("data", None, None)) == P("data", None, None)
    assert collectives.out_spec("stream_scatter", "model",
                                ("data", None, None)) == \
        collectives.out_spec("scatter", "model", ("data", None, None))
    assert collectives.out_spec("stream_hierarchical", ("pod", "model"),
                                ("data", None, None)) == P("data", None, None)


def test_stream_hier_rejects_single_axis():
    with pytest.raises(ValueError, match="pod_axis"):
        collectives.get_mode("stream_hierarchical").combine(None, "model", 0)


def test_expected_ppermutes():
    from repro.core.overlap import expected_ppermutes
    assert expected_ppermutes("stream_scatter", 8) == 7
    assert expected_ppermutes("stream_gather", 8) == 14
    assert expected_ppermutes("stream_scatter", 4, fsdp_ring=2) == 4
    # direction split never changes the op count, only the chain depth
    assert expected_ppermutes("stream_scatter_bidir", 8) == 7
    assert expected_ppermutes("stream_gather_bidir", 8) == 14


# ---------------------------------------------------------------------------
# bidirectional half-rings: hop split, depth, mode selection
# ---------------------------------------------------------------------------

def test_bidir_modes_registered():
    for name in ("stream_scatter_bidir", "stream_gather_bidir"):
        assert name in collectives.available_modes()
        assert not collectives.get_mode(name).adds_device_axis
    # out specs match the unidirectional flavour exactly
    assert collectives.out_spec("stream_scatter_bidir", "model",
                                ("data", None, None)) == \
        collectives.out_spec("stream_scatter", "model", ("data", None, None))
    assert collectives.out_spec("stream_gather_bidir", "model",
                                ("data", None, None)) == P("data", None, None)


def test_bidir_hop_split_and_depth():
    """ceil((p-1)/2) forward + floor((p-1)/2) backward hops, summing to the
    unidirectional p-1; the dependent chain halves."""
    from repro.core.overlap import (bidir_hops, expected_direction_counts,
                                    sequential_hop_depth)
    for p in (2, 3, 4, 5, 8, 16):
        hf, hb = bidir_hops(p)
        assert hf + hb == p - 1
        assert hf == -(-(p - 1) // 2) and hb == (p - 1) // 2
        assert expected_direction_counts("stream_scatter_bidir", p) == (hf, hb)
        assert expected_direction_counts("stream_gather_bidir", p) == \
            (2 * hf, 2 * hb)
        assert sequential_hop_depth("stream_scatter_bidir", p) == hf
        assert sequential_hop_depth("stream_gather_bidir", p) == 2 * hf
        assert sequential_hop_depth("stream_scatter", p) == p - 1
    with pytest.raises(ValueError, match="bidirectional"):
        expected_direction_counts("stream_scatter", 4)


def test_aggregation_mode_selects_bidir_suffix():
    """TUNING.overlap_bidir (or the explicit kwarg) appends _bidir to the
    stream modes; blocking modes never grow the suffix."""
    import dataclasses
    from repro.models.lbp_linear import aggregation_mode
    from repro.sharding.rules import Rules
    sp = dataclasses.replace(Rules.null(), seq="model")
    rep = Rules.null()
    assert aggregation_mode(sp, streaming=True, bidir=True) == \
        "stream_scatter_bidir"
    assert aggregation_mode(rep, streaming=True, bidir=True) == \
        "stream_gather_bidir"
    assert aggregation_mode(sp, streaming=True, bidir=False) == \
        "stream_scatter"
    # bidir without streaming: the blocking modes have no bidir flavour
    assert aggregation_mode(sp, streaming=False, bidir=True) == "scatter"
    assert aggregation_mode(rep, streaming=False, bidir=True) == "allreduce"


# ---------------------------------------------------------------------------
# "overlap" planning objective: finish = max(comm, compute)
# ---------------------------------------------------------------------------

def test_overlap_solver_equalizes_max_bound():
    net = StarNetwork(w=np.array([1.0, 2.0, 0.5, 1.0]),
                      z=np.array([1e-9, 1e-3, 5e-3, 1e-9]))
    N = 512
    sched = SOLVERS["overlap"](net, N)
    assert sched.k.sum() == pytest.approx(N)
    per_unit = np.maximum(N * net.w * net.t_cp, 2.0 * net.z * net.t_cm)
    bounds = sched.k * N * per_unit
    np.testing.assert_allclose(bounds, bounds[0], rtol=1e-9)
    tf = per_processor_finish(net, N, sched.k, "overlap")
    np.testing.assert_allclose(tf, sched.finish_time, rtol=1e-9)


def test_overlap_finish_never_exceeds_serial():
    """max(comm, comp) <= comm + comp pointwise, so for the SAME split the
    overlapped prediction can never be worse than PCCS's serial one."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        p = int(rng.integers(2, 12))
        net = StarNetwork(w=rng.uniform(0.2, 3.0, p),
                          z=rng.uniform(1e-9, 1e-2, p))
        N = int(rng.integers(64, 1024))
        k = rng.dirichlet(np.ones(p)) * N
        serial = per_processor_finish(net, N, k, "PCCS")
        ov = per_processor_finish(net, N, k, "overlap")
        comp = per_processor_finish(net, N, k, "PCSS")
        assert np.all(ov <= serial + 1e-12)
        assert np.all(ov >= comp - 1e-12)   # PCSS assumes comm always hidden


def test_plan_carries_both_predictions_star():
    topo = StarTopology(w=np.array([1.0, 1.5, 0.7, 1.2]),
                        z=np.array([1e-9, 1e-3, 1e-3, 1e-9]))
    pp = plan(topo, 1024, objective="PCCS")
    assert pp.finish_times_overlap is not None
    assert pp.finish_time_overlap <= pp.finish_time + 1e-12
    assert pp.summary()["finish_time_overlap"] == pp.finish_time_overlap
    # the overlap objective plans directly against the streamed plane
    po = plan(topo, 1024, objective="overlap")
    assert po.solver == "star:overlap"
    assert po.finish_time <= pp.finish_time_overlap + 1e-9
    # evaluate_split prices any split on the overlapped plane
    ev = evaluate_split(topo, pp.k, 1024, objective="overlap")
    np.testing.assert_allclose(ev, pp.finish_times_overlap)


def test_plan_overlap_hierarchical():
    topo = HierarchicalTopology.from_pod_speeds(
        [[1.0, 1.2, 0.8, 1.0], [1.1, 0.9, 1.0, 1.3]])
    pp = plan(topo, 2048, objective="PCCS")
    po = plan(topo, 2048, objective="overlap")
    assert pp.finish_times_overlap is not None
    assert po.solver.startswith("hierarchical:overlap")
    assert int(po.k.sum()) == 2048
    # overlapped prediction of the overlap-objective split beats (or ties)
    # the serial prediction of the serial split
    assert po.finish_time <= pp.finish_time + 1e-9
    ev = evaluate_split(topo, po.k, 2048, objective="overlap")
    loaded = po.k > 0
    assert float(ev[loaded].max()) == pytest.approx(po.finish_time)


def test_plan_overlap_mesh_has_no_model():
    from repro.core.network import random_mesh
    from repro.plan import MeshTopology
    pm = plan(MeshTopology.from_network(random_mesh(3, 3, seed=0)), 100)
    assert pm.finish_times_overlap is None
    assert pm.finish_time_overlap is None


# ---------------------------------------------------------------------------
# multi-device semantics (subprocess, 8 host devices)
# ---------------------------------------------------------------------------

def test_stream_modes_match_blocking_multi_device():
    """Streamed aggregation == blocking on a real 2-pod (2x4) mesh, for
    even and uneven plan()-assigned shares."""
    out = run_sub("""
        import jax, numpy as np
        from repro.compat import make_mesh
        from repro.core.lbp_matmul import (lbp_matmul, lbp_matmul_reference,
                                           lbp_matmul_heterogeneous)
        from repro.core.partition import LayerAssignment
        assert len(jax.devices()) == 8
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        ref = np.asarray(lbp_matmul_reference(x, w))

        mesh = make_mesh((2, 4), ("pod", "model"))
        flat = make_mesh((8,), ("model",))
        for msh, axis in ((flat, "model"), (mesh, "model")):
            for mode in ("stream_gather", "stream_scatter"):
                got = jax.jit(lambda x, w: lbp_matmul(
                    x, w, msh, axis=axis, mode=mode))(x, w)
                assert np.abs(np.asarray(got) - ref).max() < 1e-4, mode
        got = jax.jit(lambda x, w: lbp_matmul(
            x, w, mesh, axis=("pod", "model"),
            mode="stream_hierarchical"))(x, w)
        assert np.abs(np.asarray(got) - ref).max() < 1e-4

        # uneven plan()-assigned layer shares (ragged heterogeneous split)
        asg = LayerAssignment.from_speeds(64, [1., 2., 4., 1., 1., 1., 2., 1.])
        assert not asg.is_even()
        for mode in ("stream_gather", "stream_scatter"):
            got = jax.jit(lambda x, w: lbp_matmul_heterogeneous(
                x, w, asg, flat, axis="model", mode=mode))(x, w)
            assert np.abs(np.asarray(got) - ref).max() < 1e-4, mode
        print("MODES-OK")
    """)
    assert "MODES-OK" in out


def test_streamed_primitives_match_blocking_collectives():
    """streamed_gather_matmul == all_gather->einsum and
    streamed_scatter_matmul == einsum->psum_scatter inside shard_map."""
    out = run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.core import overlap
        assert len(jax.devices()) == 8
        mesh = make_mesh((2, 4), ("pod", "model"))
        h = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))

        def gather_stream(hl, wl):
            return overlap.streamed_gather_matmul(hl, wl, "model")
        def gather_block(hl, wl):
            return jnp.einsum("bsf,fd->bsd", hl, jax.lax.all_gather(
                wl, "model", axis=1, tiled=True))
        specs = dict(in_specs=(P("pod", None, None), P(None, "model")),
                     out_specs=P("pod", None, None))
        a = jax.jit(shard_map(gather_stream, mesh=mesh,
                              check_vma=False, **specs))(h, w)
        b = jax.jit(shard_map(gather_block, mesh=mesh,
                              check_vma=False, **specs))(h, w)
        assert np.abs(np.asarray(a) - np.asarray(b)).max() < 1e-5

        def scatter_stream(hl, wl):
            return overlap.streamed_scatter_matmul(hl, wl, "model",
                                                   scatter_dim=1)
        def scatter_block(hl, wl):
            return jax.lax.psum_scatter(
                jnp.einsum("bsf,fd->bsd", hl, wl), "model",
                scatter_dimension=1, tiled=True)
        specs = dict(in_specs=(P("pod", None, "model"), P("model", None)),
                     out_specs=P("pod", "model", None))
        a = jax.jit(shard_map(scatter_stream, mesh=mesh,
                              check_vma=False, **specs))(h, w)
        b = jax.jit(shard_map(scatter_block, mesh=mesh,
                              check_vma=False, **specs))(h, w)
        assert np.abs(np.asarray(a) - np.asarray(b)).max() < 1e-5
        print("PRIM-OK")
    """)
    assert "PRIM-OK" in out


def test_overlapped_hlo_structure_and_bytes():
    """The lowered overlapped lbp_row_parallel: zero all-gathers, exactly
    p-1 ppermutes, link bytes == the registry's stream_scatter row."""
    out = run_sub("""
        import jax, numpy as np
        from repro.analysis.hlo_collectives import collective_summary
        from repro.compat import make_mesh
        from repro.core import collectives, overlap
        from repro.models import lbp_linear
        from repro.models.tuning import set_tuning
        from repro.sharding.rules import Rules
        B, S, K, d, p = 2, 16, 64, 32, 8
        mesh = make_mesh((p,), ("model",))
        rules = Rules(seq="model", ff="model", mesh=mesh)
        h = jax.random.normal(jax.random.PRNGKey(0), (B, S, K))
        w = jax.random.normal(jax.random.PRNGKey(1), (K, d))
        set_tuning(explicit_lbp_scatter=True, overlap_streaming=True)
        comp = jax.jit(lambda h, w: lbp_linear.lbp_row_parallel(h, w, rules)
                       ).lower(h, w).compile()
        summ = collective_summary(comp.as_text(), p)
        per_op = summ["per_op"]
        assert "all-gather" not in per_op, per_op
        assert "all-reduce" not in per_op, per_op
        assert "reduce-scatter" not in per_op, per_op
        pp = per_op["collective-permute"]
        assert pp["count"] == overlap.expected_ppermutes("stream_scatter", p)
        analytic = collectives.collective_bytes_per_device(
            B * S * d, p, "stream_scatter", itemsize=4)
        assert abs(pp["link_bytes"] - analytic) < 1e-6, (pp, analytic)

        # the full (pod, data, model) mesh keeps the module all-gather-free
        # (the FSDP weight ring replaces the blocking gather)
        mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules3 = Rules(batch=("pod", "data"), seq="model", embed="data",
                       ff="model", mesh=mesh3)
        h3 = jax.random.normal(jax.random.PRNGKey(2), (4, 8, K))
        c3 = jax.jit(lambda h, w: lbp_linear.lbp_row_parallel(h, w, rules3)
                     ).lower(h3, w).compile()
        s3 = collective_summary(c3.as_text(), 8)
        assert "all-gather" not in s3["per_op"], s3["per_op"]
        set_tuning(explicit_lbp_scatter=False, overlap_streaming=False)
        print("HLO-OK")
    """)
    assert "HLO-OK" in out


def test_bidir_rings_match_blocking_multi_device():
    """Bidirectional half-ring primitives == their blocking collectives on
    8 host devices, and the bidir registry modes reproduce the reference
    matmul for p in {2, 4, 8} (odd backward ring exercised at p=2: zero
    backward hops)."""
    out = run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.core import overlap
        from repro.core.lbp_matmul import lbp_matmul, lbp_matmul_reference
        assert len(jax.devices()) == 8
        mesh = make_mesh((8,), ("model",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64))

        def rs_bidir(xl):
            return overlap.ring_reduce_scatter_bidir(xl, "model", sd=1)
        def rs_block(xl):
            return jax.lax.psum_scatter(xl, "model", scatter_dimension=1,
                                        tiled=True)
        specs = dict(in_specs=(P(None, None, "model"),),
                     out_specs=P(None, "model", None))
        a = jax.jit(shard_map(rs_bidir, mesh=mesh, check_vma=False,
                              **specs))(x)
        b = jax.jit(shard_map(rs_block, mesh=mesh, check_vma=False,
                              **specs))(x)
        assert np.abs(np.asarray(a) - np.asarray(b)).max() < 1e-5

        def ag_bidir(xl):
            return overlap.ring_all_gather_bidir(xl, "model", sd=1)
        def ag_block(xl):
            return jax.lax.all_gather(xl, "model", axis=1, tiled=True)
        specs = dict(in_specs=(P(None, "model", None),),
                     out_specs=P(None, None, None))
        a = jax.jit(shard_map(ag_bidir, mesh=mesh, check_vma=False,
                              **specs))(x)
        b = jax.jit(shard_map(ag_block, mesh=mesh, check_vma=False,
                              **specs))(x)
        assert np.abs(np.asarray(a) - np.asarray(b)).max() < 1e-5

        # registry modes end-to-end, even/odd ring sizes
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        ref = np.asarray(lbp_matmul_reference(x, w))
        for p in (2, 4, 8):
            msh = make_mesh((p,), ("model",))
            for mode in ("stream_scatter_bidir", "stream_gather_bidir"):
                got = jax.jit(lambda x, w: lbp_matmul(
                    x, w, msh, axis="model", mode=mode))(x, w)
                assert np.abs(np.asarray(got) - ref).max() < 1e-4, (p, mode)
        print("BIDIR-OK")
    """)
    assert "BIDIR-OK" in out


def test_bidir_hlo_structure_direction_counts():
    """The lowered bidir lbp_row_parallel stays all-gather-free with the
    SAME ppermute count and link bytes as the unidirectional plane, but
    the permutes split ceil((p-1)/2) forward / floor((p-1)/2) backward —
    the halved-chain-depth structure the mode exists for."""
    out = run_sub("""
        import jax, numpy as np
        from repro.analysis.hlo_collectives import (collective_summary,
                                                    permute_direction_counts)
        from repro.compat import make_mesh
        from repro.core import collectives, overlap
        from repro.models import lbp_linear
        from repro.models.tuning import set_tuning
        from repro.sharding.rules import Rules
        B, S, K, d, p = 2, 16, 64, 32, 8
        mesh = make_mesh((p,), ("model",))
        rules = Rules(seq="model", ff="model", mesh=mesh)
        h = jax.random.normal(jax.random.PRNGKey(0), (B, S, K))
        w = jax.random.normal(jax.random.PRNGKey(1), (K, d))
        set_tuning(explicit_lbp_scatter=True, overlap_streaming=True,
                   overlap_bidir=True)
        assert lbp_linear.aggregation_mode(rules) == "stream_scatter_bidir"
        comp = jax.jit(lambda h, w: lbp_linear.lbp_row_parallel(h, w, rules)
                       ).lower(h, w).compile()
        hlo = comp.as_text()
        summ = collective_summary(hlo, p)
        per_op = summ["per_op"]
        assert "all-gather" not in per_op, per_op
        assert "all-reduce" not in per_op, per_op
        assert "reduce-scatter" not in per_op, per_op
        pp = per_op["collective-permute"]
        assert pp["count"] == overlap.expected_ppermutes(
            "stream_scatter_bidir", p)
        analytic = collectives.collective_bytes_per_device(
            B * S * d, p, "stream_scatter_bidir", itemsize=4)
        assert abs(pp["link_bytes"] - analytic) < 1e-6, (pp, analytic)
        dirs = permute_direction_counts(hlo, p)
        hf, hb = overlap.expected_direction_counts("stream_scatter_bidir", p)
        assert dirs["forward"] == hf and dirs["backward"] == hb, dirs
        assert dirs["other"] == 0, dirs
        set_tuning(explicit_lbp_scatter=False, overlap_streaming=False,
                   overlap_bidir=False)
        print("BIDIR-HLO-OK")
    """)
    assert "BIDIR-HLO-OK" in out


def test_train_step_restores_global_tuning():
    """make_train_step(overlap_streaming=...) must not leak the flags into
    the process-global TUNING: they are set around the trace and restored,
    so later steps built with the default None are unaffected."""
    import jax
    from repro.configs import get_reduced
    from repro.models.tuning import TUNING
    from repro.optim.adamw import AdamWConfig
    from repro.sharding.rules import Rules
    from repro.train.step import init_train_state, make_train_step
    assert not TUNING.overlap_streaming and not TUNING.explicit_lbp_scatter
    cfg = get_reduced("llama3_2_3b")
    st = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": np.zeros((2, 16), np.int32)}
    step = make_train_step(cfg, Rules.null(), AdamWConfig(), 1,
                           overlap_streaming=True, overlap_bidir=True)
    jax.jit(step)(st, batch)
    assert not TUNING.overlap_streaming, "flag leaked past the trace"
    assert not TUNING.explicit_lbp_scatter, "flag leaked past the trace"
    assert not TUNING.overlap_bidir, "flag leaked past the trace"


def test_train_step_overlap_parity_pod_mesh():
    """A real train step on the (pod, data, model) mesh: the overlapped
    streaming plane is loss-identical to the blocking default."""
    out = run_sub("""
        import jax, numpy as np, dataclasses
        from repro.compat import make_mesh
        from repro.configs import get_reduced
        from repro.sharding.rules import make_rules
        from repro.train.step import (init_train_state, make_train_step,
                                      train_state_specs)
        from repro.optim.adamw import AdamWConfig
        from repro.models.tuning import set_tuning
        from jax.sharding import NamedSharding
        cfg = dataclasses.replace(get_reduced("llama3_2_3b"), tp=2)
        opt = AdamWConfig(warmup_steps=2, total_steps=10)
        key = jax.random.PRNGKey(0)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0,
                                              cfg.vocab_size)}
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        losses = {}
        for name, prof, ov, bd in [("default", "train", None, None),
                                   ("overlap", "train_sp", True, None),
                                   ("bidir", "train_sp", True, True)]:
            set_tuning(explicit_lbp_scatter=False, overlap_streaming=False,
                       overlap_bidir=False)
            rules = make_rules(prof, mesh)
            with mesh:
                st = init_train_state(cfg, key)
                sspec = train_state_specs(cfg, rules)
                st = jax.device_put(st, jax.tree.map(
                    lambda s: NamedSharding(mesh, s), sspec,
                    is_leaf=lambda s: isinstance(
                        s, jax.sharding.PartitionSpec)))
                step = make_train_step(cfg, rules, opt, 2,
                                       overlap_streaming=ov,
                                       overlap_bidir=bd)
                _, m = jax.jit(step)(st, batch)
            losses[name] = float(m["loss"])
        assert np.isclose(losses["default"], losses["overlap"],
                          rtol=2e-3), losses
        assert np.isclose(losses["default"], losses["bidir"],
                          rtol=2e-3), losses
        print("TRAIN-OK", losses)
    """)
    assert "TRAIN-OK" in out
