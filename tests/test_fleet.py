"""Fleet runtime invariants: exactly-once tokens under any kill/join
schedule, heartbeat health, backpressure, rescale re-planning.

Scheduling/rescale invariants run against the tensor-light FakeModel
(hypothesis properties over random workloads and fault schedules); the
fleet oracle acceptance test runs the real transformer on the reduced
llama3_2_3b config: 32 heavy-tailed staggered requests on a 3-replica
heterogeneous fleet with one replica killed mid-decode and one joining
later, token-identical to per-request ``greedy_generate``.
"""

import asyncio

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.fleet import (FaultPlan, FleetController, FleetFrontend,
                         Replica, ReplicaDead, build_engine)
from repro.serve.engine import EngineConfig, synthetic_workload
from test_serve_engine import FakeModel


def fake_replica(name, rate=1.0, fault=None, n_slots=4):
    cfg = EngineConfig(n_slots=n_slots, max_prompt_len=32, max_new_cap=16,
                       cache_len=48)
    return Replica(name, FakeModel(), cfg, rate=rate, fault=fault)


def fake_workload(n, seed=0, stagger=0.5):
    return synthetic_workload(n, FakeModel.V, lens=(5, 8, 12, 16),
                              news=(2, 3, 6, 9), stagger=stagger,
                              seed=seed)


def check_oracle(workload, completed):
    fm = FakeModel()
    assert set(completed) == set(range(len(workload)))
    for rid, (p, m, _) in enumerate(workload):
        toks = completed[rid]
        assert toks.shape == (m,), (rid, toks.shape, m)
        np.testing.assert_array_equal(toks, fm.oracle(p, m)), rid


# ---------------------------------------------------------------------------
# engine step-callable surface (the extraction the replica plane wraps)
# ---------------------------------------------------------------------------

def test_engine_incremental_harvest_and_streaming():
    eng = build_engine(FakeModel(), EngineConfig(
        n_slots=2, max_prompt_len=16, max_new_cap=8, cache_len=24))
    fm = FakeModel()
    p0, p1 = np.arange(1, 6), np.arange(3, 11)
    r0, r1 = eng.submit(p0, 6), eng.submit(p1, 3, arrival=2.0)
    seen = {}
    streamed = []
    while eng.step():
        seen.update(eng.harvest())
        streamed.append(eng.tokens_so_far(r0).copy())
        # harvest returns each completion exactly once
        assert not (set(eng.harvest()) & set(seen))
    seen.update(eng.harvest())
    np.testing.assert_array_equal(seen[r0], fm.oracle(p0, 6))
    np.testing.assert_array_equal(seen[r1], fm.oracle(p1, 3))
    # streaming prefixes are monotone prefixes of the final tokens
    for pre in streamed:
        np.testing.assert_array_equal(pre, seen[r0][:pre.shape[0]])
    assert eng.outstanding() == []
    prog = eng.progress()
    assert prog["n_completed"] == 2 and prog["n_active"] == 0


def test_engine_outstanding_is_the_failover_set():
    eng = build_engine(FakeModel(), EngineConfig(
        n_slots=1, max_prompt_len=16, max_new_cap=8, cache_len=24))
    rids = [eng.submit(np.arange(1, 5), 4) for _ in range(3)]
    assert [r.rid for r in eng.outstanding()] == rids   # all queued
    eng.step()                                          # admit the first
    out = eng.outstanding()
    assert [r.rid for r in out] == rids                 # still owed: all
    for _ in range(4):
        eng.step()
    eng.harvest()
    assert [r.rid for r in eng.outstanding()] != rids   # first one paid


# ---------------------------------------------------------------------------
# replica plane: faults and heartbeats
# ---------------------------------------------------------------------------

def test_replica_kill_fault_raises():
    rep = fake_replica("r", fault=FaultPlan(kill_at=3))
    rep.submit(np.arange(1, 9), 8)
    rep.step(0)
    rep.step(1)
    with pytest.raises(ReplicaDead):
        rep.step(2)


def test_replica_hang_stops_heartbeat():
    rep = fake_replica("r", fault=FaultPlan(hang_at=2))
    rep.submit(np.arange(1, 9), 8)
    assert rep.step(0)
    assert rep.last_heartbeat == 0
    for t in range(1, 5):
        rep.step(t)
    assert rep.last_heartbeat == 0      # silent since the hang


def test_heartbeat_death_exactly_at_miss_threshold_boundary():
    """Off-by-one pin: a hung replica is declared dead at the FIRST tick
    where (tick - last_heartbeat) EXCEEDS miss_threshold — alive through
    tick last_heartbeat + miss_threshold, killed on the next one."""
    miss = 3
    hung = fake_replica("hung", fault=FaultPlan(hang_at=2))
    good = fake_replica("good")
    ctrl = FleetController([hung, good], miss_threshold=miss)
    wl = fake_workload(8, seed=1, stagger=0.0)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    # hang_at=2: the hung replica's last beat lands at tick 1 (its step
    # 2, the first silent one, runs at tick 1... step counts are 1-based
    # per tick), so observe the actual last_heartbeat then pin the kill
    while hung.alive and not ctrl.kills:
        ctrl.tick()
        if hung.last_heartbeat + miss >= ctrl.tick_count:
            assert hung.alive, (
                f"killed early: hb={hung.last_heartbeat} miss={miss} "
                f"tick={ctrl.tick_count}")
    kill_tick, name = ctrl.kills[0]
    assert name == "hung"
    # the kill happened exactly when the gap first EXCEEDED the
    # threshold: t - hb == miss + 1, never sooner, never later
    assert kill_tick - hung.last_heartbeat == miss + 1
    report = ctrl.run()
    check_oracle(wl, report.completed)


def test_heartbeat_miss_declares_dead_and_requeues():
    hung = fake_replica("hung", fault=FaultPlan(hang_at=2))
    good = fake_replica("good")
    ctrl = FleetController([hung, good], miss_threshold=2)
    wl = fake_workload(8, seed=1)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    report = ctrl.run()
    assert [name for _, name in report.kills] == ["hung"]
    assert "heartbeat-miss" in " ".join(report.events)
    assert report.requeues >= 1
    check_oracle(wl, report.completed)


# ---------------------------------------------------------------------------
# controller: exactly-once under kill/join (property over schedules)
# ---------------------------------------------------------------------------

def test_kill_and_join_token_identical():
    reps = [fake_replica("a", 1.0, FaultPlan(kill_at=6)),
            fake_replica("b", 2.0), fake_replica("c", 0.5)]
    ctrl = FleetController(reps, miss_threshold=3)
    ctrl.schedule_join(fake_replica("d", 1.5), at_tick=10)
    wl = fake_workload(32, seed=3)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    report = ctrl.run()
    check_oracle(wl, report.completed)
    assert report.requeues >= 1
    assert [n for _, n in report.kills] == ["a"]
    assert [n for _, n in report.joins] == ["d"]
    # the joiner actually served (it joined while work remained)
    assert report.decode_tokens["d"] > 0


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**16),
       n=st.integers(4, 24),
       kill_at=st.integers(1, 20),
       join_at=st.integers(1, 24),
       stagger=st.sampled_from([0.0, 0.5, 2.0]))
def test_fleet_exactly_once_property(seed, n, kill_at, join_at, stagger):
    """No token lost or duplicated under ANY (kill, join, arrival)
    schedule: the fleet equals the per-request oracle."""
    reps = [fake_replica("a", 1.0, FaultPlan(kill_at=kill_at)),
            fake_replica("b", 1.7)]
    ctrl = FleetController(reps, miss_threshold=3)
    ctrl.schedule_join(fake_replica("c", 0.6), at_tick=join_at)
    wl = fake_workload(n, seed=seed, stagger=stagger)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    report = ctrl.run()
    check_oracle(wl, report.completed)


def test_scheduled_kill_drains_via_requeue():
    reps = [fake_replica("a", 1.0), fake_replica("b", 1.0)]
    ctrl = FleetController(reps)
    wl = fake_workload(12, seed=7, stagger=0.0)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    ctrl.schedule_kill("a", at_tick=2)
    report = ctrl.run()
    check_oracle(wl, report.completed)
    assert report.kills and report.requeues >= 1


def test_all_dead_raises_instead_of_hanging():
    ctrl = FleetController([fake_replica("a", 1.0,
                                         FaultPlan(kill_at=1))])
    ctrl.submit(np.arange(1, 9), 8)
    with pytest.raises(RuntimeError, match="no live replica"):
        ctrl.run()


def test_rescale_replans_through_runtime_rebalance():
    reps = [fake_replica("a", 1.0, FaultPlan(kill_at=4)),
            fake_replica("b", 2.0), fake_replica("c", 1.0)]
    ctrl = FleetController(reps, virtual_k=1024)
    k0 = ctrl.rebalance.assignment.k.copy()
    assert k0.shape == (3,) and k0.sum() == 1024
    wl = fake_workload(16, seed=5)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    ctrl.schedule_join(fake_replica("d", 4.0), at_tick=8)
    report = ctrl.run()
    check_oracle(wl, report.completed)
    k1 = ctrl.rebalance.assignment.k
    # after kill(a) + join(d): shares cover {b, c, d}, d (fastest) largest
    assert k1.shape == (3,) and k1.sum() == 1024
    assert ctrl.alive_names() == ["b", "c", "d"]
    assert k1[2] == k1.max()


# ---------------------------------------------------------------------------
# async front-end: backpressure and streaming
# ---------------------------------------------------------------------------

def test_frontend_backpressure_bounds_depth():
    ctrl = FleetController([fake_replica("a", 1.0, n_slots=2)])
    fe = FleetFrontend(ctrl, max_pending=3)
    wl = fake_workload(10, seed=2, stagger=0.0)

    async def go():
        for p, m, a in wl:
            await fe.submit(p, m, arrival=a)
            assert fe.depth <= fe.max_pending
        return await fe.drain()

    report = asyncio.run(go())
    check_oracle(wl, report.completed)


def test_frontend_stream_exactly_once_across_kill():
    """Stream a request while its replica is killed mid-decode: the
    consumer sees every token exactly once (the sent-cursor rides the
    deterministic regeneration)."""
    reps = [fake_replica("a", 1.0, FaultPlan(kill_at=4)),
            fake_replica("b", 1.0)]
    ctrl = FleetController(reps, miss_threshold=3)
    fe = FleetFrontend(ctrl, max_pending=16)
    wl = fake_workload(8, seed=11, stagger=0.0)
    report = fe.serve(wl, stream_rids=tuple(range(len(wl))))
    check_oracle(wl, report.completed)
    assert report.requeues >= 1
    for rid in range(len(wl)):
        np.testing.assert_array_equal(
            np.asarray(fe.streamed[rid], np.int32),
            report.completed[rid])


def test_frontend_serve_matches_controller_run():
    wl = fake_workload(10, seed=9)
    reports = []
    for _ in range(2):
        ctrl = FleetController([fake_replica("a", 1.0),
                                fake_replica("b", 2.0)])
        fe = FleetFrontend(ctrl, max_pending=4)
        reports.append(fe.serve(wl))
    # the tick clock makes the whole fleet deterministic run-to-run
    assert reports[0].ticks == reports[1].ticks
    assert reports[0].occupancy == reports[1].occupancy
    for rid in reports[0].completed:
        np.testing.assert_array_equal(reports[0].completed[rid],
                                      reports[1].completed[rid])


# ---------------------------------------------------------------------------
# dynamic correction: drift-triggered work stealing over the fleet plan
# ---------------------------------------------------------------------------

def saturated_workload(n=48, seed=7, stagger=0.25):
    """Uniform shapes + tight arrivals: every replica keeps a queued
    backlog (the stealable resource) and per-slot throughput is the clean
    contention signal."""
    return synthetic_workload(n, FakeModel.V, lens=(8,), news=(6,),
                              stagger=stagger, seed=seed)


def test_fleet_steal_zero_when_undisturbed():
    """Hysteresis contract: a healthy fleet with stealing ON performs
    zero steals and serves the exact greedy-oracle tokens — the corrector
    must be invisible on the unperturbed path."""
    reps = [fake_replica("a"), fake_replica("b"), fake_replica("c")]
    ctrl = FleetController(reps, steal=True)
    wl = saturated_workload()
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    report = ctrl.run()
    assert report.steals == 0
    assert not any("steal" in e for e in report.events)
    check_oracle(wl, report.completed)


def test_fleet_steal_corrects_contended_replica():
    """Injected 4x contention on one replica (alive, beating its
    heartbeat — the health plane must NOT kill it): the drift corrector
    trips, sheds queued backlog to the healthy replicas through the
    exactly-once requeue path, and the fleet drains strictly faster than
    the same run without stealing."""
    def build(steal):
        reps = [fake_replica("a", fault=FaultPlan(slow_at=2,
                                                  slow_factor=4)),
                fake_replica("b"), fake_replica("c")]
        ctrl = FleetController(reps, miss_threshold=6, steal=steal)
        for p, m, a in saturated_workload():
            ctrl.submit(p, m, arrival=a)
        return ctrl
    static = build(steal=False)
    rs = static.run()
    corrected = build(steal=True)
    rc = corrected.run()
    assert rs.kills == [] and rc.kills == []   # contended != dead
    assert rc.steals >= 1
    assert rc.requeues >= 1                    # shed rode the requeue path
    assert any("steal" in e for e in rc.events)
    assert corrected.tick_count < static.tick_count, (
        corrected.tick_count, static.tick_count)
    check_oracle(saturated_workload(), rc.completed)
    check_oracle(saturated_workload(), rs.completed)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16),
       slow_factor=st.sampled_from([0, 2, 3, 4]),
       stagger=st.sampled_from([0.25, 0.5, 1.0]),
       n_reps=st.integers(2, 4))
def test_fleet_steal_contention_property(seed, slow_factor, stagger,
                                         n_reps):
    """Property over contention schedules: (a) the steal count never
    exceeds the fleet-lifetime budget, (b) the token stream is identical
    to per-request greedy_generate regardless of how work moved, and
    (c) NO steal fires when no slowdown was injected (slow_factor=0)."""
    fault = (FaultPlan(slow_at=2, slow_factor=slow_factor)
             if slow_factor else None)
    names = ["a", "b", "c", "d"][:n_reps]
    reps = [fake_replica(names[0], fault=fault)] + \
        [fake_replica(n) for n in names[1:]]
    ctrl = FleetController(reps, miss_threshold=6, steal=True)
    wl = saturated_workload(seed=seed, stagger=stagger)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    report = ctrl.run()
    assert report.kills == []                  # contended replicas live
    assert report.steals <= 8                  # default budget
    if slow_factor == 0:
        assert report.steals == 0, report.events
    check_oracle(wl, report.completed)


def test_fleet_drift_gauge_resets_baseline_on_replan():
    """Satellite bugfix: every replan (kill/join/steal) must reset the
    ``fleet_drift`` baseline.  A replica planned at 3x rate but serving
    at 1x drives the gauge far past tolerance; killing it replans onto
    the two well-modeled survivors — the gauge must read 0.0 at the
    replan instant and return within the quantization tolerance within
    the first post-replan observation windows instead of dragging the
    dead plan's accumulated skew forever."""
    reps = [fake_replica("a", 3.0, FaultPlan(kill_at=8)),
            fake_replica("b"), fake_replica("c")]
    ctrl = FleetController(reps, miss_threshold=3)
    for p, m, a in saturated_workload():
        ctrl.submit(p, m, arrival=a)
    g = ctrl.metrics.gauge("fleet_drift")
    replans = ctrl.metrics.counter("replans")
    seen = replans.value
    stale, post = None, []
    while ctrl.tick():
        if replans.value > seen:
            seen = replans.value
            stale, post = (post[-1] if post else None), []
        post.append(g.value)
    tol = ctrl._drift.share_tolerance()
    assert stale is not None and stale > 2 * tol   # plan was visibly wrong
    assert len(post) >= 4
    assert min(post[:4]) <= tol                    # back inside tolerance
    assert max(post[2:]) <= 2 * tol, post          # stale level never returns
    # the reset surface itself: gauge cleared, monitor reseeded, baseline
    # moved to the current decode counters
    ctrl._replan()
    assert g.value == 0.0
    assert ctrl._drift is None or ctrl._drift.last_drift is None
    for n in ctrl._drift_names:
        assert ctrl._drift_base[n] == \
            ctrl.replicas[n].progress()["decode_tokens"]


# ---------------------------------------------------------------------------
# acceptance: real transformer, heterogeneous fleet, kill + join
# ---------------------------------------------------------------------------

def test_fleet_oracle_acceptance():
    """32 heavy-tailed staggered requests, 3 heterogeneous replicas
    (one shared slot adapter), one replica killed mid-decode, one
    joining later: token-identical to per-request greedy_generate."""
    import jax
    from repro.configs import get_reduced
    from repro.models import transformer as T
    from repro.serve import TransformerModel, greedy_generate
    from repro.sharding.rules import Rules

    cfg = get_reduced("llama3_2_3b")
    rules = Rules.null()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    wl = synthetic_workload(32, cfg.vocab_size, lens=(5, 8, 12, 16),
                            news=(1, 3, 6, 9), stagger=0.5, seed=0)
    model = TransformerModel(params, cfg, rules)   # shared: one jit set
    ec = EngineConfig(n_slots=4, max_prompt_len=16, max_new_cap=9,
                      cache_len=25, max_prefill_per_step=2)
    reps = [Replica("r0", model, ec, rate=1.0,
                    fault=FaultPlan(kill_at=5)),   # dies mid-decode
            Replica("r1", model, ec, rate=2.0),
            Replica("r2", model, ec, rate=0.5)]
    ctrl = FleetController(reps, miss_threshold=3)
    ctrl.schedule_join(Replica("r3", model, ec, rate=1.5), at_tick=8)
    fe = FleetFrontend(ctrl, max_pending=12)
    report = fe.serve(wl, stream_rids=(0,))

    assert report.n_completed == 32
    assert [n for _, n in report.kills] == ["r0"]
    assert [n for _, n in report.joins] == ["r3"]
    assert report.requeues >= 1, "the kill must have caught work in flight"
    for rid, (prompt, max_new, _) in enumerate(wl):
        ref = np.asarray(greedy_generate(
            params, cfg, rules, np.asarray(prompt)[None],
            max_new=max_new))[0]
        got = report.completed[rid]
        assert np.array_equal(ref, got), (
            f"request {rid}: fleet {got} != oracle {ref}")
    np.testing.assert_array_equal(
        np.asarray(fe.streamed[0], np.int32), report.completed[0])
