"""Paper §5: MFT-LBP linear program, PMFT-LBP, and the heuristic."""

import numpy as np
import pytest

from repro.core.network import random_mesh
from repro.core.mesh_lp import solve_fixed_k, solve_relaxed
from repro.core.pmft import fifs, pmft_lbp
from repro.core.heuristic import mft_lbp_heuristic


@pytest.mark.parametrize("dim,seed", [(3, 0), (5, 1), (5, 2)])
def test_relaxed_lp_valid(dim, seed):
    net = random_mesh(dim, dim, seed=seed)
    N = 300
    r = solve_relaxed(net, N)
    assert r.k.sum() == pytest.approx(N, rel=1e-6)
    assert r.k[net.source] == pytest.approx(0.0, abs=1e-9)
    assert np.all(r.k >= -1e-7)
    # flow conservation (54): inflow - outflow == 2 k_i N
    for i in range(net.p):
        if i == net.source:
            continue
        infl = sum(r.phi[e] for e in net.in_edges(i))
        outf = sum(r.phi[e] for e in net.out_edges(i))
        assert infl - outf == pytest.approx(2 * r.k[i] * N, rel=1e-5, abs=1e-3)
    # (53): source emits both matrices, each entry once
    out_s = sum(r.phi[e] for e in net.out_edges(net.source))
    assert out_s == pytest.approx(2 * N * N, rel=1e-9)
    # (61): makespan covers every node
    assert r.t_finish >= r.t_finish_nodes.max() - 1e-6


def test_fixed_k_matches_relaxed_at_optimum():
    net = random_mesh(4, 4, seed=3)
    N = 200
    r = solve_relaxed(net, N)
    f = solve_fixed_k(net, N, r.k)
    assert f.t_finish == pytest.approx(r.t_finish, rel=1e-6)


def test_pmft_integer_and_bounded_by_relaxation():
    net = random_mesh(5, 5, seed=7)
    N = 400
    r = solve_relaxed(net, N)
    s = pmft_lbp(net, N)
    assert s.k.sum() == N
    assert np.all(s.k >= 0)
    assert s.k[net.source] == 0
    # integer schedule can never beat the LP relaxation
    assert s.t_finish >= r.t_finish - 1e-6
    # ... and rounding N units costs at most a few units of work
    unit = N * N * net.w.max() * net.t_cp
    assert s.t_finish <= r.t_finish + 5 * unit


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_heuristic_close_to_pmft(seed):
    """Paper §6.2.3: heuristic within a fraction of a percent of PMFT-LBP
    (0.03%-0.18% in the paper; we allow 2% over random instances)."""
    net = random_mesh(5, 5, seed=seed)
    N = 300
    a = pmft_lbp(net, N)
    b = mft_lbp_heuristic(net, N)
    assert b.t_finish <= a.t_finish * 1.02 + 1e-9
    # heuristic must not use more LP solves than PMFT-LBP
    assert b.lp_solves <= a.lp_solves


def test_fifs_repairs_sum():
    net = random_mesh(5, 5, seed=11)
    N = 777   # odd N forces rounding repair
    r = solve_relaxed(net, N)
    k, res, solves, iters = fifs(net, N, r)
    assert k.sum() == N
    assert np.all(k >= 0)
    assert iters >= 0 and solves >= 1


def test_storage_constraint_respected():
    net = random_mesh(3, 3, seed=5, storage=2.0 * 300 * 300)
    N = 300
    # D_i = 2 N^2 => k_i <= (D_i - N^2) / (2N) = N/2
    r = solve_relaxed(net, N)
    cap = (2.0 * N * N - N * N) / (2.0 * N)
    assert np.all(r.k <= cap + 1e-6)
    s = pmft_lbp(net, N)
    assert np.all(s.k <= cap + 1)


def test_comm_volume_reported():
    net = random_mesh(4, 4, seed=9)
    N = 256
    s = pmft_lbp(net, N)
    # hop-by-hop volume is at least the source emission 2N^2
    assert s.comm_volume >= 2 * N * N - 1e-6
