"""The "ring" aggregation mode: byte accounting + multi-device semantics.

Byte model (per device, aggregating X output bytes over p devices):

  ring       (p-1) * X          full partial forwarded p-1 hops
  allreduce  2(p-1)/p * X       bandwidth-optimal ring all-reduce
  scatter    (p-1)/p * X        reduce-scatter half

so ring = p/2 x allreduce = p x scatter for every p — the price of the
naive neighbour relay, which is what unswitched fabrics actually pay.
"""

import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P

from repro.core import collectives

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_ring_mode_registered():
    assert "ring" in collectives.available_modes()
    mode = collectives.get_mode("ring")
    assert not mode.adds_device_axis


def test_ring_bytes_vs_allreduce_and_scatter():
    for out_elems in (1, 4096, 1 << 20):
        for p in (2, 4, 8, 64):
            for itemsize in (1, 2, 4):
                ring = collectives.collective_bytes_per_device(
                    out_elems, p, "ring", itemsize)
                ar = collectives.collective_bytes_per_device(
                    out_elems, p, "allreduce", itemsize)
                sc = collectives.collective_bytes_per_device(
                    out_elems, p, "scatter", itemsize)
                assert ring == (p - 1) * out_elems * itemsize
                assert ring == pytest.approx(0.5 * p * ar)
                assert ring == pytest.approx(p * sc)
                assert ring >= ar >= sc       # ring never cheaper
    # p=2 special case: a single hop costs exactly the allreduce bytes
    assert collectives.collective_bytes_per_device(100, 2, "ring") == \
        collectives.collective_bytes_per_device(100, 2, "allreduce")
    # degenerate single device: no traffic in any mode
    table = collectives.bytes_table(100, p=1)
    assert table["ring"] == table["allreduce"] == table["scatter"] == 0.0


def test_ring_out_spec_replicated():
    assert collectives.out_spec("ring", "model", ("data", None, None)) == \
        P("data", None, None)
    assert collectives.out_spec("ring", "model", ("data", None, None)) == \
        collectives.out_spec("allreduce", "model", ("data", None, None))


def test_ring_matches_allreduce_multi_device():
    """ppermute relay == psum on a real 8-device mesh (subprocess, same
    isolation pattern as tests/test_distributed.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core.lbp_matmul import lbp_matmul, lbp_matmul_reference
        assert len(jax.devices()) == 8
        mesh = make_mesh((8,), ("model",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        ref = np.asarray(lbp_matmul_reference(x, w))
        ring = jax.jit(lambda x, w: lbp_matmul(
            x, w, mesh, axis="model", mode="ring"))(x, w)
        ar = jax.jit(lambda x, w: lbp_matmul(
            x, w, mesh, axis="model", mode="allreduce"))(x, w)
        assert np.abs(np.asarray(ring) - ref).max() < 1e-4
        assert np.abs(np.asarray(ring) - np.asarray(ar)).max() < 1e-5
        print("RING-OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "RING-OK" in r.stdout
