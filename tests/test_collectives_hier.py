"""The "hierarchical" aggregation mode: ICI+DCN byte accounting + semantics.

Execution-plane counterpart of the ``repro.plan`` hierarchical planner:
reduce-scatter within the pod (ICI), all-reduce the 1/m shard across pods
(all the DCN traffic), all-gather within the pod (ICI).  The promise the
plan IR makes about trunk traffic is the number the collective moves:

  trunk egress per pod:  hierarchical  2 (P-1)/P x bytes(out)
                         flat ring     2 (p-1)/p x bytes(out)  (~2x for P=2)
"""

import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P

from repro.core import collectives

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_hier_mode_registered():
    assert "hierarchical" in collectives.available_modes()
    mode = collectives.get_mode("hierarchical")
    assert not mode.adds_device_axis


def test_hier_out_spec_replicated():
    axes = ("pod", "model")
    assert collectives.out_spec("hierarchical", axes, ("data", None, None)) \
        == P("data", None, None)


def test_hier_rejects_single_axis():
    with pytest.raises(ValueError, match="pod_axis"):
        collectives.get_mode("hierarchical").combine(None, "model", 0)


def test_hier_byte_breakdown_beats_flat_trunk():
    """DCN trunk egress is (P-1)/P / ((p-1)/p) of the flat ring's —
    ~(m-fold fewer shard-bytes per device) on the scarce link class."""
    for out_elems in (4096, 1 << 20):
        for n_pods in (2, 4):
            for m in (4, 16, 256):
                bd = collectives.hierarchical_byte_breakdown(
                    out_elems, n_pods, m)
                p = n_pods * m
                v = out_elems * 2.0
                assert bd["ici_per_device"] == pytest.approx(
                    2 * (m - 1) / m * v)
                assert bd["dcn_per_device"] == pytest.approx(
                    2 * (n_pods - 1) / n_pods * v / m)
                assert bd["dcn_per_pod"] == pytest.approx(
                    2 * (n_pods - 1) / n_pods * v)
                assert bd["flat_allreduce_dcn_per_pod"] == pytest.approx(
                    2 * (p - 1) / p * v)
                # the point: the trunk carries strictly less than flat
                assert bd["dcn_per_pod"] < bd["flat_allreduce_dcn_per_pod"]
    # degenerate single pod: pure ICI, no trunk traffic
    bd = collectives.hierarchical_byte_breakdown(100, 1, 8)
    assert bd["dcn_per_pod"] == 0.0


def test_hier_generic_factor_monotone():
    """The registry's worst-device factor (canonical 2-pod split) sits
    between scatter's and ring's for every even p."""
    for p in (4, 8, 64, 512):
        hier = collectives.collective_bytes_per_device(1000, p, "hierarchical")
        ar = collectives.collective_bytes_per_device(1000, p, "allreduce")
        ring = collectives.collective_bytes_per_device(1000, p, "ring")
        assert 0 < hier <= ar * 1.5     # ~allreduce-class total bytes
        assert hier < ring


def test_hier_matches_allreduce_multi_device():
    """RS(inner) + psum(pod) + AG(inner) == psum on a real (2,4) mesh
    (subprocess, same isolation pattern as tests/test_distributed.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.compat import make_mesh
        from repro.core.lbp_matmul import lbp_matmul, lbp_matmul_reference
        assert len(jax.devices()) == 8
        mesh = make_mesh((2, 4), ("pod", "model"))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        ref = np.asarray(lbp_matmul_reference(x, w))
        hier = jax.jit(lambda x, w: lbp_matmul(
            x, w, mesh, axis=("pod", "model"), mode="hierarchical"))(x, w)
        assert np.abs(np.asarray(hier) - ref).max() < 1e-4
        print("HIER-OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "HIER-OK" in r.stdout
