"""repro.compat (version-adaptive jax surface) + core.collectives registry."""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import collectives


# ---------------------------------------------------------------------------
# compat: resolution on the installed jax
# ---------------------------------------------------------------------------

def test_version_flags():
    assert compat.JAX_VERSION == compat._version_tuple(jax.__version__)
    assert len(compat.JAX_VERSION) == 3
    assert compat.JAX_VERSION >= (0, 4, 0)
    assert isinstance(compat.HAS_AXIS_TYPE, bool)
    assert compat.HAS_AXIS_TYPE == hasattr(jax.sharding, "AxisType")
    assert compat.SHARD_MAP_CHECK_KWARG in ("check_vma", "check_rep", None)


def test_version_tuple_parsing():
    assert compat._version_tuple("0.4.37") == (0, 4, 37)
    assert compat._version_tuple("0.7.2.dev123") == (0, 7, 2)
    assert compat._version_tuple("1.0") == (1, 0, 0)
    # suffixed pieces keep only leading digits (37rc1 must not become 371)
    assert compat._version_tuple("0.4.37rc1") == (0, 4, 37)
    assert compat._version_tuple("0.5.dev0") == (0, 5, 0)


def test_cost_analysis_normalizes_shapes():
    class _C:
        def __init__(self, ret):
            self._ret = ret

        def cost_analysis(self):
            if isinstance(self._ret, Exception):
                raise self._ret
            return self._ret

    assert compat.cost_analysis(_C([{"flops": 2.0}, {"bytes": 3.0}])) == \
        {"flops": 2.0, "bytes": 3.0}                     # old jax: list
    assert compat.cost_analysis(_C({"flops": 2.0})) == {"flops": 2.0}
    assert compat.cost_analysis(_C(None)) == {}
    assert compat.cost_analysis(_C(RuntimeError("no cost model"))) == {}


def test_shard_map_resolves_and_runs():
    mesh = compat.make_mesh((1,), ("data",))

    def local(xl):
        return jax.lax.psum(xl * 2.0, "data")

    fn = compat.shard_map(local, mesh=mesh, in_specs=P(None),
                          out_specs=P(None), check_vma=False)
    got = fn(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(got), np.arange(4.0) * 2.0)


def test_make_mesh_basic():
    mesh = compat.make_mesh((1,), ("data",), axis_types="auto")
    assert mesh.axis_names == ("data",)
    assert mesh.shape["data"] == 1


# ---------------------------------------------------------------------------
# compat: mocked old/new API shapes
# ---------------------------------------------------------------------------

def _fake_new_jax():
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return ("new", f, dict(mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_vma=check_vma))
    return types.SimpleNamespace(shard_map=shard_map)


def _fake_old_jax():
    def shard_map(f, *, mesh, in_specs, out_specs, check_rep=True):
        return ("old", f, dict(mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=check_rep))
    return types.SimpleNamespace(
        experimental=types.SimpleNamespace(
            shard_map=types.SimpleNamespace(shard_map=shard_map)))


def test_resolve_shard_map_new_api():
    impl, kw = compat._resolve_shard_map(_fake_new_jax())
    assert kw == "check_vma"
    wrapped = compat._build_shard_map(impl, kw)
    tag, _, got = wrapped(lambda: None, mesh="m", in_specs=1, out_specs=2,
                          check_vma=False)
    assert tag == "new" and got["check_vma"] is False


def test_resolve_shard_map_old_api_translates_kwarg():
    impl, kw = compat._resolve_shard_map(_fake_old_jax())
    assert kw == "check_rep"
    wrapped = compat._build_shard_map(impl, kw)
    tag, _, got = wrapped(lambda: None, mesh="m", in_specs=1, out_specs=2,
                          check_vma=False)
    assert tag == "old" and got["check_rep"] is False


def test_resolve_shard_map_missing_raises():
    with pytest.raises(ImportError):
        compat._resolve_shard_map(types.SimpleNamespace(experimental=None))


def test_resolve_axis_types_degrades():
    if compat.HAS_AXIS_TYPE:
        resolved = compat._resolve_axis_types("auto", 2)
        assert resolved == (compat.AxisType.Auto,) * 2
        with pytest.raises(ValueError):
            compat._resolve_axis_types("bogus", 1)
    else:
        # jax <= 0.4.x: axis_types silently degrade to None (auto-only)
        assert compat._resolve_axis_types("auto", 2) is None
        assert compat._resolve_axis_types(None, 3) is None


def test_mesh_from_devices_fallback():
    devs = jax.devices()
    mesh = compat._mesh_from_devices((1,), ("data",), devs)
    assert mesh.axis_names == ("data",)
    with pytest.raises(ValueError):
        compat._mesh_from_devices((len(devs) + 1,), ("data",), devs)


# ---------------------------------------------------------------------------
# collectives registry
# ---------------------------------------------------------------------------

def test_builtin_modes_registered():
    assert set(collectives.available_modes()) >= {
        "layers", "allreduce", "scatter"}


def test_scatter_bytes_half_of_allreduce():
    """Paper §1.2 lazy aggregation: reduce-scatter moves exactly half the
    ring bytes of all-reduce, for every (size, p, itemsize)."""
    for out_elems in (1, 4096, 1 << 20):
        for p in (2, 4, 8, 64):
            for itemsize in (1, 2, 4):
                ar = collectives.collective_bytes_per_device(
                    out_elems, p, "allreduce", itemsize)
                rs = collectives.collective_bytes_per_device(
                    out_elems, p, "scatter", itemsize)
                ly = collectives.collective_bytes_per_device(
                    out_elems, p, "layers", itemsize)
                assert ly == 0.0
                assert ar > 0.0
                assert rs == pytest.approx(0.5 * ar)


def test_bytes_table_query():
    table = collectives.bytes_table(1024, p=8, itemsize=2)
    assert table["layers"] == 0.0
    assert table["scatter"] == pytest.approx(0.5 * table["allreduce"])


def test_unknown_mode_lists_available():
    with pytest.raises(ValueError, match="registered"):
        collectives.get_mode("warp-drive")
    with pytest.raises(ValueError):
        collectives.aggregate(jnp.zeros(2), "warp-drive", "model")


def test_out_spec_builders():
    assert collectives.out_spec("allreduce", "model", ("data", None, None)) \
        == P("data", None, None)
    assert collectives.out_spec("scatter", "model", ("data", None, None)) \
        == P("data", None, "model")
    assert collectives.out_spec("scatter", "model", ("data", None, None),
                                scatter_dim=1) == P("data", "model", None)
    assert collectives.out_spec("layers", "model", ("data", None, None)) \
        == P("model", "data", None, None)
    with pytest.raises(ValueError):
        collectives.out_spec("scatter", "model", ("data",), scatter_dim=0)


def test_register_custom_mode_dispatches():
    calls = []
    mode = collectives.AggregationMode(
        name="_test_ring",
        combine=lambda partial, axis, sd: calls.append(axis) or partial,
        out_spec=lambda axis, base, sd: P(*base),
        link_byte_factor=lambda p: 42.0,
        description="test-only")
    collectives.register_mode(mode)
    try:
        with pytest.raises(ValueError):
            collectives.register_mode(mode)  # dup without overwrite
        assert "_test_ring" in collectives.available_modes()
        out = collectives.aggregate(jnp.ones(3), "_test_ring", "model")
        assert calls == ["model"] and out.shape == (3,)
        assert collectives.collective_bytes_per_device(
            10, 8, "_test_ring", 1) == 420.0
    finally:
        collectives.unregister_mode("_test_ring")
    assert "_test_ring" not in collectives.available_modes()


def test_aggregate_modes_single_device_parity():
    """All three modes reduce to the plain matmul on a 1-device mesh (the
    multi-device equivalence lives in test_distributed.py)."""
    from repro.core.lbp_matmul import lbp_matmul, lbp_matmul_reference
    mesh = compat.make_mesh((1,), ("model",))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)), jnp.float32)
    w = jnp.asarray(np.random.default_rng(1).normal(size=(8, 6)), jnp.float32)
    ref = np.asarray(lbp_matmul_reference(x, w))
    for mode in ("layers", "allreduce", "scatter"):
        out = lbp_matmul(x, w, mesh, axis="model", mode=mode)
        got = np.asarray(out.sum(0) if mode == "layers" else out)
        np.testing.assert_allclose(got, ref, atol=1e-5)
