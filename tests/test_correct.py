"""Dynamic-correction scheduling: the drift-triggered work-stealing
corrector over static LBP plans (``runtime/correct.py``).

The contract under test (ROADMAP §Dynamic correction):

  * an UNDISTURBED run performs ZERO steals and executes shares
    bit-identical to the static seed plan (hysteresis bound);
  * an injected mid-run slowdown trips the DriftMonitor, the corrector
    re-assigns marginal blocks straggler -> fastest absorber, and the
    realized finish spread converges back inside the plan's quantization
    tolerance within the steal budget;
  * steals move whole steal units (quantum / quantum x ring / request)
    so corrected shares stay aligned for their plane;
  * cooldown, budget, and the strict-improvement guard bound the event
    count and prevent oscillation.
"""

import numpy as np
import pytest

from repro.plan import StarTopology, plan
from repro.runtime.correct import (CorrectionPolicy, WorkStealingCorrector,
                                   corrected_plan, simulate_correction,
                                   steal_unit)

SPEEDS = [1.0, 2.0, 4.0, 1.0, 1.0, 1.0, 2.0, 1.0]


def star_plan(load=8192, quantum=128, objective="PCSS"):
    topo = StarTopology(w=1.0 / np.asarray(SPEEDS),
                        z=np.full(len(SPEEDS), 1e-9))
    return plan(topo, load, quantum=quantum, objective=objective)


# ---------------------------------------------------------------------------
# policy + units + plan surgery
# ---------------------------------------------------------------------------

def test_policy_validation():
    with pytest.raises(AssertionError, match="hysteresis"):
        CorrectionPolicy(hysteresis=0.9)
    with pytest.raises(AssertionError):
        CorrectionPolicy(cooldown=0)
    with pytest.raises(AssertionError):
        CorrectionPolicy(persistence=0)


def test_steal_unit_per_plane():
    pp = star_plan(quantum=128)
    assert steal_unit(pp, "train") == 128
    assert steal_unit(pp, "overlap", ring=4) == 512   # whole ring tiles
    assert steal_unit(pp, "serve") == 1               # one queued request
    with pytest.raises(ValueError, match="plane"):
        steal_unit(pp, "warp")


def test_corrected_plan_rescales_and_counts():
    pp = star_plan()
    k = pp.k.copy()
    src = int(np.argmax(k))
    dst = int(np.argmin(k))
    k[src] -= pp.quantum
    k[dst] += pp.quantum
    cp = corrected_plan(pp, k)
    assert int(cp.k.sum()) == int(pp.load)
    assert cp.meta["corrections"] == 1
    np.testing.assert_array_equal(cp.k_real, pp.k_real)   # seed provenance
    # finish times scale with the share ratio on the touched nodes
    assert cp.finish_times[src] == pytest.approx(
        pp.finish_times[src] * k[src] / pp.k[src])
    assert corrected_plan(cp, cp.k.copy()).meta["corrections"] == 2


def test_corrected_plan_rejects_bad_shares():
    pp = star_plan()
    with pytest.raises(AssertionError):
        corrected_plan(pp, pp.k + pp.quantum)   # sum != load


# ---------------------------------------------------------------------------
# corrector trip discipline (hysteresis / persistence / cooldown / budget)
# ---------------------------------------------------------------------------

def test_observe_times_zero_drift_never_trips():
    """Exact predicted busy times — and any uniform scaling of them —
    score zero drift: a uniformly slower platform has nothing to
    rebalance."""
    pp = star_plan()
    corr = WorkStealingCorrector(pp)
    for scale in (1.0, 3.0, 0.25):
        for _ in range(8):
            assert corr.observe_times(pp.finish_times * scale) is None
    assert corr.events == [] and corr.plan is pp


def test_persistence_requires_consecutive_trips():
    pp = star_plan()
    pol = CorrectionPolicy(hysteresis=1.1, persistence=3)
    corr = WorkStealingCorrector(pp, policy=pol)
    skew = pp.finish_times.copy()
    skew[2] *= 2.0                       # clear straggler
    assert corr.observe_times(skew) is None      # over #1
    assert corr.observe_times(pp.finish_times) is None   # resets the streak
    assert corr.observe_times(skew) is None      # over #1 again
    assert corr.observe_times(skew) is None      # over #2
    assert corr.observe_times(skew) is not None  # over #3 -> steal


def test_budget_and_cooldown_bound_steals():
    pp = star_plan()
    pol = CorrectionPolicy(hysteresis=1.05, cooldown=2, max_corrections=3)
    corr = WorkStealingCorrector(pp, policy=pol)
    skew_node = 2
    events = 0
    for _ in range(40):
        busy = corr.plan.k * (pp.finish_times / np.maximum(pp.k, 1))
        busy = busy.astype(float)
        busy[skew_node] *= 4.0
        if corr.observe_times(busy) is not None:
            events += 1
    assert events == len(corr.events) <= pol.max_corrections
    # cooldown: no two events on consecutive observations
    steps = [e.step for e in corr.events]
    assert all(b - a >= pol.cooldown for a, b in zip(steps, steps[1:]))


def test_steal_moves_quantum_from_straggler():
    pp = star_plan()
    corr = WorkStealingCorrector(
        pp, policy=CorrectionPolicy(hysteresis=1.05))
    w = pp.finish_times / np.maximum(pp.k, 1)
    busy = (pp.k * w).astype(float)
    busy[2] *= 2.0                       # node 2 (fastest, biggest share)
    ev = None
    while ev is None:
        ev = corr.observe_times(busy)
    assert ev.src == 2 and ev.amount == pp.quantum
    assert corr.plan.k[2] == pp.k[2] - pp.quantum
    assert int(corr.plan.k.sum()) == int(pp.load)
    assert np.all(corr.plan.k % pp.quantum == 0)


# ---------------------------------------------------------------------------
# acceptance: the deterministic contention simulation
# ---------------------------------------------------------------------------

def test_simulate_undisturbed_is_bit_identical():
    pp = star_plan()
    res = simulate_correction(pp, slow_node=None, n_steps=32)
    assert res["steals"] == 0
    assert res["final_k"] == res["seed_k"]
    assert res["makespan"] == pytest.approx(res["makespan_static"])


def test_simulate_contention_converges_within_budget():
    """Injected 2x mid-run slowdown on the biggest-share node: the
    corrector trips, re-assigns, and the final per-step finish spread is
    back inside the plan's quantization tolerance — in bounded steps,
    with a strictly better makespan than the static plan."""
    pp = star_plan()
    pol = CorrectionPolicy(hysteresis=1.25, cooldown=1, max_corrections=12)
    res = simulate_correction(pp, slow_node=2, slow_at_frac=0.3,
                              slow_factor=2.0, n_steps=32, policy=pol)
    assert 1 <= res["steals"] <= res["steal_bound"]
    assert res["convergence_step"] is not None
    assert res["unit_tolerance"] == res["tolerance"]   # unit == quantum
    assert res["spread_final"] <= res["tolerance"] + 1e-9
    assert res["makespan"] < res["makespan_static"]
    assert res["final_k"] != res["seed_k"]
    assert sum(res["final_k"]) == sum(res["seed_k"])
    # every event drains the straggler
    assert all(e["src"] == 2 for e in res["events"])


def test_simulate_steal_off_leaves_plan_static():
    pp = star_plan()
    res = simulate_correction(pp, slow_node=2, steal=False, n_steps=32)
    assert res["steals"] == 0 and res["final_k"] == res["seed_k"]
    assert res["makespan"] == pytest.approx(res["makespan_static"])


def test_simulate_overlap_plane_moves_ring_tiles():
    """The overlap plane steals whole ring tiles (quantum x ring) so the
    streamed per-device tiling stays divisible by the ring size."""
    pp = star_plan(objective="overlap")
    ring = 4
    res = simulate_correction(pp, slow_node=2, slow_factor=2.0,
                              plane="overlap", ring=ring, n_steps=32,
                              policy=CorrectionPolicy(hysteresis=1.25,
                                                      max_corrections=12))
    assert res["unit"] == pp.quantum * ring
    assert all(e["amount"] % pp.quantum == 0 for e in res["events"])
    # convergence is bounded by the one-UNIT shift: ring x the quantum
    # tolerance (the coarser unit cannot land closer than its own size)
    assert res["unit_tolerance"] == pytest.approx(res["tolerance"] * ring,
                                                  abs=1e-5)
    assert res["spread_final"] <= res["unit_tolerance"] + 1e-9
    if res["steals"]:
        assert res["makespan"] <= res["makespan_static"] + 1e-9
