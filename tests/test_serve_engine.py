"""Serving-engine invariants: admission control, slot conservation,
starvation-freedom, and token-identity against the greedy oracle.

Scheduling invariants run against a tensor-light fake model (hypothesis
properties over random workloads); the oracle-identity checks run the
real transformer on the reduced llama3_2_3b config, including the
acceptance workload of 32 staggered-arrival mixed-length requests.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve import (EngineConfig, ServingEngine, TransformerModel,
                         greedy_generate, serve_requests)
from repro.serve.engine import (AdmissionError, AdmissionLimits,
                                RequestQueue, SlotCachePool)
from repro.sharding.rules import Rules

RULES = Rules.null()


# ---------------------------------------------------------------------------
# fake model: same adapter surface, trivial tensors
# ---------------------------------------------------------------------------

class FakeModel:
    """Deterministic next-token model: next = (prev * 31 + pos) % V."""

    V = 97

    def init_pool(self, n_slots, cache_len):
        return {"state": jnp.zeros((1, n_slots, cache_len), jnp.int32)}

    def token_state(self, n_slots):
        return jnp.zeros(n_slots, jnp.int32), jnp.zeros(n_slots, jnp.int32)

    def first_token(self, prompt):
        return int(np.sum(prompt) % self.V)

    def prefill(self, pool, prompts, slots, tok, pos):
        firsts = []
        for prompt, slot in zip(prompts, slots):
            first = self.first_token(prompt)
            firsts.append(first)
            tok = tok.at[slot].set(first)
            pos = pos.at[slot].set(prompt.shape[0])
        return pool, jnp.asarray(firsts, jnp.int32), tok, pos

    def decode_multi(self, pool, tok, pos, k):
        rows = []
        for _ in range(k):
            tok = (tok * 31 + pos) % self.V
            pos = pos + 1
            rows.append(tok)
        return pool, jnp.stack(rows), tok, pos

    def decode(self, pool, tok, pos):
        pool, rows, tok, pos = self.decode_multi(pool, tok, pos, 1)
        return pool, rows[0], tok, pos

    def oracle(self, prompt, max_new):
        """Per-request reference for the fake dynamics."""
        out = [self.first_token(prompt)]
        tok, pos = out[0], prompt.shape[0]
        for _ in range(max_new - 1):
            tok = (tok * 31 + pos) % self.V
            pos += 1
            out.append(tok)
        return np.asarray(out, np.int32)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_queue_admission_budgets():
    q = RequestQueue(AdmissionLimits(max_prompt_len=8, max_new_cap=4,
                                     max_queue=2))
    q.submit(np.arange(5), 2)
    with pytest.raises(AdmissionError, match="max_prompt_len"):
        q.submit(np.arange(9), 2)
    with pytest.raises(AdmissionError, match="max_new"):
        q.submit(np.arange(3), 0)
    with pytest.raises(AdmissionError, match="max_new"):
        q.submit(np.arange(3), 5)
    with pytest.raises(AdmissionError, match="at least 1 token"):
        q.submit(np.array([], np.int32), 2)
    q.submit(np.arange(3), 2)
    with pytest.raises(AdmissionError, match="queue full"):
        q.submit(np.arange(3), 2)
    assert q.n_submitted == 2 and q.n_rejected == 5


def test_queue_fifo_among_eligible():
    q = RequestQueue()
    a = q.submit(np.arange(3), 1, arrival=2.0)
    b = q.submit(np.arange(3), 1, arrival=0.0)
    assert q.pop_ready(0.0).rid == b.rid
    assert q.pop_ready(0.0) is None          # a not yet arrived
    assert q.pop_ready(2.0).rid == a.rid


def test_engine_rejects_over_budget_total():
    eng = ServingEngine(FakeModel(), EngineConfig(
        n_slots=2, max_prompt_len=8, max_new_cap=8, cache_len=10))
    with pytest.raises(AdmissionError, match="cache slot length"):
        eng.submit(np.arange(8), 8)          # 16 > 10
    assert eng.queue.n_rejected == 1         # counted by admission control


# ---------------------------------------------------------------------------
# slot pool conservation
# ---------------------------------------------------------------------------

def test_pool_alloc_free_conservation():
    pool = SlotCachePool(2)
    a = pool.allocate()
    b = pool.allocate()
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.allocate()
    pool.free(a)
    with pytest.raises(RuntimeError, match="not allocated"):
        pool.free(a)
    c = pool.allocate()
    pool.free(b)
    pool.free(c)
    assert pool.drained and pool.n_allocated == pool.n_freed == 3


def test_pool_lowest_slot_first():
    """allocate() hands out the lowest free slot id regardless of free
    order (pins the semantics across the O(1) deque refactor)."""
    pool = SlotCachePool(4)
    assert [pool.allocate() for _ in range(4)] == [0, 1, 2, 3]
    pool.free(2)
    pool.free(0)
    assert pool.allocate() == 0
    assert pool.allocate() == 2


# ---------------------------------------------------------------------------
# scheduling invariants over random workloads (fake model)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 20),
       slots=st.integers(1, 4), cap=st.integers(1, 3))
def test_engine_conservation_and_no_starvation(seed, n, slots, cap):
    rng = np.random.default_rng(seed)
    eng = ServingEngine(FakeModel(), EngineConfig(
        n_slots=slots, max_prompt_len=12, max_new_cap=6,
        max_prefill_per_step=cap))
    want = {}
    for _ in range(n):
        prompt = rng.integers(0, 50, rng.integers(1, 13))
        max_new = int(rng.integers(1, 7))
        arrival = float(rng.integers(0, 10))
        rid = eng.submit(prompt, max_new, arrival=arrival)
        want[rid] = (prompt, max_new)
    rep = eng.run()
    # no starvation: every admitted request finished
    assert set(rep.completed) == set(want)
    # slot conservation: allocated == freed at drain, pool empty
    assert eng.pool.drained
    assert eng.pool.n_allocated == eng.pool.n_freed == n
    # each request got exactly its budget, matching the fake dynamics
    fake = FakeModel()
    for rid, (prompt, max_new) in want.items():
        got = rep.completed[rid]
        assert got.shape == (max_new,)
        np.testing.assert_array_equal(got, fake.oracle(
            np.asarray(prompt, np.int32), max_new))
    # occupancy is a valid fraction
    assert 0.0 <= rep.occupancy <= 1.0


def test_idle_engine_fast_forwards_to_arrival():
    """A far-future arrival must not spin one step per clock unit."""
    eng = ServingEngine(FakeModel(), EngineConfig(
        n_slots=2, max_prompt_len=8, max_new_cap=4))
    eng.submit(np.arange(4), 2, arrival=1_000_000.0)
    rep = eng.run(max_steps=50)
    assert len(rep.completed) == 1
    assert rep.steps < 10


# ---------------------------------------------------------------------------
# wall-clock arrival mode (trace replay in seconds on an injected clock)
# ---------------------------------------------------------------------------

def test_wall_clock_arrivals_with_manual_clock():
    """arrival_mode='seconds': arrivals are wall-clock seconds against an
    injectable monotonic clock; the engine sleeps through idle gaps
    instead of counting engine steps, and outputs stay oracle-exact."""
    from repro.serve.engine import ManualClock
    clock = ManualClock()
    eng = ServingEngine(FakeModel(), EngineConfig(
        n_slots=2, max_prompt_len=8, max_new_cap=4,
        arrival_mode="seconds"), clock=clock)
    fake = FakeModel()
    prompts = [np.arange(1, 5), np.arange(2, 8), np.arange(3, 6)]
    # second request arrives 50s in, third 120s in
    for p, arr in zip(prompts, (0.0, 50.0, 120.0)):
        eng.submit(p, 3, arrival=arr)
    rep = eng.run()
    assert len(rep.completed) == 3
    for rid, p in enumerate(prompts):
        np.testing.assert_array_equal(
            rep.completed[rid], fake.oracle(np.asarray(p, np.int32), 3))
    # the engine waited ON THE INJECTED CLOCK through both idle gaps
    assert clock.t >= 120.0


def test_wall_clock_arrivals_order_follows_clock():
    """A request 'arriving' later in seconds must not be admitted before
    the clock reaches it, even if submitted first."""
    from repro.serve.engine import ManualClock
    clock = ManualClock()
    eng = ServingEngine(FakeModel(), EngineConfig(
        n_slots=1, max_prompt_len=8, max_new_cap=4,
        arrival_mode="seconds"), clock=clock)
    late = eng.submit(np.arange(4), 2, arrival=1000.0)
    early = eng.submit(np.arange(5), 2, arrival=0.0)
    rep = eng.run()
    assert set(rep.completed) == {late, early}
    # with one slot the early request must have been served first: its
    # trace rows precede the late one's
    assert (eng.completed[early].trace_start
            <= eng.completed[late].trace_start)
    assert clock.t >= 1000.0


def test_wall_clock_mode_rejects_bad_config():
    import pytest as _pytest
    with _pytest.raises(ValueError, match="arrival_mode"):
        ServingEngine(FakeModel(), EngineConfig(arrival_mode="minutes"))


def test_engine_step_mode_is_default_and_unchanged():
    """Engine-step arrivals stay the default: the clock advances by the
    fused step width, not wall time (pinned: the fast-forward test above
    and the staggered acceptance workloads rely on it)."""
    eng = ServingEngine(FakeModel(), EngineConfig(
        n_slots=2, max_prompt_len=8, max_new_cap=4))
    assert eng.config.arrival_mode == "steps"
    eng.submit(np.arange(4), 4, arrival=0.0)
    eng.step()
    assert eng.clock == 1.0     # one iteration, one engine-clock unit


# ---------------------------------------------------------------------------
# oracle identity on the real model
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_lm():
    cfg = get_reduced("llama3_2_3b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mixed_workload(n, vocab, seed=0, lens=(5, 8, 12, 16),
                    news=(1, 3, 6, 9), stagger=0.5):
    from repro.serve.engine import synthetic_workload
    return synthetic_workload(n, vocab, lens=lens, news=news,
                              stagger=stagger, seed=seed)


def test_engine_matches_greedy_oracle_acceptance(small_lm):
    """The acceptance workload: >= 32 staggered-arrival mixed-length
    requests, token-identical to per-request greedy_generate."""
    cfg, params = small_lm
    workload = _mixed_workload(32, cfg.vocab_size)
    rep = serve_requests(params, cfg, RULES, workload, n_slots=8,
                         max_prefill_per_step=4)
    assert len(rep.completed) == 32
    for rid, (prompt, max_new, _) in enumerate(workload):
        ref = np.asarray(greedy_generate(
            params, cfg, RULES, np.asarray(prompt)[None],
            max_new=max_new))[0]
        np.testing.assert_array_equal(rep.completed[rid], ref, err_msg=str(rid))
    assert rep.occupancy > 0.5          # continuous batching actually packs


def test_engine_single_slot_sequential(small_lm):
    """n_slots=1 degenerates to sequential serving, still oracle-exact."""
    cfg, params = small_lm
    workload = _mixed_workload(3, cfg.vocab_size, seed=7, news=(2, 4))
    rep = serve_requests(params, cfg, RULES, workload, n_slots=1)
    for rid, (prompt, max_new, _) in enumerate(workload):
        ref = np.asarray(greedy_generate(
            params, cfg, RULES, np.asarray(prompt)[None],
            max_new=max_new))[0]
        np.testing.assert_array_equal(rep.completed[rid], ref)


def test_engine_hybrid_family_oracle():
    """Regression: hybrid caches lead with the conv-state width, so the
    pool time length must come from init_pool, not leaf-shape sniffing —
    getting it wrong silently truncated the prefill cache."""
    cfg = get_reduced("recurrentgemma_9b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    workload = _mixed_workload(4, cfg.vocab_size, seed=3, lens=(5, 9, 12),
                               news=(2, 4, 6), stagger=1.0)
    rep = serve_requests(params, cfg, RULES, workload, n_slots=2)
    for rid, (prompt, max_new, _) in enumerate(workload):
        ref = np.asarray(greedy_generate(
            params, cfg, RULES, np.asarray(prompt)[None],
            max_new=max_new))[0]
        np.testing.assert_array_equal(rep.completed[rid], ref, err_msg=str(rid))


def test_grouped_prefill_gated_for_recurrent(small_lm):
    """Hybrid (recurrent-state) families must not use padded grouped
    prefill; the adapter flags it and falls back per-request."""
    cfg, params = small_lm
    assert TransformerModel(params, cfg, RULES).can_group_prefill
    rg = get_reduced("recurrentgemma_9b")
    rg_params = T.init_params(rg, jax.random.PRNGKey(1))
    assert not TransformerModel(rg_params, rg, RULES).can_group_prefill


def test_engine_ssm_rejected():
    cfg = get_reduced("xlstm_1_3b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="ssm"):
        TransformerModel(params, cfg, RULES)
