"""Paper §6.2: SUMMA / Pipeline / Modified Pipeline simulators + orderings."""

import numpy as np
import pytest

from repro.core.network import random_mesh
from repro.core.mesh_baselines import (simulate_modified_pipeline,
                                       simulate_pipeline, simulate_summa)
from repro.core.pmft import pmft_lbp
from repro.core.heuristic import mft_lbp_heuristic


@pytest.mark.parametrize("dim,seed", [(5, 0), (7, 1)])
def test_volume_formulas(dim, seed):
    net = random_mesh(dim, dim, seed=seed)
    N = 800
    s = simulate_summa(net, N)
    p = simulate_pipeline(net, N)
    m = simulate_modified_pipeline(net, N)
    # SUMMA: (X-1) N^2 of A + (Y-1) N^2 of B relayed
    assert s.comm_volume == pytest.approx((dim - 1) * 2 * N * N, rel=1e-9)
    # Pipeline floods every edge with the full 2N^2
    E = len(net.edges())
    assert p.comm_volume == pytest.approx(2 * N * N * E, rel=1e-9)
    # Modified Pipeline: one copy per non-source node
    assert m.comm_volume == pytest.approx(2 * N * N * (net.p - 1), rel=1e-9)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_paper_orderings(seed):
    """Fig 7/8 orderings: LBP ~ SUMMA << ModPipe << Pipe on volume;
    LBP fastest, heuristic ~ LBP on finish time."""
    net = random_mesh(5, 5, seed=seed)
    N = 1200
    lbp = pmft_lbp(net, N)
    heur = mft_lbp_heuristic(net, N)
    s = simulate_summa(net, N)
    p = simulate_pipeline(net, N)
    m = simulate_modified_pipeline(net, N)

    # volume: LBP and SUMMA near-optimal, pipelines far above
    assert lbp.comm_volume < 0.5 * m.comm_volume
    assert m.comm_volume < p.comm_volume
    assert abs(lbp.comm_volume - s.comm_volume) < 0.5 * s.comm_volume

    # time: LBP no slower than any baseline; heuristic within 2%
    assert lbp.t_finish <= s.finish_time * (1 + 1e-9)
    assert lbp.t_finish <= m.finish_time * (1 + 1e-9)
    assert lbp.t_finish <= p.finish_time * (1 + 1e-9)
    assert heur.t_finish <= lbp.t_finish * 1.02


def test_volume_reduction_reproduces_paper_magnitude():
    """Paper: 81% reduction vs ModPipe, 90% vs Pipeline (5x5..9x9)."""
    reductions_m, reductions_p = [], []
    for seed in range(3):
        net = random_mesh(5, 5, seed=seed)
        N = 1500
        lbp = mft_lbp_heuristic(net, N)
        m = simulate_modified_pipeline(net, N)
        p = simulate_pipeline(net, N)
        reductions_m.append(1 - lbp.comm_volume / m.comm_volume)
        reductions_p.append(1 - lbp.comm_volume / p.comm_volume)
    assert np.mean(reductions_m) > 0.70   # paper: 0.81
    assert np.mean(reductions_p) > 0.85   # paper: 0.90
