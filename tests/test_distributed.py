"""Multi-device semantics: shard_map LBP matmul, compressed collectives.

These need >1 device, so each case runs in a subprocess with
``--xla_force_host_platform_device_count=8`` (the main pytest process keeps
the real single CPU device, per the dry-run isolation rule).
"""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_lbp_matmul_modes_and_ragged():
    out = run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core.lbp_matmul import (lbp_matmul, lbp_matmul_reference,
                                           lbp_matmul_heterogeneous)
        from repro.core.partition import LayerAssignment
        assert len(jax.devices()) == 8
        mesh = make_mesh((2, 4), ("data", "model"))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        ref = np.asarray(lbp_matmul_reference(x, w))
        for mode in ("allreduce", "scatter", "layers"):
            out = jax.jit(lambda x, w: lbp_matmul(
                x, w, mesh, axis="model", mode=mode, batch_axis="data"))(x, w)
            got = np.asarray(out.sum(0) if mode == "layers" else out)
            assert np.abs(got - ref).max() < 1e-4, mode
        # heterogeneous split from the paper's PCSS solver
        asg = LayerAssignment.from_speeds(64, [1., 2., 4., 1.])
        out = jax.jit(lambda x, w: lbp_matmul_heterogeneous(
            x, w, asg, mesh, axis="model"))(x, w)
        assert np.abs(np.asarray(out) - ref).max() < 1e-4
        # zero-load device (extreme straggler) still correct
        asg2 = LayerAssignment(np.array([0, 32, 32, 0]))
        out2 = jax.jit(lambda x, w: lbp_matmul_heterogeneous(
            x, w, asg2, mesh, axis="model"))(x, w)
        assert np.abs(np.asarray(out2) - ref).max() < 1e-4
        print("OK")
    """)
    assert "OK" in out


def test_scatter_mode_halves_collective_bytes():
    """Deferred aggregation (paper §1.2 made productive): reduce-scatter
    moves half the ring bytes of all-reduce — verified on compiled HLO."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core.lbp_matmul import lbp_matmul
        from repro.analysis.hlo_cost import analyze_hlo
        mesh = make_mesh((8,), ("model",))
        x = jnp.zeros((64, 512), jnp.float32)
        w = jnp.zeros((512, 256), jnp.float32)
        res = {}
        for mode in ("allreduce", "scatter", "layers"):
            c = jax.jit(lambda x, w: lbp_matmul(
                x, w, mesh, axis="model", mode=mode)).lower(x, w).compile()
            res[mode] = analyze_hlo(c.as_text())["collectives"]
        ar = res["allreduce"]["total_link_bytes"]
        rs = res["scatter"]["total_link_bytes"]
        ly = res["layers"]["total_link_bytes"]
        assert ly == 0.0, res["layers"]
        assert 0 < rs <= 0.55 * ar, (rs, ar)
        print("OK", ar, rs, ly)
    """)
    assert "OK" in out


def test_compressed_pmean():
    out = run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.optim.compression import compressed_pmean
        mesh = make_mesh((2, 4), ("pod", "data"))
        # per-pod distinct values, replicated within pod
        g = {"w": jnp.ones((8, 16)) * 3.0}
        red, err = compressed_pmean(g, mesh, axis="pod")
        # identical inputs -> exact mean, zero error
        assert np.allclose(np.asarray(red["w"]), 3.0, atol=1e-4)
        assert np.abs(np.asarray(err["w"])).max() < 1e-6
        # error feedback bound: |x - Q(x)| <= scale/2 ~ max|x|/254
        x = {"w": jax.random.normal(jax.random.PRNGKey(0), (32,))}
        red, err = compressed_pmean(x, mesh, axis="pod")
        bound = float(jnp.abs(x["w"]).max()) / 127.0
        assert np.abs(np.asarray(err["w"])).max() <= bound
        print("OK")
    """)
    assert "OK" in out


def test_all_cell_plans_construct():
    """Every (arch x shape x mesh) dry-run plan builds: shapes, specs and
    shardings are mutually consistent (no compile — structure only)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent("""
        import jax
        from repro.configs import ARCH_IDS
        from repro.configs.shapes import cells_for
        from repro.launch.input_specs import make_plan
        from repro.launch.mesh import make_production_mesh
        n = 0
        for mp in (False, True):
            mesh = make_production_mesh(multi_pod=mp)
            for arch in ARCH_IDS:
                for shape, _ in cells_for(arch):
                    plan = make_plan(arch, shape, mesh)
                    # structural consistency: every arg has a sharding
                    na = len(jax.tree.leaves(plan.args))
                    ns = len(jax.tree.leaves(plan.in_shardings))
                    assert na == ns, (arch, shape, na, ns)
                    n += 1
        assert n == 64, n
        print("OK", n, "plans")
    """)
    import subprocess, sys
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "OK 64 plans" in r.stdout


def test_explicit_lbp_scatter_parity():
    """train_sp + explicit shard_map LBP (the §Perf-optimized path) must
    produce the same loss as the default implicit path."""
    out = run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.configs import get_reduced
        from repro.sharding.rules import make_rules
        from repro.train.step import (init_train_state, make_train_step,
                                      train_state_specs)
        from repro.optim.adamw import AdamWConfig
        from repro.models.tuning import set_tuning
        from jax.sharding import NamedSharding
        import dataclasses
        cfg = get_reduced("llama3_2_3b")
        # tp=2 so the model axis really splits heads/ff in the reduced cfg
        cfg = dataclasses.replace(cfg, tp=2)
        opt = AdamWConfig(warmup_steps=2, total_steps=10)
        key = jax.random.PRNGKey(0)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}
        mesh = make_mesh((4, 2), ("data", "model"))

        losses = {}
        for name, prof, flags in [
            ("default", "train", dict(explicit_lbp_scatter=False)),
            ("sp_lbp", "train_sp", dict(explicit_lbp_scatter=True)),
        ]:
            set_tuning(**flags)
            rules = make_rules(prof, mesh)
            with mesh:
                st = init_train_state(cfg, key)
                sspec = train_state_specs(cfg, rules)
                st = jax.device_put(st, jax.tree.map(
                    lambda s: NamedSharding(mesh, s), sspec,
                    is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec)))
                _, m = jax.jit(make_train_step(cfg, rules, opt, 2))(st, batch)
            losses[name] = float(m["loss"])
        assert np.isclose(losses["default"], losses["sp_lbp"], rtol=2e-3), losses
        print("OK", losses)
    """)
    assert "OK" in out


def test_train_step_small_mesh_parity():
    """2x4 mesh train_step == single-device train_step (same seeds)."""
    out = run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.configs import get_reduced
        from repro.sharding.rules import Rules, make_rules
        from repro.train.step import (init_train_state, make_train_step,
                                      train_state_specs, batch_specs)
        from repro.optim.adamw import AdamWConfig
        from jax.sharding import NamedSharding
        cfg = get_reduced("llama3_2_3b")
        opt = AdamWConfig(warmup_steps=2, total_steps=10)
        key = jax.random.PRNGKey(0)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}

        # single device
        r0 = Rules.null()
        st0 = init_train_state(cfg, key)
        s0, m0 = jax.jit(make_train_step(cfg, r0, opt, 2))(st0, batch)

        # 2x4 mesh with the train profile
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules("train", mesh)
        with mesh:
            st1 = init_train_state(cfg, key)
            sspec = train_state_specs(cfg, rules)
            st1 = jax.device_put(st1, jax.tree.map(
                lambda s: NamedSharding(mesh, s), sspec,
                is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec)))
            s1, m1 = jax.jit(make_train_step(cfg, rules, opt, 2))(st1, batch)
        assert np.allclose(float(m0["loss"]), float(m1["loss"]), rtol=2e-3), \
            (float(m0["loss"]), float(m1["loss"]))
        # params drift check on one leaf
        a = np.asarray(jax.tree.leaves(s0["params"])[0])
        b = np.asarray(jax.tree.leaves(s1["params"])[0])
        assert np.allclose(a, b, atol=2e-3), np.abs(a-b).max()
        print("OK", float(m0["loss"]), float(m1["loss"]))
    """)
    assert "OK" in out
