"""Hypothesis property tests on system invariants beyond the paper core."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.partition import LayerAssignment
from repro.models.moe import moe_ffn
from repro.sharding.rules import Rules
from repro.data.pipeline import SyntheticTokens

RULES = Rules.null()


@settings(max_examples=25, deadline=None)
@given(K=st.integers(8, 2048), p=st.integers(2, 16),
       seed=st.integers(0, 10_000))
def test_layer_assignment_split_invariants(K, p, seed):
    rng = np.random.default_rng(seed)
    speeds = rng.uniform(0.25, 4.0, p)
    a = LayerAssignment.from_speeds(K, speeds)
    assert a.K == K
    assert np.all(a.k >= 0)
    assert a.offsets[-1] + a.k[-1] == K
    # monotone: strictly faster device never gets strictly less work
    order = np.argsort(speeds)
    k_sorted = a.k[order]
    # allow rounding slack of 1 unit
    assert np.all(np.diff(k_sorted) >= -max(1, K // p)), (speeds, a.k)
    # Theorem 1: volume is always the lower bound
    assert a.comm_volume == 2 * K * K


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), T=st.sampled_from([8, 16]),
       E=st.sampled_from([4, 8]), K=st.sampled_from([1, 2]))
def test_moe_combine_weight_conservation(seed, T, E, K):
    """Per token, combine weights sum to <= 1 (== 1 without drops), so the
    MoE output norm is bounded by the max expert output norm."""
    d, ff = 8, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    router = jax.random.normal(ks[0], (d, E)) * 0.1
    wg = jax.random.normal(ks[1], (E, d, ff)) * 0.05
    wu = jax.random.normal(ks[2], (E, d, ff)) * 0.05
    wd = jax.random.normal(ks[3], (E, ff, d)) * 0.05
    x = jax.random.normal(ks[4], (1, T, d))
    out, aux = moe_ffn(x, router, wg, wu, wd, RULES, experts_per_token=K,
                       capacity_factor=8.0)
    assert np.all(np.isfinite(np.asarray(out)))
    assert np.isfinite(float(aux)) and float(aux) >= 1.0 - 1e-5  # >= balanced


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), step=st.integers(0, 100))
def test_pipeline_random_access_consistency(seed, step):
    """iterating k steps == random access at k (exact resume invariant)."""
    ds = SyntheticTokens(vocab_size=32, global_batch=2, seq_len=8, seed=seed)
    it = iter(ds)
    for _ in range(step % 5):
        next(it)
    via_iter = next(it)
    via_ra = ds.batch_at(step % 5)
    np.testing.assert_array_equal(via_iter["tokens"], via_ra["tokens"])


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_star_modes_ordering(seed):
    """PCSS (full overlap) is never slower than PCCS (no overlap); SCSS
    never slower than SCCS (same communication order, overlap added)."""
    from repro.core.network import random_star
    from repro.core.star import solve
    net = random_star(8, seed=seed)
    N = 300
    assert solve(net, N, "PCSS").finish_time <= \
        solve(net, N, "PCCS").finish_time + 1e-9
    assert solve(net, N, "SCSS").finish_time <= \
        solve(net, N, "SCCS").finish_time + 1e-9


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 5000), n=st.sampled_from([128, 400]))
def test_mesh_lp_lower_bounds_integer(seed, n):
    """LP relaxation lower-bounds every integer schedule (weak duality)."""
    from repro.core.network import random_mesh
    from repro.core.mesh_lp import solve_relaxed
    from repro.core.heuristic import mft_lbp_heuristic
    net = random_mesh(3, 3, seed=seed)
    relax = solve_relaxed(net, n)
    integer = mft_lbp_heuristic(net, n)
    assert integer.t_finish >= relax.t_finish - 1e-6
