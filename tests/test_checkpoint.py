"""Checkpoint store: roundtrip, atomicity, async writer, reshard-restore."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (AsyncCheckpointer, latest_step,
                                    load_checkpoint, save_checkpoint)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16)),
                       "b": jnp.zeros((16,), jnp.float32)},
            "opt": {"m": {"w": jnp.ones((8, 16)) * 0.5,
                          "b": jnp.zeros((16,))},
                    "step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    st = _state()
    save_checkpoint(tmp_path, 12, st)
    assert latest_step(tmp_path) == 12
    target = jax.eval_shape(lambda: _state())
    step, loaded = load_checkpoint(tmp_path, 12, target)
    assert step == 12
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_ignores_partial(tmp_path):
    st = _state()
    save_checkpoint(tmp_path, 5, st)
    # simulate a crashed write: tmp dir + manifest without done
    bad = tmp_path / "step_00000009"
    bad.mkdir()
    (bad / "manifest.json").write_text(json.dumps({"step": 9, "done": False,
                                                   "leaves": {}}))
    (tmp_path / "step_00000011.tmp").mkdir()
    assert latest_step(tmp_path) == 5


def test_multiple_checkpoints_latest_wins(tmp_path):
    for s in (3, 9, 6):
        save_checkpoint(tmp_path, s, _state(s))
    assert latest_step(tmp_path) == 9


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    st = _state(1)
    ck.save(4, st)
    ck.wait()
    assert latest_step(tmp_path) == 4
    target = jax.eval_shape(lambda: _state())
    _, loaded = load_checkpoint(tmp_path, 4, target)
    np.testing.assert_array_equal(np.asarray(st["params"]["w"]),
                                  np.asarray(loaded["params"]["w"]))


def test_restore_with_different_sharding(tmp_path):
    """Reshard-on-restore: same host, different (trivial) sharding objects —
    the elastic-rescale code path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import make_mesh
    st = _state(2)
    save_checkpoint(tmp_path, 1, st)
    mesh = make_mesh((1,), ("data",))
    shardings = jax.tree.map(lambda x: NamedSharding(mesh, P()), st)
    target = jax.eval_shape(lambda: _state())
    _, loaded = load_checkpoint(tmp_path, 1, target, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(st["params"]["w"]),
                                  np.asarray(loaded["params"]["w"]))
    assert loaded["params"]["w"].sharding == shardings["params"]["w"]


def test_missing_leaf_raises(tmp_path):
    st = {"a": jnp.zeros(3)}
    save_checkpoint(tmp_path, 2, st)
    target = jax.eval_shape(lambda: {"a": jnp.zeros(3), "b": jnp.zeros(4)})
    with pytest.raises(KeyError):
        load_checkpoint(tmp_path, 2, target)


def test_latest_step_skips_torn_manifest(tmp_path):
    """A crashed writer can leave a directory whose manifest is torn —
    half-written JSON must read as 'not a checkpoint', not a crash."""
    save_checkpoint(tmp_path, 5, _state())
    torn = tmp_path / "step_00000009"
    torn.mkdir()
    (torn / "manifest.json").write_text('{"step": 9, "done": tru')  # torn
    empty = tmp_path / "step_00000012"
    empty.mkdir()
    (empty / "manifest.json").write_text("")                        # empty
    notdict = tmp_path / "step_00000013"
    notdict.mkdir()
    (notdict / "manifest.json").write_text("[1, 2]")        # wrong type
    assert latest_step(tmp_path) == 5


# ---------------------------------------------------------------------------
# AsyncCheckpointer: ordering, error propagation, save-during-save
# ---------------------------------------------------------------------------

def test_async_checkpointer_wait_is_idempotent(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    ck.wait()                       # nothing in flight: no-op
    ck.save(1, _state())
    ck.wait()
    ck.wait()                       # second wait after join: no-op
    assert latest_step(tmp_path) == 1


def test_async_checkpointer_error_propagates_on_wait(tmp_path):
    # the checkpoint 'directory' is an existing FILE: the worker thread's
    # save_checkpoint must fail, and the failure must surface on wait()
    blocked = tmp_path / "not_a_dir"
    blocked.write_text("occupied")
    ck = AsyncCheckpointer(blocked)
    ck.save(1, {"a": jnp.zeros(2)})
    with pytest.raises(OSError):
        ck.wait()
    # the error is delivered once, then cleared — the writer is reusable
    ck.wait()


def test_async_checkpointer_save_during_save_serializes(tmp_path,
                                                        monkeypatch):
    """A save issued while one is in flight waits for it (snapshot
    ordering): both land, in order, and nothing is lost."""
    import threading
    import repro.checkpoint.store as store_mod
    release = threading.Event()
    order = []
    real = store_mod.save_checkpoint

    def slow_save(directory, step, state):
        if step == 1:
            release.wait(timeout=30)
        order.append(step)
        return real(directory, step, state)

    monkeypatch.setattr(store_mod, "save_checkpoint", slow_save)
    ck = AsyncCheckpointer(tmp_path)
    ck.save(1, _state(1))
    t = threading.Thread(target=lambda: ck.save(2, _state(2)))
    t.start()                   # blocks in save(2)'s wait() on save(1)
    assert latest_step(tmp_path) is None    # nothing landed yet
    release.set()
    t.join(timeout=30)
    ck.wait()
    assert order == [1, 2]
    assert latest_step(tmp_path) == 2


def test_async_checkpointer_overlapping_saves_all_land(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    for s in (1, 2, 3):
        ck.save(s, _state(s))   # each save waits for the previous write
    ck.wait()
    assert latest_step(tmp_path) == 3
    for s in (1, 2, 3):
        target = jax.eval_shape(lambda: _state())
        _, loaded = load_checkpoint(tmp_path, s, target)
        np.testing.assert_array_equal(
            np.asarray(_state(s)["params"]["w"]),
            np.asarray(loaded["params"]["w"]))


# ---------------------------------------------------------------------------
# resharding checkpoints: restore under a different topology
# ---------------------------------------------------------------------------

def _plans(K):
    from repro.plan import StarTopology, plan, production_topology
    plan_prod = plan(production_topology(multi_pod=True, seed=0), K,
                     quantum=1)           # the (2,16,16) fleet plan
    plan_star = plan(StarTopology.from_speeds(
        np.array([1.0, 2.0, 0.5, 1.5, 1.0, 3.0, 0.75])), K, quantum=1)
    return plan_prod, plan_star


def test_reshard_restore_bit_identical_across_topologies(tmp_path):
    """Acceptance: params saved under the (2,16,16) production plan
    restore bit-identical under a 7-device star plan (and back)."""
    from repro.checkpoint import (plan_offsets, restore_resharded,
                                  save_sharded)
    K = 1024
    plan_prod, plan_star = _plans(K)
    rng = np.random.default_rng(0)
    state = {"params": {"w": rng.normal(size=(K, 8)).astype(np.float32),
                        "b": np.arange(8, dtype=np.float32)},
             "step": np.asarray(7, np.int32)}
    save_sharded(tmp_path, 3, state, plan_prod)
    step, full, shards = restore_resharded(tmp_path, 3, state, plan_star)
    assert step == 3 and len(shards) == plan_star.p
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(full)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    offs = plan_offsets(plan_star)
    for i, sh in enumerate(shards):
        np.testing.assert_array_equal(
            sh["params"]["w"], state["params"]["w"][offs[i]:offs[i + 1]])
        np.testing.assert_array_equal(sh["params"]["b"],
                                      state["params"]["b"])   # replicated
    # ... and the reverse direction: star checkpoint -> production plan
    save_sharded(tmp_path, 4, state, plan_star)
    _, full2, shards2 = restore_resharded(tmp_path, 4, state, plan_prod)
    np.testing.assert_array_equal(full2["params"]["w"],
                                  state["params"]["w"])
    assert len(shards2) == plan_prod.p
    assert sum(s["params"]["w"].shape[0] for s in shards2) == K


def test_reshard_load_sharded_roundtrip(tmp_path):
    from repro.checkpoint import load_sharded, save_sharded
    _, plan_star = _plans(128)
    state = {"w": np.arange(128 * 2, dtype=np.int64).reshape(128, 2)}
    save_sharded(tmp_path, 1, state, plan_star)
    step, full = load_sharded(tmp_path, 1, state)
    assert step == 1
    np.testing.assert_array_equal(full["w"], state["w"])


def test_reshard_rejects_mismatched_load(tmp_path):
    from repro.checkpoint import restore_resharded, save_sharded
    from repro.plan import StarTopology, plan
    plan_a = plan(StarTopology.from_speeds(np.array([1.0, 1.0])), 64,
                  quantum=1)
    plan_b = plan(StarTopology.from_speeds(np.array([1.0, 1.0])), 128,
                  quantum=1)
    state = {"w": np.zeros((64, 2), np.float32)}
    save_sharded(tmp_path, 1, state, plan_a)
    with pytest.raises(ValueError, match="load"):
        restore_resharded(tmp_path, 1, state, plan_b)


def test_reshard_atomicity_ignores_partial(tmp_path):
    from repro.checkpoint import save_sharded
    from repro.plan import StarTopology, plan
    p = plan(StarTopology.from_speeds(np.array([1.0, 1.0])), 64, quantum=1)
    save_sharded(tmp_path, 5, {"w": np.zeros((64,), np.float32)}, p)
    (tmp_path / "step_00000009.tmp").mkdir()   # crashed writer
    assert latest_step(tmp_path) == 5


# ---------------------------------------------------------------------------
# shard integrity: checksum sidecars + typed CorruptShard
# ---------------------------------------------------------------------------

def test_save_sharded_writes_checksum_sidecars(tmp_path):
    import hashlib
    from repro.checkpoint import save_sharded, verify_sharded
    _, plan_star = _plans(128)
    state = {"w": np.arange(128 * 2, dtype=np.float32).reshape(128, 2),
             "b": np.ones(5, np.float32)}
    d = save_sharded(tmp_path, 2, state, plan_star)
    payloads = sorted(f for f in d.iterdir() if f.suffix == ".npy")
    assert len(payloads) == plan_star.p + 1   # shards + replicated leaf
    for f in payloads:
        side = f.with_name(f.name + ".sha256")
        assert side.exists(), f"missing sidecar for {f.name}"
        assert side.read_text().strip() \
            == hashlib.sha256(f.read_bytes()).hexdigest()
    assert verify_sharded(tmp_path, 2) == len(payloads)


def test_truncated_shard_raises_corrupt_shard(tmp_path):
    """A torn write (payload truncated after the manifest landed) must
    raise the typed error, never np.load garbage or a crash deep in
    deserialization."""
    from repro.checkpoint import (CorruptShard, restore_resharded,
                                  save_sharded, verify_sharded)
    _, plan_star = _plans(128)
    state = {"w": np.arange(128 * 4, dtype=np.float32).reshape(128, 4)}
    d = save_sharded(tmp_path, 1, state, plan_star)
    victim = sorted(d.glob("w__shard*.npy"))[2]
    victim.write_bytes(victim.read_bytes()[:40])   # torn mid-write
    with pytest.raises(CorruptShard, match="sha256 mismatch"):
        restore_resharded(tmp_path, 1, state, plan_star)
    with pytest.raises(CorruptShard):
        verify_sharded(tmp_path, 1)


def test_bitflip_and_missing_shard_raise_corrupt_shard(tmp_path):
    from repro.checkpoint import (CorruptShard, load_sharded, save_sharded)
    _, plan_star = _plans(128)
    state = {"w": np.arange(128, dtype=np.float32)}
    d = save_sharded(tmp_path, 1, state, plan_star)
    victim = sorted(d.glob("w__shard*.npy"))[0]
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF                                 # silent bit rot
    victim.write_bytes(bytes(raw))
    with pytest.raises(CorruptShard, match="mismatch"):
        load_sharded(tmp_path, 1, state)
    victim.unlink()                                 # lost file
    with pytest.raises(CorruptShard, match="missing"):
        load_sharded(tmp_path, 1, state)


def test_missing_sidecar_raises_corrupt_shard(tmp_path):
    """No sidecar, no trust: a payload that cannot be verified is
    treated as corrupt (pre-integrity checkpoints must be re-saved)."""
    from repro.checkpoint import CorruptShard, load_sharded, save_sharded
    _, plan_star = _plans(128)
    state = {"w": np.arange(128, dtype=np.float32)}
    d = save_sharded(tmp_path, 1, state, plan_star)
    next(iter(sorted(d.glob("*.sha256")))).unlink()
    with pytest.raises(CorruptShard, match="sidecar missing"):
        load_sharded(tmp_path, 1, state)


def test_intact_checkpoint_unaffected_by_integrity_layer(tmp_path):
    """The happy path round-trips bit-identical through verification."""
    from repro.checkpoint import restore_resharded, save_sharded
    plan_prod, plan_star = _plans(256)
    rng = np.random.default_rng(3)
    state = {"w": rng.normal(size=(256, 3)).astype(np.float32)}
    save_sharded(tmp_path, 9, state, plan_prod)
    _, full, shards = restore_resharded(tmp_path, 9, state, plan_star)
    np.testing.assert_array_equal(full["w"], state["w"])
    assert len(shards) == plan_star.p
