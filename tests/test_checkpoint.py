"""Checkpoint store: roundtrip, atomicity, async writer, reshard-restore."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (AsyncCheckpointer, latest_step,
                                    load_checkpoint, save_checkpoint)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16)),
                       "b": jnp.zeros((16,), jnp.float32)},
            "opt": {"m": {"w": jnp.ones((8, 16)) * 0.5,
                          "b": jnp.zeros((16,))},
                    "step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    st = _state()
    save_checkpoint(tmp_path, 12, st)
    assert latest_step(tmp_path) == 12
    target = jax.eval_shape(lambda: _state())
    step, loaded = load_checkpoint(tmp_path, 12, target)
    assert step == 12
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_ignores_partial(tmp_path):
    st = _state()
    save_checkpoint(tmp_path, 5, st)
    # simulate a crashed write: tmp dir + manifest without done
    bad = tmp_path / "step_00000009"
    bad.mkdir()
    (bad / "manifest.json").write_text(json.dumps({"step": 9, "done": False,
                                                   "leaves": {}}))
    (tmp_path / "step_00000011.tmp").mkdir()
    assert latest_step(tmp_path) == 5


def test_multiple_checkpoints_latest_wins(tmp_path):
    for s in (3, 9, 6):
        save_checkpoint(tmp_path, s, _state(s))
    assert latest_step(tmp_path) == 9


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    st = _state(1)
    ck.save(4, st)
    ck.wait()
    assert latest_step(tmp_path) == 4
    target = jax.eval_shape(lambda: _state())
    _, loaded = load_checkpoint(tmp_path, 4, target)
    np.testing.assert_array_equal(np.asarray(st["params"]["w"]),
                                  np.asarray(loaded["params"]["w"]))


def test_restore_with_different_sharding(tmp_path):
    """Reshard-on-restore: same host, different (trivial) sharding objects —
    the elastic-rescale code path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import make_mesh
    st = _state(2)
    save_checkpoint(tmp_path, 1, st)
    mesh = make_mesh((1,), ("data",))
    shardings = jax.tree.map(lambda x: NamedSharding(mesh, P()), st)
    target = jax.eval_shape(lambda: _state())
    _, loaded = load_checkpoint(tmp_path, 1, target, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(st["params"]["w"]),
                                  np.asarray(loaded["params"]["w"]))
    assert loaded["params"]["w"].sharding == shardings["params"]["w"]


def test_missing_leaf_raises(tmp_path):
    st = {"a": jnp.zeros(3)}
    save_checkpoint(tmp_path, 2, st)
    target = jax.eval_shape(lambda: {"a": jnp.zeros(3), "b": jnp.zeros(4)})
    with pytest.raises(KeyError):
        load_checkpoint(tmp_path, 2, target)
