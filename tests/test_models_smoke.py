"""Per-arch reduced-config smoke: one forward/train step on CPU, shapes +
no NaNs; serving (prefill + decode) consistent with the full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import transformer as T
from repro.sharding.rules import Rules

RULES = Rules.null()
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=24):
    b = {"tokens": jax.random.randint(KEY, (B, S - cfg.prefix_len), 0,
                                      cfg.vocab_size)}
    if cfg.prefix_len:
        b["prefix_embeds"] = jax.random.normal(
            KEY, (B, cfg.prefix_len, cfg.d_model), jnp.bfloat16) * 0.02
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_reduced(arch)
    params = T.init_params(cfg, KEY)
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: T.loss_fn(p, cfg, RULES, batch)))(params)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert np.all(np.isfinite(np.asarray(g))), path
    # shapes preserved
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(grads)[0]):
        assert a.shape == b.shape, pa


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    params = T.init_params(cfg, KEY)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    hid, aux = T.forward_hidden(params, cfg, RULES, toks, remat=False)
    assert hid.shape == (B, S, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(hid, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serving_consistency(arch):
    """prefill(S-1) + decode(1) logits == full-forward logits at last pos.

    f32 cache isolates path-consistency from cache-storage precision (the
    production bf16 cache trades ~1e-2 logit precision for half the HBM)."""
    cfg = get_reduced(arch)
    params = T.init_params(cfg, KEY)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)

    cache = T.init_cache(cfg, B, S, dtype=jnp.float32)
    cache, _ = T.prefill(params, cfg, RULES, toks[:, :S - 1], cache)
    pos = jnp.full((B,), S - 1, jnp.int32)
    dec_logits, _ = T.decode_step(params, cfg, RULES, toks[:, S - 1:S], pos,
                                  cache)

    hid, _ = T.forward_hidden(params, cfg, RULES, toks, remat=False)
    from repro.models.layers import rms_norm
    hN = rms_norm(hid, params["final_norm"], cfg.norm_eps)
    ref = jnp.einsum("bd,vd->bv", hN[:, -1].astype(jnp.float32),
                     params["embed"].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]), np.asarray(ref),
                               rtol=5e-3, atol=5e-3)


def test_serving_bf16_cache_close():
    """Production bf16 cache: decode logits within bf16-rounding tolerance
    of the f32-cache path (storage precision is the only difference)."""
    cfg = get_reduced("llama3_2_3b")
    params = T.init_params(cfg, KEY)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    outs = {}
    for dt in (jnp.float32, jnp.bfloat16):
        cache = T.init_cache(cfg, B, S, dtype=dt)
        cache, _ = T.prefill(params, cfg, RULES, toks[:, :S - 1], cache)
        pos = jnp.full((B,), S - 1, jnp.int32)
        logits, _ = T.decode_step(params, cfg, RULES, toks[:, S - 1:S], pos,
                                  cache)
        outs[dt] = np.asarray(logits)
    np.testing.assert_allclose(outs[jnp.float32], outs[jnp.bfloat16],
                               rtol=0.1, atol=0.1)


def test_full_configs_param_counts():
    """Full configs land near published sizes (sanity on the registry)."""
    expected = {
        "llama3_2_3b": 3.2e9, "mistral_large_123b": 122e9,
        "granite_8b": 8.1e9, "qwen3_14b": 14e9, "olmoe_1b_7b": 6.9e9,
        "qwen3_moe_235b_a22b": 235e9, "pixtral_12b": 11.6e9,
        "recurrentgemma_9b": 8.5e9, "xlstm_1_3b": 1.1e9,
        "musicgen_medium": 1.8e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).n_params()
        assert abs(got - want) / want < 0.15, (arch, got, want)


def test_moe_active_params():
    cfg = get_config("qwen3_moe_235b_a22b")
    act = cfg.n_active_params()
    assert 18e9 < act < 26e9  # "A22B"
    cfg2 = get_config("olmoe_1b_7b")
    assert 0.9e9 < cfg2.n_active_params() < 1.6e9  # "1B active"


def test_windowed_ring_cache_long_decode():
    """recurrentgemma: decode far beyond the window with a ring cache
    matches a full-cache run (the long_500k mechanism)."""
    cfg = get_reduced("recurrentgemma_9b")
    params = T.init_params(cfg, KEY)
    B, S = 1, 40   # window is 16 in reduced config
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)

    # run 1: ring cache sized to the window
    cache = T.init_cache(cfg, B, S, dtype=jnp.float32)  # -> min(S, window)=16
    cache, _ = T.prefill(params, cfg, RULES, toks[:, :S], cache)
    pos = jnp.full((B,), S, jnp.int32)
    ring_logits, _ = T.decode_step(params, cfg, RULES, toks[:, S:S + 1], pos,
                                   cache)

    # run 2: full forward reference
    hid, _ = T.forward_hidden(params, cfg, RULES, toks, remat=False)
    from repro.models.layers import rms_norm
    hN = rms_norm(hid, params["final_norm"], cfg.norm_eps)
    ref = jnp.einsum("bd,vd->bv", hN[:, -1].astype(jnp.float32),
                     params["embed"].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(ring_logits[:, 0]),
                               np.asarray(ref), rtol=5e-3, atol=5e-3)
