"""AdamW, schedule, grad clipping, int8 compression primitives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               cosine_schedule)
from repro.optim.compression import dequantize_int8, quantize_int8


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=1, total_steps=200,
                      weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 0.01 * l0


def test_cosine_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(jnp.asarray(s), cfg)) for s in
           [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.0, abs=1e-6)


def test_grad_clip_applied():
    cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=1, grad_clip=1.0,
                      weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, state, metrics = adamw_update(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-5)
    # post-clip first moment is bounded by (1-b1) * clip-scaled grad
    m = np.asarray(state["m"]["w"])
    assert np.all(np.abs(m) <= (1 - cfg.b1) * 1.0)


def test_int8_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 3.0
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(x) - np.asarray(dequantize_int8(q, s)))
    assert err.max() <= float(s) / 2 + 1e-7
    assert q.dtype == jnp.int8


def test_error_feedback_converges():
    """EF accumulation: mean of quantized-with-feedback equals true signal."""
    rng = jax.random.PRNGKey(1)
    x = jax.random.normal(rng, (256,))
    e = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    steps = 50
    for _ in range(steps):
        q, s = quantize_int8(x + e)
        deq = dequantize_int8(q, s)
        e = (x + e) - deq
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc / steps), np.asarray(x),
                               atol=float(s) / 2 + 1e-6)
