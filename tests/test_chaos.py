"""Fault-domain hardening: chaos-injected fleets under composite
schedules (kill x hang x slow x transient x torn-shard x timing).

The contract under test (ISSUE 9 / ROADMAP "Fleet runtime" fault
matrix): every RECOVERABLE schedule preserves the fleet oracle — tokens
identical to per-request greedy decoding, zero silent drops — and
byte-identical trace determinism; every unrecoverable schedule fails
loudly with a typed error (``FleetDegraded``, ``CorruptShard``), never
a hang, never garbage.  All on the tick clock: re-running any schedule
replays exactly.
"""

import asyncio

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CorruptShard, reshard_state
from repro.fleet import (ChaosReplicaSpec, ChaosSchedule, FaultPlan,
                         FleetController, FleetDegraded, FleetFrontend,
                         RetryPolicy, TransientError, chaos_verdicts,
                         run_chaos)
from repro.obs import MetricsRegistry, Tracer, to_chrome_json
from test_fleet import check_oracle, fake_replica, fake_workload


def mk(name, rate, fault):
    return fake_replica(name, rate=rate, fault=fault)


def ckpt_state(k=1024):
    """A co-hosted LBP state with one load-sized (partitioned) leaf and
    one replicated leaf — what the controller snapshots and restores."""
    return {"w": np.arange(k * 2, dtype=np.float32).reshape(k, 2),
            "bias": np.arange(3, dtype=np.float32)}


# ---------------------------------------------------------------------------
# retry/backoff: transient vs fatal classification
# ---------------------------------------------------------------------------

def test_transient_retry_recovers_without_kill():
    """A transient window shorter than the retry budget clears through
    backoff: no kill, no requeue churn, oracle intact."""
    reps = [mk("t", 1.0, FaultPlan(transient_at=3, transient_for=2)),
            mk("ok", 1.0, None)]
    ctrl = FleetController(reps, retry=RetryPolicy(max_retries=3))
    wl = fake_workload(12, seed=11)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    report = ctrl.run()
    check_oracle(wl, report.completed)
    assert report.retries == 2          # two failing attempts
    assert report.recoveries == 1       # one incident, cleared
    assert report.kills == []
    assert ctrl.metrics.counter_value("retries") == 2
    assert ctrl.metrics.counter_value("recoveries") == 1


def test_retry_exhaustion_escalates_to_kill_and_requeue():
    """A transient that never clears within the budget is reclassified
    fatal: the existing kill + exactly-once-requeue path drains the
    replica's work onto the survivor, oracle intact."""
    reps = [mk("flaky", 1.0, FaultPlan(transient_at=2, transient_for=50)),
            mk("ok", 1.0, None)]
    ctrl = FleetController(reps, retry=RetryPolicy(max_retries=2))
    wl = fake_workload(10, seed=4, stagger=0.0)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    report = ctrl.run()
    check_oracle(wl, report.completed)
    assert [n for _, n in report.kills] == ["flaky"]
    assert any("retry-exhausted" in e for e in report.events)
    assert report.recoveries == 0
    assert report.retries == 2          # budget spent before escalation
    assert report.requeues >= 1


def test_backoff_is_exponential_and_capped_on_tick_clock():
    tracer = Tracer()
    reps = [mk("t", 1.0, FaultPlan(transient_at=1, transient_for=5)),
            mk("ok", 1.0, None)]
    ctrl = FleetController(
        reps, retry=RetryPolicy(max_retries=8, backoff_base=1,
                                backoff_cap=4),
        tracer=tracer)
    wl = fake_workload(8, seed=2)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    report = ctrl.run()
    check_oracle(wl, report.completed)
    retries = [e for e in tracer.events if e["name"] == "retry"]
    assert [e["args"]["backoff"] for e in retries] == [1, 2, 4, 4, 4]
    assert [e["args"]["attempt"] for e in retries] == [1, 2, 3, 4, 5]
    # backed-off ticks stamp the heartbeat: a backoff is never misread
    # as a hang, so the only terminal events are the recovery itself
    assert report.kills == [] and report.recoveries == 1


def test_transient_during_backoff_not_heartbeat_killed():
    """Backoff longer than miss_threshold: the controller stamps the
    heartbeat of a deliberately idled replica, so the health plane does
    not shoot the patient it is treating."""
    reps = [mk("t", 1.0, FaultPlan(transient_at=1, transient_for=2)),
            mk("ok", 1.0, None)]
    ctrl = FleetController(
        reps, miss_threshold=2,
        retry=RetryPolicy(max_retries=5, backoff_base=8, backoff_cap=8))
    wl = fake_workload(8, seed=9)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    report = ctrl.run()
    check_oracle(wl, report.completed)
    assert report.kills == []
    assert ctrl.metrics.counter_value("heartbeat_misses") == 0


# ---------------------------------------------------------------------------
# live checkpoint-recovery: restore re-sliced on every rescale
# ---------------------------------------------------------------------------

def test_restore_on_kill_reslices_onto_survivor_plan(tmp_path):
    state = ckpt_state()
    reps = [mk("a", 1.0, FaultPlan(kill_at=5)), mk("b", 2.0, None),
            mk("c", 1.0, None)]
    ctrl = FleetController(reps, checkpoint_dir=tmp_path,
                           checkpoint_state=state, checkpoint_every=3)
    wl = fake_workload(16, seed=6)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    report = ctrl.run()
    check_oracle(wl, report.completed)
    assert report.restores == 1 and report.corrupt_shards == 0
    assert any("restored snapshot" in e for e in report.events)
    # the restored views ARE the survivors' new plan's re-slices
    assert len(ctrl.shards) == 2
    want = reshard_state(state, ctrl.rebalance.plan)
    for got, exp in zip(ctrl.shards, want):
        assert np.array_equal(got["w"], exp["w"])
        assert np.array_equal(got["bias"], exp["bias"])
    # shard sizes follow the plan's integer shares exactly
    assert [s["w"].shape[0] for s in ctrl.shards] \
        == [int(k) for k in ctrl.rebalance.plan.k]


def test_restore_on_join_reslices_onto_grown_fleet(tmp_path):
    state = ckpt_state()
    reps = [mk("a", 1.0, None), mk("b", 1.0, None)]
    ctrl = FleetController(reps, checkpoint_dir=tmp_path,
                           checkpoint_state=state, checkpoint_every=4)
    ctrl.schedule_join(mk("c", 2.0, None), at_tick=6)
    wl = fake_workload(16, seed=8)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    report = ctrl.run()
    check_oracle(wl, report.completed)
    assert report.restores == 1
    assert len(ctrl.shards) == 3        # the joiner holds a share
    want = reshard_state(state, ctrl.rebalance.plan)
    for got, exp in zip(ctrl.shards, want):
        assert np.array_equal(got["w"], exp["w"])


def test_torn_shard_falls_back_to_older_intact_epoch(tmp_path):
    """A replica tearing its shard of every new snapshot: the kill-time
    restore detects the corruption (CorruptShard), counts it, and falls
    back to the older intact epoch — garbage is never loaded and the
    run still drains oracle-identical."""
    state = ckpt_state()
    reps = [mk("a", 1.0, FaultPlan(kill_at=8)),
            # b's shards torn from its step 3 on: the epoch-0 snapshot
            # (written before any step ran) stays intact
            mk("b", 1.0, FaultPlan(torn_shard_at=3)),
            mk("c", 1.0, None)]
    ctrl = FleetController(reps, checkpoint_dir=tmp_path,
                           checkpoint_state=state, checkpoint_every=4)
    wl = fake_workload(16, seed=12)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    report = ctrl.run()
    check_oracle(wl, report.completed)
    assert report.corrupt_shards >= 1   # the torn epoch was detected
    assert report.restores == 1         # ...and an intact one restored
    assert any("corrupt" in e for e in report.events)
    want = reshard_state(state, ctrl.rebalance.plan)
    for got, exp in zip(ctrl.shards, want):
        assert np.array_equal(got["w"], exp["w"])


def test_every_snapshot_torn_raises_corrupt_shard(tmp_path):
    """Unrecoverable corruption fails LOUDLY with the typed error — the
    controller refuses to hand garbage params to the survivors."""
    state = ckpt_state()
    reps = [mk("a", 1.0, FaultPlan(kill_at=4)),
            mk("b", 1.0, FaultPlan(torn_shard_at=0))]   # torn from birth
    ctrl = FleetController(reps, checkpoint_dir=tmp_path,
                           checkpoint_state=state, checkpoint_every=2)
    wl = fake_workload(8, seed=3)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    with pytest.raises(CorruptShard):
        ctrl.run()


# ---------------------------------------------------------------------------
# graceful degradation: typed rejection + bounded drain
# ---------------------------------------------------------------------------

def test_degraded_submit_rejected_with_retry_after():
    """All capacity lost, join scheduled: the frontend rejects with the
    typed FleetDegraded whose retry_after points at the join tick —
    instead of queueing onto a fleet that cannot serve."""
    reps = [mk("a", 1.0, FaultPlan(kill_at=2)),
            mk("b", 1.0, FaultPlan(kill_at=2))]
    ctrl = FleetController(reps, miss_threshold=3)
    ctrl.schedule_join(mk("c", 1.0, None), at_tick=12)
    wl = fake_workload(6, seed=5, stagger=0.0)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    while not ctrl.degraded:
        ctrl.tick()
    fe = FleetFrontend(ctrl)
    with pytest.raises(FleetDegraded) as ei:
        asyncio.run(fe.submit(np.arange(1, 6), 4))
    assert ei.value.retry_after == 12 - ctrl.tick_count
    assert ctrl.metrics.counter_value("degraded_rejections") == 1


def test_min_alive_floor_rejects_above_zero():
    """A capacity floor above 1: losing one of two replicas degrades
    the fleet even though it can still limp along."""
    reps = [mk("a", 1.0, FaultPlan(kill_at=3)), mk("b", 1.0, None)]
    ctrl = FleetController(reps, min_alive=2)
    assert not ctrl.degraded
    wl = fake_workload(6, seed=1, stagger=0.0)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    while not ctrl.degraded:
        ctrl.tick()
    fe = FleetFrontend(ctrl)
    with pytest.raises(FleetDegraded) as ei:
        asyncio.run(fe.submit(np.arange(1, 6), 4))
    assert ei.value.retry_after is None     # no recovery scheduled
    # the survivor still drains what was already admitted
    report = ctrl.run()
    check_oracle(wl, report.completed)


def test_join_exits_degradation_and_replans():
    """join_devices arriving while degraded: the fleet re-plans onto the
    joiner, exits degradation, and drains oracle-identical."""
    reps = [mk("a", 1.0, FaultPlan(kill_at=2)),
            mk("b", 1.0, FaultPlan(kill_at=2))]
    ctrl = FleetController(reps, miss_threshold=3)
    ctrl.schedule_join(mk("c", 1.5, None), at_tick=10)
    wl = fake_workload(10, seed=7, stagger=0.0)
    for p, m, a in wl:
        ctrl.submit(p, m, arrival=a)
    saw_degraded = False
    report = None
    while True:
        more = ctrl.tick()
        saw_degraded = saw_degraded or ctrl.degraded
        if not more:
            break
    assert saw_degraded
    assert not ctrl.degraded            # the join restored capacity
    assert ctrl.alive_names() == ["c"]
    report = ctrl.report()
    check_oracle(wl, report.completed)  # zero silent drops across the gap
    # degradation exit went through a replan onto the joiner
    assert ctrl.rebalance.assignment.k.shape == (1,)


def test_drain_deadline_raises_instead_of_hanging():
    """A replica hung below the heartbeat radar (miss_threshold too
    large to trip): drain(deadline=...) raises the typed error instead
    of ticking forever."""
    reps = [mk("h", 1.0, FaultPlan(hang_at=2))]
    ctrl = FleetController(reps, miss_threshold=10**9)
    fe = FleetFrontend(ctrl)

    async def go():
        await fe.submit(np.arange(1, 9), 8)
        await fe.drain(deadline=50)

    with pytest.raises(FleetDegraded, match="drain deadline"):
        asyncio.run(go())
    assert ctrl.tick_count <= 60        # bounded, not a hang


def test_stream_terminates_on_kill_during_drain():
    """S2 regression (kill-during-drain schedule): a streamed request
    whose only replica dies after drain() began must terminate with a
    typed error — both the drainer and the streamer — never hang."""
    reps = [mk("only", 1.0, FaultPlan(kill_at=4))]
    ctrl = FleetController(reps, miss_threshold=3)
    fe = FleetFrontend(ctrl)

    async def go():
        rid = await fe.submit(np.arange(1, 9), 8)

        async def consume():
            got = []
            async for tok in fe.stream(rid):
                got.append(tok)
            return got

        task = asyncio.ensure_future(consume())
        drain_err = stream_err = None
        try:
            await fe.drain()
        except (FleetDegraded, RuntimeError) as e:
            drain_err = e
        try:
            await task
        except (FleetDegraded, RuntimeError) as e:
            stream_err = e
        return drain_err, stream_err

    drain_err, stream_err = asyncio.run(go())
    assert isinstance(drain_err, FleetDegraded)
    assert stream_err is not None       # typed, not a hang
    assert fe._closed                   # drain closed on the failure path


# ---------------------------------------------------------------------------
# the chaos property: composite schedules, one harness
# ---------------------------------------------------------------------------

def composite_schedule(kill_at, hang_at, transient_at, slow, join_at,
                       checkpoint_every=0, torn=False):
    """Four replicas, one fault domain each, plus a healthy anchor so
    every schedule is recoverable; ``join_at`` optionally grows it."""
    return ChaosSchedule(
        replicas=(
            ChaosReplicaSpec("k", 1.0,
                             FaultPlan(kill_at=kill_at)
                             if kill_at else None),
            ChaosReplicaSpec("h", 1.0,
                             FaultPlan(hang_at=hang_at)
                             if hang_at else None),
            ChaosReplicaSpec("t", 2.0,
                             FaultPlan(transient_at=transient_at,
                                       transient_for=2,
                                       torn_shard_at=3 if torn else None)
                             if transient_at else None),
            ChaosReplicaSpec("anchor", 1.5,
                             FaultPlan(slow_at=2, slow_factor=2)
                             if slow else None),
        ),
        join_at=join_at, checkpoint_every=checkpoint_every)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16),
       n=st.integers(4, 20),
       kill_at=st.sampled_from([None, 2, 6, 14]),
       hang_at=st.sampled_from([None, 3, 9]),
       transient_at=st.sampled_from([None, 2, 7]),
       slow=st.booleans(),
       join_at=st.sampled_from([None, 5, 12]),
       stagger=st.sampled_from([0.0, 0.5]))
def test_chaos_property_recoverable_schedules_preserve_oracle(
        seed, n, kill_at, hang_at, transient_at, slow, join_at, stagger):
    """ANY recoverable composite schedule (kill x hang x slow x
    transient x timing): tokens identical to the per-request greedy
    oracle, zero silent drops — the acceptance property."""
    sched = composite_schedule(kill_at, hang_at, transient_at, slow,
                               join_at)
    wl = fake_workload(n, seed=seed, stagger=stagger)
    ctrl, report = run_chaos(sched, mk, wl,
                             retry=RetryPolicy(max_retries=3))
    check_oracle(wl, report.completed)
    v = chaos_verdicts(sched, report, wl)
    assert v["gates"]["zero_silent_drops"]
    assert v["gates"]["recovered_all_transients"]


def test_chaos_composite_trace_byte_identical(tmp_path):
    """Determinism pin: the SAME composite chaos schedule (kill + hang +
    transient + slow + torn shard + join + checkpointing) produces a
    byte-identical Chrome trace across two runs — every retry, backoff,
    restore and corrupt-shard instant lands on the same tick."""
    def one_run(subdir):
        tracer, metrics = Tracer(), MetricsRegistry()
        sched = composite_schedule(kill_at=6, hang_at=9, transient_at=2,
                                   slow=True, join_at=10,
                                   checkpoint_every=4, torn=True)
        wl = fake_workload(16, seed=13)
        d = tmp_path / subdir
        ctrl, report = run_chaos(sched, mk, wl,
                                 retry=RetryPolicy(max_retries=3),
                                 checkpoint_dir=d,
                                 checkpoint_state=ckpt_state(),
                                 tracer=tracer, metrics=metrics)
        check_oracle(wl, report.completed)
        assert report.recoveries >= 1 and report.restores >= 1
        return to_chrome_json(tracer), metrics.snapshot()

    j1, m1 = one_run("run1")
    j2, m2 = one_run("run2")
    assert j1 == j2
    assert m1 == m2


def test_unrecoverable_schedule_raises_typed_never_hangs():
    """Loss of every replica with no join scheduled: the typed
    FleetDegraded (a RuntimeError) escapes promptly — the unrecoverable
    half of the acceptance property."""
    sched = ChaosSchedule(
        replicas=(ChaosReplicaSpec("a", 1.0, FaultPlan(kill_at=3)),
                  ChaosReplicaSpec("b", 1.0, FaultPlan(hang_at=2))))
    wl = fake_workload(6, seed=2, stagger=0.0)
    with pytest.raises(FleetDegraded, match="no live replica"):
        run_chaos(sched, mk, wl, miss_threshold=2)


def test_transient_error_is_not_replica_dead():
    """The classification boundary: TransientError must not share a
    type with ReplicaDead, or a retry would mask real crashes."""
    from repro.fleet import ReplicaDead
    assert not issubclass(TransientError, ReplicaDead)
    assert not issubclass(ReplicaDead, TransientError)
    rep = mk("x", 1.0, FaultPlan(transient_at=1, transient_for=1))
    rep.submit(np.arange(1, 6), 4)
    with pytest.raises(TransientError):
        rep.step(0)
    assert rep.step(1)                  # cleared: the engine works again
