"""Straggler rebalance + elastic rescale planning (paper solvers as brain)."""

import numpy as np
import pytest

from repro.core.partition import LayerAssignment
from repro.runtime.rebalance import drop_devices, measure_speeds, plan_rebalance


def test_measure_speeds():
    s = measure_speeds([1.0, 2.0, 1.0, 0.5])   # device 3 is 2x fast, 1 slow
    assert s[3] == s.max()
    assert s[1] == s.min()
    assert s.mean() == pytest.approx(1.0)


def test_plan_rebalance_proportional():
    K = 4096
    plan = plan_rebalance(K, [1.0, 1.0, 2.0, 4.0], quantum=128)
    k = plan.assignment.k
    assert k.sum() == K
    assert np.all(k % 128 == 0)
    assert k[3] > k[2] > k[0]
    assert plan.predicted_speedup > 1.0


def test_plan_rebalance_small_K_falls_back():
    plan = plan_rebalance(16, [1.0, 2.0], quantum=128)
    assert plan.assignment.k.sum() == 16


def test_straggler_gets_less():
    plan = plan_rebalance(2048, [1.0] * 7 + [0.25], quantum=128)
    k = plan.assignment.k
    assert k[-1] <= k[:-1].min()
    assert plan.predicted_speedup > 1.5   # even split is gated by straggler


def test_drop_devices_resolves():
    base = LayerAssignment.even(4096, 8, quantum=128)
    plan = drop_devices(base, dead=[2, 5], speeds=[1.0] * 8, quantum=128)
    assert plan.assignment.p == 6
    assert plan.assignment.K == 4096
    assert np.all(plan.assignment.k % 128 == 0)


def test_layer_assignment_invariants():
    a = LayerAssignment.from_speeds(1024, [1, 2, 3, 4], quantum=1)
    assert a.K == 1024
    assert a.offsets[0] == 0
    assert a.offsets[-1] + a.k[-1] == 1024
    assert a.comm_volume == 2 * 1024 * 1024   # Theorem 1
