"""Straggler rebalance + elastic rescale planning (paper solvers as brain)."""

import numpy as np
import pytest

from repro.core.partition import LayerAssignment
from repro.runtime.rebalance import (drop_devices, join_devices,
                                     measure_speeds, plan_rebalance)


def test_measure_speeds():
    s = measure_speeds([1.0, 2.0, 1.0, 0.5])   # device 3 is 2x fast, 1 slow
    assert s[3] == s.max()
    assert s[1] == s.min()
    assert s.mean() == pytest.approx(1.0)


def test_measure_speeds_guards_unmeasured_devices():
    # a zero step time is "no history", not "infinitely fast": the device
    # gets the median measured rate instead of a division by zero
    s = measure_speeds([1.0, 0.0, 2.0])
    assert np.all(np.isfinite(s)) and np.all(s > 0)
    assert s.mean() == pytest.approx(1.0)
    assert s[0] > s[2]          # measured ordering preserved (rate = 1/t)
    raw = np.array([1.0, np.median([1.0, 0.5]), 0.5])
    np.testing.assert_allclose(s, raw / raw.mean())
    # negative times are equally not measurements
    s2 = measure_speeds([1.0, -3.0, 2.0])
    np.testing.assert_allclose(s2, s)
    # a fleet with no history at all degrades to the even split
    np.testing.assert_allclose(measure_speeds([0.0, 0.0, -1.0]), 1.0)


def test_measure_speeds_rejects_bad_shapes():
    with pytest.raises(ValueError):
        measure_speeds([])
    with pytest.raises(ValueError):
        measure_speeds([[1.0, 2.0]])


def test_plan_rebalance_proportional():
    K = 4096
    plan = plan_rebalance(K, [1.0, 1.0, 2.0, 4.0], quantum=128)
    k = plan.assignment.k
    assert k.sum() == K
    assert np.all(k % 128 == 0)
    assert k[3] > k[2] > k[0]
    assert plan.predicted_speedup > 1.0


def test_plan_rebalance_small_K_falls_back():
    plan = plan_rebalance(16, [1.0, 2.0], quantum=128)
    assert plan.assignment.k.sum() == 16


def test_straggler_gets_less():
    plan = plan_rebalance(2048, [1.0] * 7 + [0.25], quantum=128)
    k = plan.assignment.k
    assert k[-1] <= k[:-1].min()
    assert plan.predicted_speedup > 1.5   # even split is gated by straggler


def test_drop_devices_resolves():
    base = LayerAssignment.even(4096, 8, quantum=128)
    plan = drop_devices(base, dead=[2, 5], speeds=[1.0] * 8, quantum=128)
    assert plan.assignment.p == 6
    assert plan.assignment.K == 4096
    assert np.all(plan.assignment.k % 128 == 0)


def test_join_devices_resolves():
    base = LayerAssignment.even(4096, 4, quantum=128)
    plan = join_devices(base, [4.0], [1.0] * 4, quantum=128)
    k = plan.assignment.k
    assert plan.assignment.p == 5
    assert k.sum() == 4096
    assert np.all(k % 128 == 0)
    assert k[4] == k.max()              # the fast joiner takes the most


def test_join_devices_extends_star_topology():
    from repro.plan import StarTopology
    base = LayerAssignment.even(4096, 4, quantum=128)
    topo = StarTopology.from_speeds([1.0, 1.0, 1.0, 1.0])
    plan = join_devices(base, [2.0, 0.5], [1.0] * 4, quantum=128,
                        topology=topo)
    assert plan.assignment.p == 6
    assert plan.assignment.k.sum() == 4096
    assert plan.plan.topology_kind == "star"
    # joiners inherit the per-device speed view: 2x joiner beats the
    # incumbents, 0.5x joiner trails them
    k = plan.assignment.k
    assert k[4] == k.max() and k[5] == k.min()


def test_join_devices_error_paths():
    base = LayerAssignment.even(1024, 2, quantum=1)
    with pytest.raises(ValueError, match="positive"):
        join_devices(base, [0.0], [1.0, 1.0], quantum=1)
    with pytest.raises(ValueError, match="positive"):
        join_devices(base, [], [1.0, 1.0], quantum=1)
    from repro.plan import production_topology
    hier = production_topology(multi_pod=True, seed=0)
    base512 = LayerAssignment.even(1024, hier.p, quantum=1)
    with pytest.raises(ValueError, match="rebuild"):
        join_devices(base512, [1.0], [1.0] * hier.p, quantum=1,
                     topology=hier)


def test_layer_assignment_invariants():
    a = LayerAssignment.from_speeds(1024, [1, 2, 3, 4], quantum=1)
    assert a.K == 1024
    assert a.offsets[0] == 0
    assert a.offsets[-1] + a.k[-1] == 1024
    assert a.comm_volume == 2 * 1024 * 1024   # Theorem 1
