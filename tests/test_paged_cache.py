"""Paged KV-cache plane: allocator invariants, page-budget admission,
and token-identity of the paged engine against the greedy oracle AND the
slot-pool engine.

Allocator properties (hypothesis, deterministic shim fallback):
  * conservation — pages allocated == pages freed once drained;
  * exclusivity — no physical page is held by two live requests, under
    arbitrary admit/grow/release interleavings (fragmentation);
  * bounded growth — grow-on-decode can never exceed the admission-time
    reservation (preemption-freedom is structural).

The acceptance check runs the 32-request heavy-tailed staggered workload
with a page size small enough that EVERY request spans >= 2 physical
pages with at least one non-contiguous jump — the paged plane must still
be token-identical to per-request ``greedy_generate`` and to the slot
engine.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve import greedy_generate, serve_requests
from repro.serve.engine import (EngineConfig, PagedCachePool,
                                PagedTransformerModel, Request, ServingEngine,
                                SlotCachePool, synthetic_workload)
from repro.sharding.rules import Rules

RULES = Rules.null()


def _req(rid, prompt_len, max_new):
    return Request(rid=rid, prompt=np.arange(1, prompt_len + 1),
                   max_new=max_new)


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------

def test_paged_pool_admit_claim_release_roundtrip():
    pool = PagedCachePool(n_pages=8, page_size=4, n_slots=2,
                          pages_per_slot=4)
    r = _req(0, prompt_len=5, max_new=8)
    assert pool.pages_needed(5, 8) == 3      # 12 tokens / 4 per page
    assert pool.can_admit(r)
    slot = pool.admit(r)
    assert pool.live_pages(0) == (0, 1)      # prefill: ceil(5/4) pages
    assert pool.reserved_pages == 3
    # grow to cover 9 tokens -> third page
    pool.grow_to(0, 9)
    assert pool.live_pages(0) == (0, 1, 2)
    # table row mirrors the claims; tail stays trash
    np.testing.assert_array_equal(
        pool.table[slot], [0, 1, 2, pool.trash_page])
    r.slot = slot
    pool.release(r)
    assert pool.drained and pool.n_allocated == pool.n_freed == 3
    assert np.all(pool.table == pool.trash_page)
    assert pool.page_history[0] == (0, 1, 2)


def test_paged_pool_grow_past_reservation_raises():
    pool = PagedCachePool(n_pages=8, page_size=4, n_slots=2,
                          pages_per_slot=4)
    pool.admit(_req(0, prompt_len=4, max_new=4))   # reserve ceil(7/4) = 2
    pool.grow_to(0, 7)
    with pytest.raises(RuntimeError, match="reservation"):
        pool.grow_to(0, 9)                          # needs a 3rd page


def test_paged_pool_admission_gated_on_pages_not_rows():
    # 2 rows but only enough unreserved pages for one worst-case request
    pool = PagedCachePool(n_pages=4, page_size=4, n_slots=2,
                          pages_per_slot=3)
    a = _req(0, prompt_len=8, max_new=5)            # reserve 3 pages
    assert pool.can_admit(a)
    a.slot = pool.admit(a)
    b = _req(1, prompt_len=8, max_new=5)
    assert not pool.can_admit(b)                    # row free, pages not
    pool.release(a)
    assert pool.can_admit(b)


def test_paged_pool_fragmentation_reuses_freed_pages():
    """Interleaved release/claim fragments the pool: a later request's
    pages span a freed hole plus the tail — non-contiguous — and no page
    is ever aliased.  Freed pages come back LIFO (the free list is a
    stack, not a sorted heap), so the hole is reused before the tail."""
    pool = PagedCachePool(n_pages=8, page_size=2, n_slots=4,
                          pages_per_slot=3)
    a, b, c = (_req(i, prompt_len=4, max_new=1) for i in range(3))
    for r in (a, b, c):
        r.slot = pool.admit(r)                      # a:{0,1} b:{2,3} c:{4,5}
    pool.release(b)                                 # hole at {2,3}
    d = _req(3, prompt_len=2, max_new=5)            # reserve 3, claim 1
    d.slot = pool.admit(d)
    assert pool.live_pages(3) == (2,)     # top of the LIFO stack = b's
    # first page (releases push a request's pages reversed)
    pool.grow_to(3, 3)
    pool.grow_to(3, 5)
    # d spans the freed hole {2,3} then jumps the live c to page 6
    assert pool.live_pages(3) == (2, 3, 6)
    flat = [p for r in (a, c, d) for p in pool.live_pages(r.rid)]
    assert len(flat) == len(set(flat))              # no aliasing


def test_paged_pool_free_pages_are_a_lifo_stack():
    """Pin the allocator discipline: page claims pop the most recently
    freed page first (O(1) stack, no ordering guarantee beyond LIFO),
    and a fresh pool hands out ascending ids.  Page identity is
    interchangeable through the table indirection, so the ONLY contract
    is exclusivity + LIFO reuse — anything asserting globally-lowest-
    first would be over-pinning."""
    pool = PagedCachePool(n_pages=6, page_size=2, n_slots=3,
                          pages_per_slot=3)
    a = _req(0, prompt_len=4, max_new=1)            # claims {0, 1}
    b = _req(1, prompt_len=4, max_new=1)            # claims {2, 3}
    a.slot = pool.admit(a)
    b.slot = pool.admit(b)
    assert pool.live_pages(0) == (0, 1)             # fresh pool: ascending
    assert pool.live_pages(1) == (2, 3)
    pool.release(a)                                 # stack top: 0, then 1
    c = _req(2, prompt_len=6, max_new=1)
    c.slot = pool.admit(c)
    # c reuses a's pages in a's original order, THEN falls through to the
    # untouched tail — LIFO, not lowest-id-first across the whole pool
    assert pool.live_pages(2) == (0, 1, 4)
    assert pool.free_page_count == 1


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), n_pages=st.integers(4, 24),
       page_size=st.integers(1, 5))
def test_paged_pool_conservation_and_exclusivity(seed, n_pages, page_size):
    """Random admit/grow/release interleavings: live pages are always
    exclusive, claims never pass reservations, and the drained pool
    conserves pages."""
    rng = np.random.default_rng(seed)
    pages_per_slot = max(2, n_pages // 2)
    pool = PagedCachePool(n_pages=n_pages, page_size=page_size,
                          n_slots=4, pages_per_slot=pages_per_slot)
    live = {}
    next_rid = 0
    for _ in range(60):
        op = rng.integers(0, 3)
        if op == 0:   # admit
            plen = int(rng.integers(1, 2 * page_size + 1))
            cap = pages_per_slot * page_size - plen
            if cap < 1:
                continue
            mn = int(rng.integers(1, cap + 1))
            r = _req(next_rid, plen, mn)
            if pool.can_admit(r):
                r.slot = pool.admit(r)
                live[next_rid] = r
                next_rid += 1
        elif op == 1 and live:   # grow a random live request one token
            rid = int(rng.choice(list(live)))
            r = live[rid]
            if r.n_generated < r.max_new:
                r.n_generated += 1
                pool.grow_to(rid, r.prompt_len + r.n_generated - 1)
        elif op == 2 and live:   # release a random live request
            rid = int(rng.choice(list(live)))
            pool.release(live.pop(rid))
        # exclusivity + reservation bound at every step
        flat = []
        for rid in live:
            pages = pool.live_pages(rid)
            assert len(pages) <= pool.pages_needed(
                live[rid].prompt_len, live[rid].max_new)
            flat.extend(pages)
        assert len(flat) == len(set(flat)), "page aliased by two requests"
        assert all(0 <= p < n_pages for p in flat)
        # table mirrors the claims
        for rid in live:
            row = pool.table[live[rid].slot]
            claimed = pool.live_pages(rid)
            np.testing.assert_array_equal(row[:len(claimed)], claimed)
            assert np.all(row[len(claimed):] == pool.trash_page)
    for r in list(live.values()):
        pool.release(r)
    assert pool.drained
    assert pool.n_allocated == pool.n_freed
    assert pool.free_page_count == n_pages


# ---------------------------------------------------------------------------
# engine-level scheduling on the paged plane (tensor-light fake)
# ---------------------------------------------------------------------------

class FakePagedModel:
    """The FakeModel dynamics (next = (prev * 31 + pos) % V) behind the
    paged adapter surface — pool tensors unused, so this exercises pure
    scheduling/allocation behaviour."""

    V = 97

    def init_paged_pool(self, pool):
        return {"pages": jnp.zeros((1, pool.n_pages + 1, pool.page_size),
                                   jnp.int32)}

    def token_state(self, n_slots):
        return jnp.zeros(n_slots, jnp.int32), jnp.zeros(n_slots, jnp.int32)

    def first_token(self, prompt):
        return int(np.sum(prompt) % self.V)

    def prefill(self, pool, prompts, slots, tok, pos):
        firsts = []
        for prompt, slot in zip(prompts, slots):
            first = self.first_token(prompt)
            firsts.append(first)
            tok = tok.at[slot].set(first)
            pos = pos.at[slot].set(prompt.shape[0])
        return pool, jnp.asarray(firsts, jnp.int32), tok, pos

    def decode_multi(self, pool, tok, pos, k):
        rows = []
        for _ in range(k):
            tok = (tok * 31 + pos) % self.V
            pos = pos + 1
            rows.append(tok)
        return pool, jnp.stack(rows), tok, pos

    def decode(self, pool, tok, pos):
        pool, rows, tok, pos = self.decode_multi(pool, tok, pos, 1)
        return pool, rows[0], tok, pos

    def oracle(self, prompt, max_new):
        out = [self.first_token(prompt)]
        tok, pos = out[0], prompt.shape[0]
        for _ in range(max_new - 1):
            tok = (tok * 31 + pos) % self.V
            pos += 1
            out.append(tok)
        return np.asarray(out, np.int32)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 16),
       page_size=st.integers(1, 4), budget=st.integers(0, 8))
def test_paged_engine_conservation_and_no_starvation(seed, n, page_size,
                                                     budget):
    """Random workloads against a page-budget-constrained pool: every
    request completes with exactly the fake-oracle tokens, and the pool
    conserves pages at drain — even when the page budget (not the slot
    count) is the binding admission constraint."""
    rng = np.random.default_rng(seed)
    pages_per_slot = -(-18 // page_size)
    ec = EngineConfig(n_slots=3, max_prompt_len=12, max_new_cap=6,
                      cache_len=18, max_prefill_per_step=2,
                      page_size=page_size,
                      n_pages=pages_per_slot + budget)
    eng = ServingEngine(FakePagedModel(), ec)
    want = {}
    for _ in range(n):
        prompt = rng.integers(0, 50, rng.integers(1, 13))
        max_new = int(rng.integers(1, 7))
        arrival = float(rng.integers(0, 8))
        rid = eng.submit(prompt, max_new, arrival=arrival)
        want[rid] = (prompt, max_new)
    rep = eng.run()
    assert set(rep.completed) == set(want)
    assert eng.pool.drained
    assert eng.pool.n_allocated == eng.pool.n_freed
    fake = FakePagedModel()
    for rid, (prompt, max_new) in want.items():
        np.testing.assert_array_equal(
            rep.completed[rid],
            fake.oracle(np.asarray(prompt, np.int32), max_new))
    # every request's final page count stayed within its reservation
    for rid, pages in eng.pool.page_history.items():
        prompt, max_new = want[rid]
        assert len(pages) <= eng.pool.pages_needed(prompt.shape[0], max_new)


def test_paged_engine_page_budget_limits_concurrency():
    """With pages for only one worst-case request, requests serve
    sequentially (admission by page budget) yet all complete."""
    ec = EngineConfig(n_slots=4, max_prompt_len=8, max_new_cap=4,
                      cache_len=12, page_size=4, n_pages=3)
    eng = ServingEngine(FakePagedModel(), ec)
    for i in range(3):
        eng.submit(np.arange(1, 9), 4, arrival=0.0)
    rep = eng.run()
    assert len(rep.completed) == 3
    # one request's reservation (3 pages) fills the pool: occupancy over
    # n_slots=4 can never exceed 1/4
    assert rep.occupancy <= 0.25 + 1e-9


# ---------------------------------------------------------------------------
# oracle identity on the real model (acceptance workload)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_lm():
    cfg = get_reduced("llama3_2_3b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_paged_engine_acceptance_fragmented_oracle_identity(small_lm):
    """THE acceptance check: 32 heavy-tailed staggered requests, page
    size 4 so every request spans >= 2 physical pages with at least one
    non-contiguous jump; paged output must be token-identical to
    per-request greedy_generate AND to the slot engine."""
    cfg, params = small_lm
    workload = synthetic_workload(32, cfg.vocab_size,
                                  lens=(5, 9, 13, 17), news=(6, 12, 16),
                                  stagger=0.5, seed=0)
    max_len = max(p.shape[0] + m for p, m, _ in workload)
    ec = EngineConfig(n_slots=8, max_prompt_len=17, max_new_cap=16,
                      cache_len=max_len, max_prefill_per_step=4,
                      page_size=4)
    eng = ServingEngine(PagedTransformerModel(params, cfg, RULES), ec)
    for p, m, a in workload:
        eng.submit(p, m, arrival=a)
    rep = eng.run()
    assert len(rep.completed) == 32

    slot_rep = serve_requests(params, cfg, RULES, workload, n_slots=8,
                              max_prefill_per_step=4)
    for rid, (prompt, max_new, _) in enumerate(workload):
        ref = np.asarray(greedy_generate(
            params, cfg, RULES, np.asarray(prompt)[None],
            max_new=max_new))[0]
        np.testing.assert_array_equal(rep.completed[rid], ref,
                                      err_msg=f"vs greedy, rid {rid}")
        np.testing.assert_array_equal(rep.completed[rid],
                                      slot_rep.completed[rid],
                                      err_msg=f"vs slot engine, rid {rid}")
    # fragmentation evidence: every request held >= 2 pages and took at
    # least one non-contiguous jump through the physical pool
    assert set(eng.pool.page_history) == set(range(32))
    for rid, pages in eng.pool.page_history.items():
        assert len(pages) >= 2, (rid, pages)
        assert any(b != a + 1 for a, b in zip(pages, pages[1:])), \
            (rid, pages)
    assert eng.pool.drained
    assert eng.pool.n_allocated == eng.pool.n_freed
    assert rep.page_occupancy > 0.0


def test_paged_engine_single_request_exact(small_lm):
    """Degenerate case: one request, page growth across many pages."""
    cfg, params = small_lm
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 13).astype(np.int32)
    ref = np.asarray(greedy_generate(params, cfg, RULES, prompt[None],
                                     max_new=16))[0]
    rep = serve_requests(params, cfg, RULES, [(prompt, 16, 0.0)],
                         n_slots=1, page_size=4)
    np.testing.assert_array_equal(rep.completed[0], ref)


def test_paged_rejects_recurrent_families():
    cfg = get_reduced("recurrentgemma_9b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="paged"):
        PagedTransformerModel(params, cfg, RULES)


def test_paged_engine_requires_paged_adapter(small_lm):
    cfg, params = small_lm
    from repro.serve import TransformerModel
    with pytest.raises(TypeError, match="init_paged_pool"):
        ServingEngine(TransformerModel(params, cfg, RULES),
                      EngineConfig(n_slots=2, page_size=4))


def test_slot_pool_interface_unchanged():
    """The slot pool keeps its direct allocate/free surface AND serves
    the shared admission interface the scheduler uses."""
    pool = SlotCachePool(2)
    r = _req(0, 4, 2)
    assert pool.can_admit(r)
    r.slot = pool.admit(r)
    pool.release(r)
    assert pool.drained and pool.n_allocated == pool.n_freed == 1
