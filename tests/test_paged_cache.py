"""Paged KV-cache plane: allocator invariants, page-budget admission,
and token-identity of the paged engine against the greedy oracle AND the
slot-pool engine.

Allocator properties (hypothesis, deterministic shim fallback):
  * conservation — pages allocated == pages freed once drained;
  * exclusivity — no physical page is held by two live requests, under
    arbitrary admit/grow/release interleavings (fragmentation);
  * bounded growth — grow-on-decode can never exceed the admission-time
    reservation (preemption-freedom is structural).

The acceptance check runs the 32-request heavy-tailed staggered workload
with a page size small enough that EVERY request spans >= 2 physical
pages with at least one non-contiguous jump — the paged plane must still
be token-identical to per-request ``greedy_generate`` and to the slot
engine.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve import greedy_generate, serve_requests
from repro.serve.engine import (EngineConfig, PagedCachePool,
                                PagedTransformerModel, Request, ServingEngine,
                                SlotCachePool, shared_prefix_workload,
                                synthetic_workload)
from repro.sharding.rules import Rules

RULES = Rules.null()


def _req(rid, prompt_len, max_new):
    return Request(rid=rid, prompt=np.arange(1, prompt_len + 1),
                   max_new=max_new)


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------

def test_paged_pool_admit_claim_release_roundtrip():
    pool = PagedCachePool(n_pages=8, page_size=4, n_slots=2,
                          pages_per_slot=4)
    r = _req(0, prompt_len=5, max_new=8)
    assert pool.pages_needed(5, 8) == 3      # 12 tokens / 4 per page
    assert pool.can_admit(r)
    slot = pool.admit(r)
    assert pool.live_pages(0) == (0, 1)      # prefill: ceil(5/4) pages
    assert pool.reserved_pages == 3
    # grow to cover 9 tokens -> third page
    pool.grow_to(0, 9)
    assert pool.live_pages(0) == (0, 1, 2)
    # table row mirrors the claims; tail stays trash
    np.testing.assert_array_equal(
        pool.table[slot], [0, 1, 2, pool.trash_page])
    r.slot = slot
    pool.release(r)
    assert pool.drained and pool.n_allocated == pool.n_freed == 3
    assert np.all(pool.table == pool.trash_page)
    assert pool.page_history[0] == (0, 1, 2)


def test_paged_pool_grow_past_reservation_raises():
    pool = PagedCachePool(n_pages=8, page_size=4, n_slots=2,
                          pages_per_slot=4)
    pool.admit(_req(0, prompt_len=4, max_new=4))   # reserve ceil(7/4) = 2
    pool.grow_to(0, 7)
    with pytest.raises(RuntimeError, match="reservation"):
        pool.grow_to(0, 9)                          # needs a 3rd page


def test_paged_pool_admission_gated_on_pages_not_rows():
    # 2 rows but only enough unreserved pages for one worst-case request
    pool = PagedCachePool(n_pages=4, page_size=4, n_slots=2,
                          pages_per_slot=3)
    a = _req(0, prompt_len=8, max_new=5)            # reserve 3 pages
    assert pool.can_admit(a)
    a.slot = pool.admit(a)
    b = _req(1, prompt_len=8, max_new=5)
    assert not pool.can_admit(b)                    # row free, pages not
    pool.release(a)
    assert pool.can_admit(b)


def test_paged_pool_fragmentation_reuses_freed_pages():
    """Interleaved release/claim fragments the pool: a later request's
    pages span a freed hole plus the tail — non-contiguous — and no page
    is ever aliased.  Freed pages come back LIFO (the free list is a
    stack, not a sorted heap), so the hole is reused before the tail."""
    pool = PagedCachePool(n_pages=8, page_size=2, n_slots=4,
                          pages_per_slot=3)
    a, b, c = (_req(i, prompt_len=4, max_new=1) for i in range(3))
    for r in (a, b, c):
        r.slot = pool.admit(r)                      # a:{0,1} b:{2,3} c:{4,5}
    pool.release(b)                                 # hole at {2,3}
    d = _req(3, prompt_len=2, max_new=5)            # reserve 3, claim 1
    d.slot = pool.admit(d)
    assert pool.live_pages(3) == (2,)     # top of the LIFO stack = b's
    # first page (releases push a request's pages reversed)
    pool.grow_to(3, 3)
    pool.grow_to(3, 5)
    # d spans the freed hole {2,3} then jumps the live c to page 6
    assert pool.live_pages(3) == (2, 3, 6)
    flat = [p for r in (a, c, d) for p in pool.live_pages(r.rid)]
    assert len(flat) == len(set(flat))              # no aliasing


def test_paged_pool_free_pages_are_a_lifo_stack():
    """Pin the allocator discipline: page claims pop the most recently
    freed page first (O(1) stack, no ordering guarantee beyond LIFO),
    and a fresh pool hands out ascending ids.  Page identity is
    interchangeable through the table indirection, so the ONLY contract
    is exclusivity + LIFO reuse — anything asserting globally-lowest-
    first would be over-pinning."""
    pool = PagedCachePool(n_pages=6, page_size=2, n_slots=3,
                          pages_per_slot=3)
    a = _req(0, prompt_len=4, max_new=1)            # claims {0, 1}
    b = _req(1, prompt_len=4, max_new=1)            # claims {2, 3}
    a.slot = pool.admit(a)
    b.slot = pool.admit(b)
    assert pool.live_pages(0) == (0, 1)             # fresh pool: ascending
    assert pool.live_pages(1) == (2, 3)
    pool.release(a)                                 # stack top: 0, then 1
    c = _req(2, prompt_len=6, max_new=1)
    c.slot = pool.admit(c)
    # c reuses a's pages in a's original order, THEN falls through to the
    # untouched tail — LIFO, not lowest-id-first across the whole pool
    assert pool.live_pages(2) == (0, 1, 4)
    assert pool.free_page_count == 1


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), n_pages=st.integers(4, 24),
       page_size=st.integers(1, 5))
def test_paged_pool_conservation_and_exclusivity(seed, n_pages, page_size):
    """Random admit/grow/release interleavings: live pages are always
    exclusive, claims never pass reservations, and the drained pool
    conserves pages."""
    rng = np.random.default_rng(seed)
    pages_per_slot = max(2, n_pages // 2)
    pool = PagedCachePool(n_pages=n_pages, page_size=page_size,
                          n_slots=4, pages_per_slot=pages_per_slot)
    live = {}
    next_rid = 0
    for _ in range(60):
        op = rng.integers(0, 3)
        if op == 0:   # admit
            plen = int(rng.integers(1, 2 * page_size + 1))
            cap = pages_per_slot * page_size - plen
            if cap < 1:
                continue
            mn = int(rng.integers(1, cap + 1))
            r = _req(next_rid, plen, mn)
            if pool.can_admit(r):
                r.slot = pool.admit(r)
                live[next_rid] = r
                next_rid += 1
        elif op == 1 and live:   # grow a random live request one token
            rid = int(rng.choice(list(live)))
            r = live[rid]
            if r.n_generated < r.max_new:
                r.n_generated += 1
                pool.grow_to(rid, r.prompt_len + r.n_generated - 1)
        elif op == 2 and live:   # release a random live request
            rid = int(rng.choice(list(live)))
            pool.release(live.pop(rid))
        # exclusivity + reservation bound at every step
        flat = []
        for rid in live:
            pages = pool.live_pages(rid)
            assert len(pages) <= pool.pages_needed(
                live[rid].prompt_len, live[rid].max_new)
            flat.extend(pages)
        assert len(flat) == len(set(flat)), "page aliased by two requests"
        assert all(0 <= p < n_pages for p in flat)
        # table mirrors the claims
        for rid in live:
            row = pool.table[live[rid].slot]
            claimed = pool.live_pages(rid)
            np.testing.assert_array_equal(row[:len(claimed)], claimed)
            assert np.all(row[len(claimed):] == pool.trash_page)
    for r in list(live.values()):
        pool.release(r)
    assert pool.drained
    assert pool.n_allocated == pool.n_freed
    assert pool.free_page_count == n_pages


# ---------------------------------------------------------------------------
# engine-level scheduling on the paged plane (tensor-light fake)
# ---------------------------------------------------------------------------

class FakePagedModel:
    """The FakeModel dynamics (next = (prev * 31 + pos) % V) behind the
    paged adapter surface — pool tensors unused, so this exercises pure
    scheduling/allocation behaviour."""

    V = 97

    def init_paged_pool(self, pool):
        return {"pages": jnp.zeros((1, pool.n_pages + 1, pool.page_size),
                                   jnp.int32)}

    def token_state(self, n_slots):
        return jnp.zeros(n_slots, jnp.int32), jnp.zeros(n_slots, jnp.int32)

    def first_token(self, prompt):
        return int(np.sum(prompt) % self.V)

    def prefill(self, pool, prompts, slots, tok, pos):
        firsts = []
        for prompt, slot in zip(prompts, slots):
            first = self.first_token(prompt)
            firsts.append(first)
            tok = tok.at[slot].set(first)
            pos = pos.at[slot].set(prompt.shape[0])
        return pool, jnp.asarray(firsts, jnp.int32), tok, pos

    def decode_multi(self, pool, tok, pos, k):
        rows = []
        for _ in range(k):
            tok = (tok * 31 + pos) % self.V
            pos = pos + 1
            rows.append(tok)
        return pool, jnp.stack(rows), tok, pos

    def decode(self, pool, tok, pos):
        pool, rows, tok, pos = self.decode_multi(pool, tok, pos, 1)
        return pool, rows[0], tok, pos

    def oracle(self, prompt, max_new):
        out = [self.first_token(prompt)]
        tok, pos = out[0], prompt.shape[0]
        for _ in range(max_new - 1):
            tok = (tok * 31 + pos) % self.V
            pos += 1
            out.append(tok)
        return np.asarray(out, np.int32)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 16),
       page_size=st.integers(1, 4), budget=st.integers(0, 8))
def test_paged_engine_conservation_and_no_starvation(seed, n, page_size,
                                                     budget):
    """Random workloads against a page-budget-constrained pool: every
    request completes with exactly the fake-oracle tokens, and the pool
    conserves pages at drain — even when the page budget (not the slot
    count) is the binding admission constraint."""
    rng = np.random.default_rng(seed)
    pages_per_slot = -(-18 // page_size)
    ec = EngineConfig(n_slots=3, max_prompt_len=12, max_new_cap=6,
                      cache_len=18, max_prefill_per_step=2,
                      page_size=page_size,
                      n_pages=pages_per_slot + budget)
    eng = ServingEngine(FakePagedModel(), ec)
    want = {}
    for _ in range(n):
        prompt = rng.integers(0, 50, rng.integers(1, 13))
        max_new = int(rng.integers(1, 7))
        arrival = float(rng.integers(0, 8))
        rid = eng.submit(prompt, max_new, arrival=arrival)
        want[rid] = (prompt, max_new)
    rep = eng.run()
    assert set(rep.completed) == set(want)
    assert eng.pool.drained
    assert eng.pool.n_allocated == eng.pool.n_freed
    fake = FakePagedModel()
    for rid, (prompt, max_new) in want.items():
        np.testing.assert_array_equal(
            rep.completed[rid],
            fake.oracle(np.asarray(prompt, np.int32), max_new))
    # every request's final page count stayed within its reservation
    for rid, pages in eng.pool.page_history.items():
        prompt, max_new = want[rid]
        assert len(pages) <= eng.pool.pages_needed(prompt.shape[0], max_new)


def test_paged_engine_page_budget_limits_concurrency():
    """With pages for only one worst-case request, requests serve
    sequentially (admission by page budget) yet all complete."""
    ec = EngineConfig(n_slots=4, max_prompt_len=8, max_new_cap=4,
                      cache_len=12, page_size=4, n_pages=3)
    eng = ServingEngine(FakePagedModel(), ec)
    for i in range(3):
        eng.submit(np.arange(1, 9), 4, arrival=0.0)
    rep = eng.run()
    assert len(rep.completed) == 3
    # one request's reservation (3 pages) fills the pool: occupancy over
    # n_slots=4 can never exceed 1/4
    assert rep.occupancy <= 0.25 + 1e-9


# ---------------------------------------------------------------------------
# oracle identity on the real model (acceptance workload)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_lm():
    cfg = get_reduced("llama3_2_3b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_paged_engine_acceptance_fragmented_oracle_identity(small_lm):
    """THE acceptance check: 32 heavy-tailed staggered requests, page
    size 4 so every request spans >= 2 physical pages with at least one
    non-contiguous jump; paged output must be token-identical to
    per-request greedy_generate AND to the slot engine."""
    cfg, params = small_lm
    workload = synthetic_workload(32, cfg.vocab_size,
                                  lens=(5, 9, 13, 17), news=(6, 12, 16),
                                  stagger=0.5, seed=0)
    max_len = max(p.shape[0] + m for p, m, _ in workload)
    ec = EngineConfig(n_slots=8, max_prompt_len=17, max_new_cap=16,
                      cache_len=max_len, max_prefill_per_step=4,
                      page_size=4)
    eng = ServingEngine(PagedTransformerModel(params, cfg, RULES), ec)
    for p, m, a in workload:
        eng.submit(p, m, arrival=a)
    rep = eng.run()
    assert len(rep.completed) == 32

    slot_rep = serve_requests(params, cfg, RULES, workload, n_slots=8,
                              max_prefill_per_step=4)
    for rid, (prompt, max_new, _) in enumerate(workload):
        ref = np.asarray(greedy_generate(
            params, cfg, RULES, np.asarray(prompt)[None],
            max_new=max_new))[0]
        np.testing.assert_array_equal(rep.completed[rid], ref,
                                      err_msg=f"vs greedy, rid {rid}")
        np.testing.assert_array_equal(rep.completed[rid],
                                      slot_rep.completed[rid],
                                      err_msg=f"vs slot engine, rid {rid}")
    # fragmentation evidence: every request held >= 2 pages and took at
    # least one non-contiguous jump through the physical pool
    assert set(eng.pool.page_history) == set(range(32))
    for rid, pages in eng.pool.page_history.items():
        assert len(pages) >= 2, (rid, pages)
        assert any(b != a + 1 for a, b in zip(pages, pages[1:])), \
            (rid, pages)
    assert eng.pool.drained
    assert eng.pool.n_allocated == eng.pool.n_freed
    assert rep.page_occupancy > 0.0


def test_paged_engine_single_request_exact(small_lm):
    """Degenerate case: one request, page growth across many pages."""
    cfg, params = small_lm
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 13).astype(np.int32)
    ref = np.asarray(greedy_generate(params, cfg, RULES, prompt[None],
                                     max_new=16))[0]
    rep = serve_requests(params, cfg, RULES, [(prompt, 16, 0.0)],
                         n_slots=1, page_size=4)
    np.testing.assert_array_equal(rep.completed[0], ref)


def test_paged_rejects_recurrent_families():
    cfg = get_reduced("recurrentgemma_9b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="paged"):
        PagedTransformerModel(params, cfg, RULES)


def test_paged_engine_requires_paged_adapter(small_lm):
    cfg, params = small_lm
    from repro.serve import TransformerModel
    with pytest.raises(TypeError, match="init_paged_pool"):
        ServingEngine(TransformerModel(params, cfg, RULES),
                      EngineConfig(n_slots=2, page_size=4))


def test_slot_pool_interface_unchanged():
    """The slot pool keeps its direct allocate/free surface AND serves
    the shared admission interface the scheduler uses."""
    pool = SlotCachePool(2)
    r = _req(0, 4, 2)
    assert pool.can_admit(r)
    r.slot = pool.admit(r)
    pool.release(r)
    assert pool.drained and pool.n_allocated == pool.n_freed == 1


# ---------------------------------------------------------------------------
# prefix sharing + copy-on-write
# ---------------------------------------------------------------------------

TPL = np.arange(100, 108, dtype=np.int32)       # two FULL pages at size 4


def _tpl_req(rid, suffix, max_new, template=TPL):
    return Request(rid=rid,
                   prompt=np.concatenate(
                       [template, np.asarray(suffix, np.int32)]),
                   max_new=max_new)


def _shared_pool(**kw):
    args = dict(n_pages=12, page_size=4, n_slots=4, pages_per_slot=4,
                share_prefixes=True)
    args.update(kw)
    return PagedCachePool(**args)


def test_prefix_follower_attaches_after_seal():
    """Creator claims + registers; after seal_prefilled a same-template
    follower attaches to the creator's pages (refcount 2) and reserves
    only its private tail — the shared + private admission math."""
    pool = _shared_pool()
    a = _tpl_req(0, [1, 2], max_new=3)          # 10 prompt tokens, 3 pages
    a.slot = pool.admit(a)
    assert pool.live_pages(0) == (0, 1, 2)
    assert pool.reserved_pages == 3
    pool.seal_prefilled([a])                    # prefill dispatch landed
    b = _tpl_req(1, [3, 4], max_new=3)
    assert pool.can_admit(b)
    b.slot = pool.admit(b)
    assert pool.shared_pages(1) == (0, 1)       # attached, not copied
    assert pool.refcount(0) == pool.refcount(1) == 2
    assert pool.refcount(2) == 1                # a's partial page: private
    assert pool.live_pages(1) == (0, 1, 3)      # CoW: own partial page
    assert pool.reserved_pages == 4             # 4 claimed + 0 future
    assert pool.n_shared_attached == 2 and pool.max_refcount == 2
    # the creator can retire first: pages survive for the follower
    pool.release(a)
    assert pool.refcount(0) == pool.refcount(1) == 1
    assert pool.refcount(2) == 0                # freed with a
    pool.release(b)
    assert pool.drained and pool.n_allocated == pool.n_freed == 4
    assert len(pool.prefix_index) == 0          # evicted at refcount zero


def test_prefix_cow_write_table_masks_shared_pages():
    """No request ever writes a page with refcount > 1: attached pages
    AND sealed creator pages are the trash page in write_table, while
    the read table still maps them — the page-granular copy-on-write."""
    pool = _shared_pool()
    a = _tpl_req(0, [1, 2], max_new=3)
    a.slot = pool.admit(a)
    # before seal the creator's own prefill must be able to write them
    np.testing.assert_array_equal(pool.write_table[a.slot, :3], [0, 1, 2])
    pool.seal_prefilled([a])
    np.testing.assert_array_equal(
        pool.write_table[a.slot], [pool.trash_page, pool.trash_page, 2,
                                   pool.trash_page])
    b = _tpl_req(1, [3, 4], max_new=3)
    b.slot = pool.admit(b)
    np.testing.assert_array_equal(
        pool.write_table[b.slot], [pool.trash_page, pool.trash_page, 3,
                                   pool.trash_page])
    np.testing.assert_array_equal(pool.table[b.slot, :3], [0, 1, 3])
    # global exclusivity: every non-trash write entry appears exactly once
    writable = pool.write_table[pool.write_table != pool.trash_page]
    assert len(writable) == len(set(writable.tolist()))
    for page in set(writable.tolist()):
        assert pool.refcount(page) == 1


def test_prefix_same_step_co_admits_stay_private():
    """Two creators of one template admitted BEFORE any seal: the second
    register loses and claims private copies — nobody attaches to an
    unwritten page (materialize-after-prefill ordering)."""
    pool = _shared_pool(n_pages=16)
    a = _tpl_req(0, [1, 2], max_new=3)
    b = _tpl_req(1, [3, 4], max_new=3)
    a.slot = pool.admit(a)
    b.slot = pool.admit(b)                      # same step: no seal yet
    assert pool.shared_pages(0) == pool.shared_pages(1) == ()
    assert all(pool.refcount(p) == 1
               for p in pool.live_pages(0) + pool.live_pages(1))
    pool.seal_prefilled([a, b])                 # only a's keys indexed
    c = _tpl_req(2, [5, 6], max_new=3)
    c.slot = pool.admit(c)
    assert pool.shared_pages(2) == pool.live_pages(0)[:2]
    pool.release(a)                             # c still holds a's pages
    pool.release(b)
    pool.release(c)
    assert pool.drained and pool.n_allocated == pool.n_freed


def test_prefix_sharing_off_is_bitwise_private():
    """share_prefixes=False: write_table always equals table and every
    page has refcount 1 — the old plane, bit for bit."""
    pool = PagedCachePool(n_pages=12, page_size=4, n_slots=4,
                          pages_per_slot=4)
    a = _tpl_req(0, [1, 2], max_new=3)
    a.slot = pool.admit(a)
    pool.seal_prefilled([a])                    # engine calls it anyway
    b = _tpl_req(1, [3, 4], max_new=3)
    b.slot = pool.admit(b)
    np.testing.assert_array_equal(pool.table, pool.write_table)
    assert pool.n_shared_attached == 0
    assert pool.reserved_pages == 6             # full private worst case


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), n_pages=st.integers(8, 24),
       page_size=st.integers(1, 4))
def test_prefix_refcount_conservation_and_cow_exclusivity(seed, n_pages,
                                                          page_size):
    """Mixed shared/private churn with delayed seals: refcounts always
    equal the live holder count, no page with refcount > 1 is ever
    writable anywhere, non-trash write entries stay globally exclusive,
    and the drained pool conserves pages with an empty index (every
    shared page's refcount hit zero)."""
    rng = np.random.default_rng(seed)
    pages_per_slot = max(3, n_pages // 2)
    pool = PagedCachePool(n_pages=n_pages, page_size=page_size, n_slots=4,
                          pages_per_slot=pages_per_slot,
                          share_prefixes=True)
    templates = [rng.integers(0, 50, 2 * page_size),
                 rng.integers(0, 50, page_size)]
    live, pending, next_rid = {}, [], 0
    for _ in range(80):
        op = int(rng.integers(0, 4))
        if op == 0:   # admit: template-headed (shared) or random private
            if rng.random() < 0.6:
                t = templates[int(rng.integers(0, len(templates)))]
                sfx = rng.integers(0, 50, int(rng.integers(1,
                                                           page_size + 1)))
                prompt = np.concatenate([t, sfx]).astype(np.int32)
            else:
                prompt = rng.integers(
                    0, 50, int(rng.integers(1, 2 * page_size + 1))
                ).astype(np.int32)
            cap = pages_per_slot * page_size - prompt.shape[0]
            if cap < 1:
                continue
            r = Request(rid=next_rid, prompt=prompt,
                        max_new=int(rng.integers(1, cap + 1)))
            if pool.can_admit(r):
                r.slot = pool.admit(r)
                live[next_rid] = r
                pending.append(r)
                next_rid += 1
        elif op == 1 and pending:   # the prefill dispatch lands
            pool.seal_prefilled(pending)
            pending = []
        elif op == 2 and live:      # grow a live request one token
            rid = int(rng.choice(list(live)))
            r = live[rid]
            if r.n_generated < r.max_new:
                r.n_generated += 1
                pool.grow_to(rid, r.prompt_len + r.n_generated - 1)
        elif op == 3 and live:      # release (kill/retire, maybe unsealed)
            rid = int(rng.choice(list(live)))
            r = live.pop(rid)
            pool.release(r)
            pending = [p for p in pending if p.rid != rid]
        # --- invariants at every step --------------------------------
        holders = {}
        for rid, r in live.items():
            for p in pool.live_pages(rid):
                holders[p] = holders.get(p, 0) + 1
        for p, n in holders.items():
            assert pool.refcount(p) == n, (p, n)
        writable = pool.write_table[pool.write_table != pool.trash_page]
        assert len(writable) == len(set(writable.tolist()))
        for p in set(writable.tolist()):
            assert pool.refcount(p) == 1, "writable page is shared"
        for rid, r in live.items():
            row = pool.table[r.slot]
            claimed = pool.live_pages(rid)
            np.testing.assert_array_equal(row[:len(claimed)], claimed)
            assert np.all(row[len(claimed):] == pool.trash_page)
    for r in list(live.values()):
        pool.release(r)
    assert pool.drained
    assert pool.n_allocated == pool.n_freed
    assert pool.free_page_count == n_pages
    assert len(pool.prefix_index) == 0
    assert all(pool.refcount(p) == 0 for p in range(n_pages))


def test_prefix_sharing_fake_engine_scheduling():
    """Engine loop with sharing on, tensor-free fake: oracle tokens,
    conservation at drain, and real attach evidence (the fake's decode
    never touches pages, so this isolates scheduling + allocation)."""
    ec = EngineConfig(n_slots=3, max_prompt_len=12, max_new_cap=6,
                      cache_len=18, max_prefill_per_step=2, page_size=4,
                      n_pages=8, prefix_sharing=True)
    eng = ServingEngine(FakePagedModel(), ec)
    tpl = np.arange(60, 68)                      # two full pages
    want = {}
    rng = np.random.default_rng(3)
    for i in range(12):
        prompt = np.concatenate([tpl, rng.integers(0, 50, 1 + i % 3)])
        rid = eng.submit(prompt, 2 + i % 4, arrival=float(i % 5))
        want[rid] = (prompt.astype(np.int32), 2 + i % 4)
    rep = eng.run()
    assert set(rep.completed) == set(want)
    fake = FakePagedModel()
    for rid, (prompt, max_new) in want.items():
        np.testing.assert_array_equal(rep.completed[rid],
                                      fake.oracle(prompt, max_new))
    assert eng.pool.drained
    assert eng.pool.n_allocated == eng.pool.n_freed
    assert eng.pool.n_shared_attached > 0 and eng.pool.max_refcount > 1


def test_prefix_sharing_requires_paged_plane():
    with pytest.raises(ValueError, match="prefix_sharing"):
        ServingEngine(FakePagedModel(),
                      EngineConfig(n_slots=2, prefix_sharing=True))


def test_prefix_sharing_acceptance_oracle_identity(small_lm):
    """THE sharing acceptance check: 32 requests over 4 shared templates;
    the sharing engine is token-identical to greedy_generate AND to the
    non-sharing paged engine, while peak pages-in-use stays strictly
    below the private-reservation baseline."""
    cfg, params = small_lm
    wl = shared_prefix_workload(32, cfg.vocab_size, n_templates=4,
                                template_len=16, suffix_lens=(4, 8, 12),
                                news=(6, 12, 16), stagger=0.5, seed=0)
    max_len = max(p.shape[0] + m for p, m, _ in wl)

    def run(sharing):
        ec = EngineConfig(n_slots=8, max_prompt_len=28, max_new_cap=16,
                          cache_len=max_len, max_prefill_per_step=4,
                          page_size=4, prefix_sharing=sharing)
        eng = ServingEngine(PagedTransformerModel(params, cfg, RULES), ec)
        for p, m, a in wl:
            eng.submit(p, m, arrival=a)
        return eng, eng.run()

    eng_off, rep_off = run(False)
    eng_on, rep_on = run(True)
    assert len(rep_on.completed) == 32
    for rid, (prompt, max_new, _) in enumerate(wl):
        ref = np.asarray(greedy_generate(
            params, cfg, RULES, np.asarray(prompt)[None],
            max_new=max_new))[0]
        np.testing.assert_array_equal(rep_on.completed[rid], ref,
                                      err_msg=f"vs greedy, rid {rid}")
        np.testing.assert_array_equal(rep_on.completed[rid],
                                      rep_off.completed[rid],
                                      err_msg=f"vs non-sharing, rid {rid}")
    # capacity evidence: sharing held strictly fewer pages at peak, with
    # real attaches, and still conserved everything at drain
    assert eng_on.pool.peak_used_pages < eng_off.pool.peak_used_pages
    assert eng_on.pool.n_shared_attached > 0
    assert eng_on.pool.max_refcount > 1
    assert eng_on.pool.drained
    assert eng_on.pool.n_allocated == eng_on.pool.n_freed
    assert len(eng_on.pool.prefix_index) == 0


def test_prefix_sharing_fleet_kill_requeue_oracle(small_lm):
    """Sharing survives the fault domain: a 2-replica sharing fleet with
    one replica killed mid-flight requeues its work onto the survivor,
    which re-matches or re-creates the shared pages — outputs stay
    token-identical to greedy_generate."""
    from repro.fleet import FaultPlan, FleetController, FleetFrontend, \
        Replica
    cfg, params = small_lm
    rules = RULES
    wl = shared_prefix_workload(16, cfg.vocab_size, n_templates=2,
                                template_len=12, suffix_lens=(4, 8),
                                news=(3, 6, 9), stagger=0.5, seed=1)
    max_len = max(p.shape[0] + m for p, m, _ in wl)
    ec = EngineConfig(n_slots=4, max_prompt_len=20, max_new_cap=9,
                      cache_len=max_len, max_prefill_per_step=2,
                      page_size=4, prefix_sharing=True)
    # the paged adapter binds its page pool: one instance per replica
    reps = [Replica("r0", PagedTransformerModel(params, cfg, rules), ec,
                    rate=1.0, fault=FaultPlan(kill_at=4)),
            Replica("r1", PagedTransformerModel(params, cfg, rules), ec,
                    rate=2.0)]
    ctrl = FleetController(reps, miss_threshold=3)
    fe = FleetFrontend(ctrl, max_pending=8)
    report = fe.serve(wl)
    assert report.n_completed == 16
    assert [n for _, n in report.kills] == ["r0"]
    assert report.requeues >= 1, "the kill must have caught work in flight"
    for rid, (prompt, max_new, _) in enumerate(wl):
        ref = np.asarray(greedy_generate(
            params, cfg, rules, np.asarray(prompt)[None],
            max_new=max_new))[0]
        np.testing.assert_array_equal(report.completed[rid], ref,
                                      err_msg=f"rid {rid}")
    # the survivor actually shared (requeued + native traffic both hit
    # its index); the dead pool is abandoned whole, never drained
    assert ctrl.replicas["r1"].engine.pool.n_shared_attached > 0
