"""Paper §3 + §6.1: rectangular baselines, bounds, Theorem 1 / Lemma 2."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.network import random_star
from repro.core.rect_partition import (even_col, lbp_volume, nrrp, peri_sum,
                                       rect_lower_bound_volume, recursive,
                                       speed_proportional_areas,
                                       star_finish_time)


def _areas(seed, p):
    rng = np.random.default_rng(seed)
    f = rng.uniform(0.5, 2.0, p)
    return f / f.sum()


@pytest.mark.parametrize("algo", [peri_sum, recursive, nrrp])
@pytest.mark.parametrize("seed,p", [(0, 4), (1, 16), (2, 9), (3, 25)])
def test_area_conservation(algo, seed, p):
    f = _areas(seed, p)
    part = algo(f)
    got = part.areas(p)
    assert np.allclose(np.sort(got), np.sort(f), atol=1e-9)
    assert got.sum() == pytest.approx(1.0)


def test_even_col_cost():
    p = 16
    part = even_col(p)
    assert part.cost_unit() == pytest.approx(p * (1.0 / p) + p * 1.0)


@pytest.mark.parametrize("seed,p", [(0, 16), (5, 8), (9, 25)])
def test_rect_beats_nothing_below_lower_bound(seed, p):
    """Lemma 2: every rectangular partition exceeds the global 2N^2 bound;
    and each algo respects its approximation guarantee vs the rect LB."""
    f = _areas(seed, p)
    N = 1000
    lb = rect_lower_bound_volume(f, N)
    lbp = lbp_volume(N)
    assert lbp < lb   # Lemma 2: 2N^2 < 2N sum(sqrt(s_i)) for p > 1
    for algo, ratio in [(peri_sum, 1.75), (recursive, 1.35), (nrrp, 1.35)]:
        v = algo(f).comm_volume(N)
        assert v >= lb - 1e-6, algo.__name__
        assert v <= ratio * lb + 1e-6, algo.__name__


def test_nrrp_no_worse_than_recursive():
    for seed in range(6):
        f = _areas(seed, 2)  # square-corner case is a 2-proc leaf
        assert nrrp(f).cost_unit() <= recursive(f).cost_unit() + 1e-9


def test_square_corner_beats_guillotine_when_skewed():
    """DeFlumere: one small processor -> corner square wins."""
    f = np.array([0.95, 0.05])
    v_n = nrrp(f).cost_unit()
    v_r = recursive(f).cost_unit()
    assert v_n < v_r
    # cost = (w+h of square) + (full rows+cols) = 2*sqrt(0.05) + 2
    assert v_n == pytest.approx(2 * np.sqrt(0.05) + 2.0, rel=1e-9)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), p=st.integers(2, 32))
def test_property_lemma2(seed, p):
    """C_REC > 2 N^2 for every algorithm and every area vector (p > 1)."""
    f = _areas(seed, p)
    for algo in (peri_sum, recursive, nrrp):
        assert algo(f).cost_unit() > 2.0


def test_star_finish_time_balance():
    """Speed-proportional areas balance rect finish times vs Even-Col."""
    net = random_star(16, seed=4)
    N = 500
    f = speed_proportional_areas(net)
    t_bal = star_finish_time(peri_sum(f), net, N)
    t_even = star_finish_time(even_col(16), net, N)
    assert t_bal < t_even
