"""End-to-end system behaviour: training loop, checkpoint/restart, failure
recovery, data pipeline determinism."""

import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import SyntheticTokens
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.sharding.rules import Rules


def _trainer(tmp_path, **over):
    cfg = get_reduced("llama3_2_3b")
    kw = dict(total_steps=8, checkpoint_every=3,
              checkpoint_dir=str(tmp_path / "ckpt"), grad_accum=1)
    kw.update(over)
    return Trainer(cfg, Rules.null(), TrainerConfig(**kw),
                   batch_size=4, seq_len=32)


def test_loss_decreases(tmp_path):
    tr = _trainer(tmp_path, total_steps=12)
    hist = tr.run()
    assert len(hist) == 12
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first


def test_failure_recovery_bitwise_identical(tmp_path):
    """A simulated device fault + restart reproduces the uninterrupted
    trajectory exactly (checkpoint + random-access data pipeline)."""
    clean = _trainer(tmp_path / "a").run()
    faulty = _trainer(tmp_path / "b", inject_failure_at=5).run()
    assert len(faulty) >= len(clean)
    clean_by_step = {h["step"]: h["loss"] for h in clean}
    # after recovery the re-run steps must match bit-for-bit
    for h in faulty:
        assert h["loss"] == pytest.approx(clean_by_step[h["step"]],
                                          rel=0, abs=0), h["step"]


def test_resume_from_checkpoint_continues(tmp_path):
    t1 = _trainer(tmp_path, total_steps=6)
    t1.run()
    # second trainer resumes from step 6 checkpoint and finishes to 10
    t2 = _trainer(tmp_path, total_steps=10)
    hist = t2.run()
    steps = [h["step"] for h in hist]
    assert steps[0] == 6 and steps[-1] == 9


def test_pipeline_determinism_and_host_sharding():
    ds = SyntheticTokens(vocab_size=64, global_batch=8, seq_len=16, seed=3)
    a = ds.batch_at(5)
    b = ds.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host sharding partitions rows
    h0 = ds.batch_at(5, host_id=0, n_hosts=2)
    h1 = ds.batch_at(5, host_id=1, n_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_pipeline_learnable_structure():
    """The affine-bigram stream must be predictable above chance."""
    ds = SyntheticTokens(vocab_size=64, global_batch=4, seq_len=256, seed=0,
                         noise=0.1)
    x = ds.batch_at(0)["tokens"]
    a, b = 3 + 2 * (0 % 5), 17
    pred = (a * x[:, :-1] + b) % 64
    acc = float(np.mean(pred == x[:, 1:]))
    assert acc > 0.8   # 1 - noise - collisions
