"""Serving throughput: continuous-batching engine vs the fixed-batch path.

  PYTHONPATH=src python -m benchmarks.serve [--smoke] [--out BENCH_serve.json]

Workload: staggered-arrival requests with mixed prompt/max-new lengths on
the reduced llama3_2_3b config.  The baseline is the pre-engine serving
path — fixed batches of ``slots`` requests, every prompt right-padded to
the longest and every request decoded for the longest max-new in the
workload (that is what a single fixed-shape batch costs).  Both sides are
timed after a warmup pass so jit compilation is excluded; throughput
counts *useful* tokens only (each request's own max_new) on both sides.
(The baseline's actual padded outputs are NOT the per-request greedy
tokens — short rows condition on pad KV, and logits are read at the
common padded last position — but it performs exactly the tensor work a
fixed-shape batch must, which is what the wall-clock comparison
measures; token correctness is the engine's tested property.)

Emits ``BENCH_serve.json``: tokens/sec, batch occupancy, time-to-first-
token for the perf trajectory (CI runs ``--smoke``), plus the
``paged_vs_slot`` section — the paged KV plane timed against the slot
plane on the same workload, with token-identity and fragmentation
evidence (requests spanning non-contiguous pages) as structural gates
for ``benchmarks/check_regression.py`` — and the ``fleet`` section: the
same workload through a 3-replica heterogeneous fleet with one replica
killed mid-decode and one joining later, checked token-identical to the
single engine (requeue counts and per-replica occupancy recorded).
``fleet.chaos`` is the fault-domain smoke: the same workload through a
fixed-seed COMPOSITE fault schedule (kill x transient x contention x
torn-shard x join) with retry/backoff and live checkpoint-recovery on,
reduced to structural verdicts (recoveries == injected transients,
restores == rescales, token identity, zero silent drops) that
``check_regression.py`` gates.  ``prefix_sharing`` is the shared-prefix
capacity smoke: the shared-template workload on the paged plane with
and without ``prefix_sharing``, gated on token identity (vs the private
plane and the greedy oracle), peak pages-in-use strictly below the
private baseline, observed refcounted attaches, and conservation at
drain (see ``docs/serving.md``).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time
from typing import Dict, List, Tuple

import numpy as np

# default artifact location: the repository root, so the perf trajectory
# is tracked across PRs instead of vanishing into /tmp or CI workspaces
DEFAULT_OUT = str(pathlib.Path(__file__).resolve().parents[1]
                  / "BENCH_serve.json")

PROMPT_LENS = (8, 16, 32, 64)
MAX_NEWS = (2, 4, 8, 32)    # heavy-tailed output lengths: the fixed batch
                            # decodes the max for every request, the engine
                            # retires each request at its own length


def make_workload(n: int, seed: int, vocab: int,
                  prompt_lens=PROMPT_LENS, max_news=MAX_NEWS,
                  stagger: float = 0.5
                  ) -> List[Tuple[np.ndarray, int, float]]:
    from repro.serve.engine import synthetic_workload
    return synthetic_workload(n, vocab, lens=prompt_lens, news=max_news,
                              stagger=stagger, seed=seed)


def run_engine(model, workload, slots: int, page_size=None
               ) -> Dict[str, float]:
    from repro.serve import EngineConfig, ServingEngine
    max_len = max(p.shape[0] for p, _, _ in workload)
    max_new = max(m for _, m, _ in workload)
    engine = ServingEngine(model, EngineConfig(
        n_slots=slots, max_prompt_len=max_len, max_new_cap=max_new,
        cache_len=max_len + max_new,
        max_prefill_per_step=max(2, slots // 2),
        page_size=page_size))
    for prompt, m, arrival in workload:
        engine.submit(prompt, m, arrival=arrival)
    rep = engine.run()
    assert len(rep.completed) == len(workload)
    # thin reader: the engine's report derives every metric through
    # obs.metrics.throughput_summary — no bench-side re-derivation
    out = rep.as_dict()
    if page_size is None:
        out.pop("page_occupancy")
    return out


def paged_identity(slot_model, paged_model, workload, slots: int,
                   page_size: int) -> Dict[str, object]:
    """Token-identity + fragmentation evidence for the paged plane: one
    run per plane, outputs compared request-by-request, and the paged
    pool's page history checked for multi-page non-contiguous spans."""
    from repro.serve import EngineConfig, ServingEngine
    max_len = max(p.shape[0] for p, _, _ in workload)
    max_new = max(m for _, m, _ in workload)

    def engine(model, ps):
        eng = ServingEngine(model, EngineConfig(
            n_slots=slots, max_prompt_len=max_len, max_new_cap=max_new,
            cache_len=max_len + max_new,
            max_prefill_per_step=max(2, slots // 2), page_size=ps))
        for prompt, m, arrival in workload:
            eng.submit(prompt, m, arrival=arrival)
        return eng

    slot_eng = engine(slot_model, None)
    paged_eng = engine(paged_model, page_size)
    slot_rep, paged_rep = slot_eng.run(), paged_eng.run()
    identical = all(
        np.array_equal(slot_rep.completed[rid], paged_rep.completed[rid])
        for rid in slot_rep.completed)
    hist = paged_eng.pool.page_history
    multi = sum(len(pages) >= 2 for pages in hist.values())
    frag = sum(any(b != a + 1 for a, b in zip(pages, pages[1:]))
               for pages in hist.values())
    return {
        "token_identical": bool(identical),
        "requests": len(hist),
        "multi_page_requests": int(multi),
        "fragmented_requests": int(frag),
    }


def run_fleet(model, workload, slots: int,
              reference: Dict[int, np.ndarray],
              artifacts_dir=None) -> Dict[str, object]:
    """Elastic-rescale scenario: 3 heterogeneous replicas sharing the
    slot adapter (one compilation set), one killed mid-decode, one
    joining later.  Deterministic by construction (tick clock, seeded
    workload, fixed fault schedule), so everything here is a structural
    gate: the fleet's tokens must equal the single engine's, requests
    must have been requeued by the kill, and nothing may be lost.

    The run is traced (one shared Tracer on the controller's tick axis)
    and metered; ``artifacts_dir`` receives ``trace.json`` (Perfetto)
    and ``metrics.json`` (registry snapshot) — the CI artifacts."""
    from repro.fleet import (FaultPlan, FleetController, FleetFrontend,
                             Replica)
    from repro.obs import MetricsRegistry, Tracer, write_chrome_trace
    from repro.serve import EngineConfig
    max_len = max(p.shape[0] for p, _, _ in workload)
    max_new = max(m for _, m, _ in workload)
    ec = EngineConfig(
        n_slots=slots, max_prompt_len=max_len, max_new_cap=max_new,
        cache_len=max_len + max_new,
        max_prefill_per_step=max(2, slots // 2))
    tracer, metrics = Tracer(), MetricsRegistry()
    replicas = [
        Replica("r0", model, ec, rate=1.0, fault=FaultPlan(kill_at=4),
                tracer=tracer, metrics=metrics),
        Replica("r1", model, ec, rate=2.0, tracer=tracer, metrics=metrics),
        Replica("r2", model, ec, rate=0.5, tracer=tracer, metrics=metrics),
    ]
    # stealing is ON but must stay invisible: this scenario injects
    # kill/join faults, never contention, so the drift corrector's
    # hysteresis has to hold at zero steals (gated by check_regression)
    controller = FleetController(replicas, miss_threshold=3, steal=True,
                                 tracer=tracer, metrics=metrics)
    controller.schedule_join(Replica("r3", model, ec, rate=1.5,
                                     tracer=tracer, metrics=metrics),
                             at_tick=8)
    frontend = FleetFrontend(controller, max_pending=2 * slots)
    report = frontend.serve(workload)
    identical = (set(report.completed) == set(reference)
                 and all(np.array_equal(reference[rid],
                                        report.completed[rid])
                         for rid in reference))
    # exercise the admission-rejection path end to end: an over-budget
    # prompt must be refused by a live engine and counted by reason
    from repro.serve.engine.queue import AdmissionError
    survivor = controller.replicas[controller.alive_names()[0]]
    try:
        survivor.engine.submit(np.zeros(max_len + max_new + 1, np.int32), 1)
    except AdmissionError:
        pass
    if artifacts_dir is not None:
        d = pathlib.Path(artifacts_dir)
        d.mkdir(parents=True, exist_ok=True)
        write_chrome_trace(tracer, d / "trace.json")
        metrics.write_json(d / "metrics.json")
    return {
        "token_identical": bool(identical),
        "completed": int(report.n_completed),
        "requeued": int(report.requeues),
        "kills": len(report.kills),
        "joins": len(report.joins),
        "steals": int(report.steals),
        "ticks": int(report.ticks),
        "replica_occupancy": {n: round(float(v), 4)
                              for n, v in sorted(
                                  report.occupancy.items())},
        "replica_decode_tokens": {n: int(v) for n, v in sorted(
            report.decode_tokens.items())},
        # the metrics-snapshot structural gates (check_regression):
        # counted requeues must match the report, rejections must be
        # counted by reason
        "metrics": {
            "requeues": int(metrics.counter_value("requeues")),
            "admission_rejections": int(
                metrics.counter_total("admission_rejections")),
            "heartbeat_misses": int(
                metrics.counter_value("heartbeat_misses")),
            "steals": int(metrics.counter_value("steals")),
            "trace_events": len(tracer),
        },
    }


def run_chaos_scenario(model, workload, slots: int,
                       reference: Dict[int, np.ndarray],
                       artifacts_dir=None) -> Dict[str, object]:
    """Chaos smoke: one fixed-seed COMPOSITE fault schedule through the
    shared chaos harness — kill + transient(retry/backoff) + contention
    + torn checkpoint shards + join, with live checkpoint-recovery on.
    Tick-driven and fully fault-scheduled, so every emitted number is a
    structural verdict for check_regression: recoveries must equal the
    injected transients, every rescale must restore the checkpointed
    state (falling back past the torn snapshots), tokens must equal the
    single-engine reference, and nothing may be silently dropped."""
    import tempfile
    from repro.fleet import (ChaosReplicaSpec, ChaosSchedule, FaultPlan,
                             Replica, RetryPolicy, chaos_verdicts,
                             run_chaos)
    from repro.obs import MetricsRegistry, Tracer, write_chrome_trace
    from repro.serve import EngineConfig
    max_len = max(p.shape[0] for p, _, _ in workload)
    max_new = max(m for _, m, _ in workload)
    ec = EngineConfig(
        n_slots=slots, max_prompt_len=max_len, max_new_cap=max_new,
        cache_len=max_len + max_new,
        max_prefill_per_step=max(2, slots // 2))
    tracer, metrics = Tracer(), MetricsRegistry()

    def mk(name, rate, fault):
        return Replica(name, model, ec, rate=rate, fault=fault,
                       tracer=tracer, metrics=metrics)

    schedule = ChaosSchedule(
        replicas=(
            ChaosReplicaSpec("c0", 1.0, FaultPlan(kill_at=6)),
            ChaosReplicaSpec("c1", 2.0, FaultPlan(transient_at=3,
                                                  transient_for=2)),
            # contended AND tearing its shard of every snapshot from its
            # step 2 on — restores must fall back to an intact epoch
            ChaosReplicaSpec("c2", 1.0, FaultPlan(slow_at=2, slow_factor=2,
                                                  torn_shard_at=2)),
        ),
        join_at=10, join_name="c3", join_rate=1.5, checkpoint_every=4)
    # the co-hosted LBP state the controller snapshots/restores: one
    # load-sized leaf (sharded by the rebalance plan) + one replicated
    state = {"w": np.arange(1024 * 4, dtype=np.float32).reshape(1024, 4),
             "bias": np.arange(8, dtype=np.float32)}
    with tempfile.TemporaryDirectory() as ckpt_dir:
        ctrl, report = run_chaos(
            schedule, mk, workload,
            retry=RetryPolicy(max_retries=3, backoff_base=1, backoff_cap=8),
            checkpoint_dir=ckpt_dir, checkpoint_state=state,
            tracer=tracer, metrics=metrics)
    v = chaos_verdicts(schedule, report, workload, reference)
    if artifacts_dir is not None:
        d = pathlib.Path(artifacts_dir)
        d.mkdir(parents=True, exist_ok=True)
        write_chrome_trace(tracer, d / "chaos_trace.json")
    v["metrics"] = {
        "retries": int(metrics.counter_value("retries")),
        "recoveries": int(metrics.counter_value("recoveries")),
        "restores": int(metrics.counter_value("restores")),
        "corrupt_shards": int(metrics.counter_value("corrupt_shards")),
        "checkpoints": int(metrics.counter_value("checkpoints")),
        "trace_events": len(tracer),
    }
    return v


def run_prefix_sharing(paged_model, params, cfg, rules,
                       smoke: bool) -> Dict[str, object]:
    """Prefix-sharing capacity smoke: the shared-template workload
    served twice on the paged plane — worst-case private reservation vs
    ``prefix_sharing`` — reduced to structural verdicts for
    ``check_regression.py``: token identity vs the non-sharing plane AND
    (spot-checked, one request per template) vs ``greedy_generate``,
    peak pages-in-use strictly below the private baseline, refcounted
    attaches actually observed, and conservation at drain."""
    from repro.serve import EngineConfig, ServingEngine, greedy_generate
    from repro.serve.engine import shared_prefix_workload
    n, n_templates = (16, 2) if smoke else (32, 4)
    wl = shared_prefix_workload(n, cfg.vocab_size, n_templates=n_templates,
                                template_len=16, suffix_lens=(4, 8, 12),
                                news=(4, 8, 12, 16), stagger=0.5)

    def run(sharing):
        eng = ServingEngine(paged_model, EngineConfig(
            n_slots=8, max_prompt_len=28, max_new_cap=16, cache_len=44,
            page_size=4, prefix_sharing=sharing))
        for prompt, m, arrival in wl:
            eng.submit(prompt, m, arrival=arrival)
        return eng, eng.run()

    eng_off, rep_off = run(False)
    eng_on, rep_on = run(True)
    identical = all(np.array_equal(rep_off.completed[rid],
                                   rep_on.completed[rid])
                    for rid in rep_off.completed)
    oracle_ok = True
    for rid in range(n_templates):            # one request per template
        prompt, m, _ = wl[rid]
        ref = np.asarray(greedy_generate(params, cfg, rules,
                                         np.asarray(prompt)[None],
                                         max_new=m))[0]
        oracle_ok = oracle_ok and np.array_equal(ref, rep_on.completed[rid])
    pool_on, pool_off = eng_on.pool, eng_off.pool
    return {
        "requests": n, "templates": n_templates,
        "token_identical_vs_private": bool(identical),
        "token_identical_vs_oracle": bool(oracle_ok),
        "peak_used_pages_private": int(pool_off.peak_used_pages),
        "peak_used_pages_shared": int(pool_on.peak_used_pages),
        "capacity_ratio": (pool_off.peak_used_pages
                           / max(pool_on.peak_used_pages, 1)),
        "shared_attaches": int(pool_on.n_shared_attached),
        "max_refcount": int(pool_on.max_refcount),
        "refcount_conserved": bool(
            pool_on.n_allocated == pool_on.n_freed
            and len(pool_on.prefix_index) == 0
            and pool_on.free_page_count == pool_on.n_pages),
    }


def run_fixed_batch(params, cfg, rules, workload, slots: int
                    ) -> Dict[str, float]:
    """The seed serving path: fixed batches, padded to the workload max."""
    import jax.numpy as jnp
    from repro.serve import cached_decode_step, cached_prefill_step
    from repro.models import transformer as T
    Smax = max(p.shape[0] for p, _, _ in workload)
    new_max = max(m for _, m, _ in workload)
    prefill = cached_prefill_step(cfg, rules)
    decode = cached_decode_step(cfg, rules)
    useful = sum(m for _, m, _ in workload)

    t0 = time.perf_counter()
    ttfts = []
    for g in range(0, len(workload), slots):
        group = workload[g:g + slots]
        batch = np.zeros((slots, Smax), np.int32)   # pad rows + dummy reqs
        for b, (p, _, _) in enumerate(group):
            batch[b, :p.shape[0]] = p
        cache = T.init_cache(cfg, slots, Smax + new_max)
        cache, logits = prefill(params, jnp.asarray(batch), cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        tok.block_until_ready()
        ttfts += [time.perf_counter() - t0] * len(group)
        pos = jnp.full((slots,), Smax, jnp.int32)
        for _ in range(new_max - 1):
            nxt, _, cache = decode(params, tok, pos, cache)
            tok = nxt[:, None]
            pos = pos + 1
        tok.block_until_ready()
    wall = time.perf_counter() - t0
    n_groups = (len(workload) + slots - 1) // slots
    raw = n_groups * slots * new_max
    decode_steps = n_groups * (new_max - 1)
    # same derivation as the engine report (obs.metrics.throughput_summary):
    # the fixed batch contributes its useful fraction once per decode step
    from repro.obs import throughput_summary
    out = throughput_summary(
        useful_tokens=useful, wall_s=wall, ttfts_s=ttfts,
        occupancy_sum=(useful / raw) * decode_steps,
        decode_steps=decode_steps)
    out.pop("decode_tokens_per_sec")   # the fixed path times no decode split
    return out


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload for CI (16 requests, 4 slots)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page for the paged-plane side")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=5,
                    help="measured repetitions; best wall per side is kept "
                         "(shared CI runners swing several-fold run to run)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    import jax
    from repro.configs import get_reduced
    from repro.models import transformer as T
    from repro.serve import TransformerModel
    from repro.sharding.rules import Rules

    n, slots = (16, 4) if args.smoke else (args.requests, args.slots)
    lens, news = ((8, 16), (2, 16)) if args.smoke else (PROMPT_LENS, MAX_NEWS)
    page_size = args.page_size
    cfg = get_reduced("llama3_2_3b")
    rules = Rules.null()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    workload = make_workload(n, args.seed, cfg.vocab_size, lens, news)
    from repro.serve import PagedTransformerModel
    model = TransformerModel(params, cfg, rules)
    paged_model = PagedTransformerModel(params, cfg, rules)

    # warmup: compile every shape all three paths will touch
    run_engine(model, workload, slots)
    run_engine(paged_model, workload, slots, page_size=page_size)
    run_fixed_batch(params, cfg, rules, workload, slots)

    eng = min((run_engine(model, workload, slots)
               for _ in range(args.reps)), key=lambda r: r["wall_s"])
    paged = min((run_engine(paged_model, workload, slots,
                            page_size=page_size)
                 for _ in range(args.reps)), key=lambda r: r["wall_s"])
    base = min((run_fixed_batch(params, cfg, rules, workload, slots)
                for _ in range(args.reps)), key=lambda r: r["wall_s"])
    identity = paged_identity(model, paged_model, workload, slots,
                              page_size)

    # fleet oracle reference: the single engine's tokens (themselves
    # oracle-tested against greedy_generate in tier-1)
    from repro.serve import EngineConfig, ServingEngine
    max_len = max(p.shape[0] for p, _, _ in workload)
    max_new = max(m for _, m, _ in workload)
    ref_eng = ServingEngine(model, EngineConfig(
        n_slots=slots, max_prompt_len=max_len, max_new_cap=max_new,
        cache_len=max_len + max_new,
        max_prefill_per_step=max(2, slots // 2)))
    for prompt, m, arrival in workload:
        ref_eng.submit(prompt, m, arrival=arrival)
    reference = ref_eng.run().completed
    # trace.json / metrics.json land beside the BENCH artifact (CI
    # uploads the whole directory)
    fleet = run_fleet(model, workload, slots, reference,
                      artifacts_dir=pathlib.Path(args.out).parent)
    fleet["chaos"] = run_chaos_scenario(
        model, workload, slots, reference,
        artifacts_dir=pathlib.Path(args.out).parent)
    sharing = run_prefix_sharing(paged_model, params, cfg, rules,
                                 smoke=args.smoke)
    result = {
        "workload": {"requests": n, "slots": slots, "seed": args.seed,
                     "prompt_lens": list(lens), "max_news": list(news),
                     "page_size": page_size,
                     "arch": cfg.name, "smoke": bool(args.smoke)},
        "engine": eng,
        "paged": paged,
        "fixed_batch": base,
        "speedup": eng["tokens_per_sec"] / base["tokens_per_sec"],
        "paged_vs_slot": {
            "tokens_per_sec_ratio": (paged["tokens_per_sec"]
                                     / eng["tokens_per_sec"]),
            "occupancy_delta": paged["occupancy"] - eng["occupancy"],
            "page_occupancy": paged["page_occupancy"],
            **identity,
        },
        "fleet": fleet,
        "prefix_sharing": sharing,
    }
    print(f"\nworkload: {n} staggered requests, {slots} slots, {cfg.name}")
    print(f"engine:      {eng['tokens_per_sec']:8.1f} tok/s  "
          f"occupancy {eng['occupancy']:.2f}  "
          f"ttft {eng['ttft_mean_s']*1e3:.0f}ms")
    print(f"paged:       {paged['tokens_per_sec']:8.1f} tok/s  "
          f"occupancy {paged['occupancy']:.2f}  "
          f"page-occ {paged['page_occupancy']:.2f}  "
          f"(page_size={page_size})")
    print(f"fixed batch: {base['tokens_per_sec']:8.1f} tok/s  "
          f"useful-fraction {base['occupancy']:.2f}  "
          f"ttft {base['ttft_mean_s']*1e3:.0f}ms")
    print(f"speedup:     {result['speedup']:.2f}x   paged/slot "
          f"{result['paged_vs_slot']['tokens_per_sec_ratio']:.2f}x  "
          f"identical={identity['token_identical']}  "
          f"fragmented {identity['fragmented_requests']}"
          f"/{identity['requests']}")
    print(f"fleet:       {fleet['completed']} completed in "
          f"{fleet['ticks']} ticks, {fleet['kills']} kill / "
          f"{fleet['joins']} join, requeued {fleet['requeued']}, "
          f"steals {fleet['steals']}, "
          f"identical={fleet['token_identical']}")
    ch = fleet["chaos"]
    print(f"chaos:       {ch['completed']} completed under composite "
          f"faults: {ch['retries']} retries -> {ch['recoveries']} "
          f"recovered, {ch['kills']} kill / {ch['joins']} join -> "
          f"{ch['restores']} restores ({ch['corrupt_shards']} torn "
          f"snapshots skipped), identical={ch['token_identical']}, "
          f"gates={'all pass' if all(ch['gates'].values()) else ch['gates']}")
    print(f"sharing:     {sharing['requests']} reqs / "
          f"{sharing['templates']} templates: peak pages "
          f"{sharing['peak_used_pages_private']} -> "
          f"{sharing['peak_used_pages_shared']} "
          f"({sharing['capacity_ratio']:.2f}x), "
          f"{sharing['shared_attaches']} attaches, max refcount "
          f"{sharing['max_refcount']}, "
          f"identical={sharing['token_identical_vs_private']}"
          f"/oracle={sharing['token_identical_vs_oracle']}, "
          f"conserved={sharing['refcount_conserved']}")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")
    return result


if __name__ == "__main__":
    main()
