"""Shared benchmark helpers (timing + host-device re-exec)."""

from __future__ import annotations

import os
import subprocess
import sys
import time


def time_best(fn, reps: int) -> float:
    """Best wall time over ``reps`` calls (shared CI hosts swing several-
    fold run to run; best-of is the stable statistic)."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def host_device_env(n: int = 8) -> dict:
    """A copy of os.environ with ``n`` forced host devices APPENDED to
    XLA_FLAGS (dump/debug flags are preserved; an existing device_count
    pin is respected)."""
    env = dict(os.environ)
    if "device_count" not in env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={n}"
                            ).strip()
    return env


def ensure_host_devices(n: int = 8) -> None:
    """Re-exec the current script with ``n`` forced host devices unless
    XLA_FLAGS already pins a device count.  Must run before jax is
    imported."""
    if "device_count" in os.environ.get("XLA_FLAGS", ""):
        return
    raise SystemExit(subprocess.run([sys.executable] + sys.argv,
                                    env=host_device_env(n)).returncode)
