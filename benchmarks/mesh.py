"""Figs 7/8/9: heterogeneous mesh — volume, finish time, simplex iterations.

Paper setup (§6.2): 5x5 / 7x7 / 9x9 meshes, w*Tcp ~ U(0.0005, 0.0008),
z*Tcm ~ U(0.0002, 0.0005), N = 1000..2000, averages over independent nets.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.heuristic import mft_lbp_heuristic
from repro.core.mesh_baselines import (simulate_modified_pipeline,
                                       simulate_pipeline, simulate_summa)
from repro.core.network import random_mesh
from repro.core.pmft import pmft_lbp

DIMS = [5, 7, 9]
NS = [1000, 1500, 2000]
TRIALS = 3


def run() -> Dict:
    out: Dict = {}
    for dim in DIMS:
        per = {k: [] for k in ["LBP", "LBP-heuristic", "SUMMA",
                               "ModifiedPipeline", "Pipeline"]}
        pert = {k: [] for k in per}
        iters = {"LBP": [], "LBP-heuristic": []}
        for N in NS:
            acc_v = {k: 0.0 for k in per}
            acc_t = {k: 0.0 for k in per}
            acc_i = {k: 0.0 for k in iters}
            for trial in range(TRIALS):
                net = random_mesh(dim, dim, seed=dim * 100 + trial)
                a = pmft_lbp(net, N)
                h = mft_lbp_heuristic(net, N)
                s = simulate_summa(net, N)
                mp = simulate_modified_pipeline(net, N)
                pl = simulate_pipeline(net, N)
                acc_v["LBP"] += a.comm_volume
                acc_v["LBP-heuristic"] += h.comm_volume
                acc_v["SUMMA"] += s.comm_volume
                acc_v["ModifiedPipeline"] += mp.comm_volume
                acc_v["Pipeline"] += pl.comm_volume
                acc_t["LBP"] += a.t_finish
                acc_t["LBP-heuristic"] += h.t_finish
                acc_t["SUMMA"] += s.finish_time
                acc_t["ModifiedPipeline"] += mp.finish_time
                acc_t["Pipeline"] += pl.finish_time
                acc_i["LBP"] += a.simplex_iters
                acc_i["LBP-heuristic"] += h.simplex_iters
            for k in per:
                per[k].append(acc_v[k] / TRIALS)
                pert[k].append(acc_t[k] / TRIALS)
            for k in iters:
                iters[k].append(acc_i[k] / TRIALS)
        out[dim] = {"volume": per, "time": pert, "iters": iters}
    return out


def report(out_fn) -> List[tuple]:
    res = run()
    rows = []
    for dim in DIMS:
        v = res[dim]["volume"]
        t = res[dim]["time"]
        it = res[dim]["iters"]
        out_fn(f"\nFig 7 — {dim}x{dim} mesh comm volume (M entries), N={NS}")
        for k in v:
            out_fn(f"  {k:17s} " + " ".join(f"{x/1e6:9.1f}" for x in v[k]))
        out_fn(f"Fig 8 — {dim}x{dim} mesh finish time (s), N={NS}")
        for k in t:
            out_fn(f"  {k:17s} " + " ".join(f"{x:9.0f}" for x in t[k]))
        out_fn(f"Fig 9 — {dim}x{dim} simplex iterations, N={NS}")
        for k in it:
            out_fn(f"  {k:17s} " + " ".join(f"{x:9.0f}" for x in it[k]))

        i = len(NS) - 1
        rows.append((f"fig7.{dim}x{dim}.lbp_cut_vs_modpipe_pct",
                     (1 - v["LBP"][i] / v["ModifiedPipeline"][i]) * 100,
                     "paper: 81%"))
        rows.append((f"fig7.{dim}x{dim}.lbp_cut_vs_pipe_pct",
                     (1 - v["LBP"][i] / v["Pipeline"][i]) * 100,
                     "paper: 90%"))
        rows.append((f"fig8.{dim}x{dim}.heuristic_excess_pct",
                     (t["LBP-heuristic"][i] / t["LBP"][i] - 1) * 100,
                     "paper: 0.03-0.18%"))
        rows.append((f"fig8.{dim}x{dim}.summa_excess_pct",
                     (t["SUMMA"][i] / t["LBP"][i] - 1) * 100,
                     "paper: 46-56%"))
        rows.append((f"fig9.{dim}x{dim}.heuristic_iter_ratio",
                     it["LBP-heuristic"][i] / max(it["LBP"][i], 1),
                     "paper: far below"))
    return rows
