"""One benchmark per paper table/figure + the roofline report.

  fig6a_star_comm   Fig 6(a): star-network total communication volume
  fig6b_star_time   Fig 6(b): star-network task finishing time (PCCS)
  fig7_mesh_comm    Fig 7: mesh overall communication volume (5/7/9)
  fig8_mesh_time    Fig 8: mesh task finishing time
  fig9_lp_iters     Fig 9: simplex iterations, PMFT-LBP vs heuristic
  roofline_report   §Roofline: three-term table from dry-run artifacts

``python -m benchmarks.run`` executes all of them and prints
``name,value,derived`` CSV rows plus the paper-claim comparisons.
"""
